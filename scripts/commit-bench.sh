#!/usr/bin/env bash
# Pull the latest bench-quick artifact JSONs from CI into the repo root.
#
# The `bench-quick` CI job runs every bench with --quick and uploads the
# emitted JSON files as the `bench-json` artifact. This script downloads
# that artifact from the most recent successful run on the current branch
# and drops the files where the benches would have written them locally,
# so they can be committed as the measured baseline.
#
# Usage:
#   bash scripts/commit-bench.sh [run-id]
#
# With no argument, the latest successful CI run for the current branch is
# used. Requires the GitHub CLI (`gh`) authenticated against the repo.
set -euo pipefail

cd "$(dirname "$0")/.."

run_id="${1:-}"
if [[ -z "$run_id" ]]; then
    branch="$(git rev-parse --abbrev-ref HEAD)"
    # CI checkouts are detached; fall back to the ref GitHub Actions exports.
    if [[ "$branch" == "HEAD" ]]; then
        branch="${GITHUB_REF_NAME:-}"
        if [[ -z "$branch" ]]; then
            echo "detached HEAD and no GITHUB_REF_NAME — pass a run id" >&2
            exit 1
        fi
    fi
    run_id="$(gh run list --branch "$branch" --status success --limit 1 \
        --json databaseId --jq '.[0].databaseId')"
    if [[ -z "$run_id" || "$run_id" == "null" ]]; then
        echo "no successful CI run found for branch '$branch'" >&2
        exit 1
    fi
fi

echo "downloading bench-json artifact from run $run_id"
gh run download "$run_id" --name bench-json --dir .

for f in BENCH_perf_hotpath.json BENCH_train_step.json; do
    [[ -f "$f" ]] || { echo "artifact missing $f" >&2; exit 1; }
done

git add BENCH_perf_hotpath.json BENCH_train_step.json
git status --short BENCH_perf_hotpath.json BENCH_train_step.json
echo "bench JSONs staged; review and commit."
