"""L1 Pallas kernel: codebook gather + sum (the decoder's front half,
paper Fig. 2).

Maps each row of integer codes ``(B, m)`` to the sum of the indexed rows of
``m`` codebooks ``(m, c, d_c)``. Exposed as :func:`gather_sum`, a
``jax.custom_vjp`` so the surrounding L2 model can be differentiated (the
cotangent w.r.t. the codebooks is a scatter-add, also a Pallas kernel;
codes are integral and get no gradient).

TPU mapping (DESIGN.md §3): the grid tiles the batch (``block_b`` rows per
step) while the codebooks stay VMEM-resident across grid steps —
``m·c·d_c·4`` bytes, ≤8 MB for every configuration in the paper. Two
in-kernel gather strategies:

- ``onehot`` — one-hot matmul per codebook, MXU-friendly for small ``c``;
- ``take``   — vector gather, better for large ``c`` (e.g. 256).

Kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); structure, not interpret-mode wallclock, is what carries to
TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch rows per grid step. 128 keeps the working set
# (block_b·(m + d_c)·4B + codebooks) well under VMEM while filling the
# 8×128 VPU lanes.
DEFAULT_BLOCK_B = 128

# Below this cardinality the one-hot matmul beats the gather on MXU.
ONEHOT_MAX_C = 16


def _fwd_kernel(codes_ref, books_ref, o_ref, *, use_onehot):
    codes = codes_ref[...]  # (block_b, m)
    books = books_ref[...]  # (m, c, d_c)
    m, c, _d = books.shape
    acc = jnp.zeros((codes.shape[0], books.shape[2]), jnp.float32)
    for i in range(m):  # static unroll: m is a compile-time constant
        if use_onehot:
            onehot = jax.nn.one_hot(codes[:, i], c, dtype=jnp.float32)
            acc = acc + onehot @ books[i]
        else:
            acc = acc + jnp.take(books[i], codes[:, i], axis=0)
    o_ref[...] = acc


def _bwd_kernel(codes_ref, g_ref, gbooks_ref):
    codes = codes_ref[...]  # (B, m)
    g = g_ref[...]  # (B, d_c)
    m, c, d = gbooks_ref.shape
    out = jnp.zeros((m, c, d), jnp.float32)
    for i in range(m):
        onehot = jax.nn.one_hot(codes[:, i], c, dtype=jnp.float32)  # (B, c)
        out = out.at[i].add(onehot.T @ g)
    gbooks_ref[...] = out


def _pad_to_multiple(x, multiple):
    b = x.shape[0]
    rem = b % multiple
    if rem == 0:
        return x, b
    pad = multiple - rem
    return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0), b


def _gather_sum_fwd_impl(codes, books, block_b):
    b, m = codes.shape
    _m, c, d = books.shape
    use_onehot = c <= ONEHOT_MAX_C
    padded, orig_b = _pad_to_multiple(codes, block_b)
    grid = padded.shape[0] // block_b
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, use_onehot=use_onehot),
        grid=(grid,),
        in_specs=[
            # batch tile advances with the grid...
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            # ...codebooks are replicated (VMEM-resident across steps).
            pl.BlockSpec((m, c, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[0], d), jnp.float32),
        interpret=True,
    )(padded, books)
    return out[:orig_b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def gather_sum(codes, books, block_b=DEFAULT_BLOCK_B):
    """``out[b] = Σ_i books[i, codes[b, i], :]`` — (B, d_c)."""
    return _gather_sum_fwd_impl(codes, books, block_b)


def _gather_sum_vjp_fwd(codes, books, block_b):
    return _gather_sum_fwd_impl(codes, books, block_b), (codes, books.shape)


def _gather_sum_vjp_bwd(block_b, res, g):
    codes, books_shape = res
    gbooks = pl.pallas_call(
        _bwd_kernel,
        out_shape=jax.ShapeDtypeStruct(books_shape, jnp.float32),
        interpret=True,
    )(codes, g)
    return None, gbooks


gather_sum.defvjp(_gather_sum_vjp_fwd, _gather_sum_vjp_bwd)


def vmem_bytes(block_b, m, c, d_c):
    """Static VMEM footprint estimate for one grid step (DESIGN.md §9):
    code tile + codebooks + accumulator/output tile, f32."""
    return 4 * (block_b * m + m * c * d_c + 2 * block_b * d_c)
