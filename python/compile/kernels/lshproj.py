"""L1 Pallas kernel: blocked random projection ``U = A @ V`` (Algorithm 1
lines 7-8) for the *dense* auxiliary path (pre-trained embeddings).

The production encoder is the streaming rust implementation (DESIGN.md §8);
this kernel demonstrates how the projection maps to a TPU tile schedule
(rows of ``A`` stream HBM→VMEM block by block, the projection block ``V``
stays resident) and backs the kernel-level benches. Encoding is a one-shot
preprocessing step, so no VJP is needed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256


def _proj_kernel(a_ref, v_ref, o_ref):
    o_ref[...] = a_ref[...] @ v_ref[...]


def project(aux, vs, block_n=DEFAULT_BLOCK_N):
    """``(n, d) @ (d, k) -> (n, k)`` with the row dimension tiled.

    ``vs`` holds one random vector per *output bit* of Algorithm 1; a block
    of bits shares a single pass over ``A`` (the paper's memory argument
    bounds the live set to ``V`` and ``U`` — here ``k·d`` and ``block_n·k``
    floats).
    """
    n, d = aux.shape
    k = vs.shape[1]
    rem = n % block_n
    if rem:
        pad = block_n - rem
        aux = jnp.concatenate([aux, jnp.zeros((pad, d), aux.dtype)], axis=0)
    grid = aux.shape[0] // block_n
    out = pl.pallas_call(
        _proj_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((aux.shape[0], k), jnp.float32),
        interpret=True,
    )(aux, vs)
    return out[:n]


def vmem_bytes(block_n, d, k):
    """Per-grid-step VMEM estimate: A tile + V + U tile, f32."""
    return 4 * (block_n * d + d * k + block_n * k)
