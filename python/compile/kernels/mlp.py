"""L1 Pallas kernel: fused dense layer ``relu?(x @ w + b)`` — the decoder
MLP's building block (paper Fig. 2, right half).

The grid tiles the batch; ``w``/``b`` stay VMEM-resident across grid steps
(d_c×d_m ≤ 512×512×4B = 1 MB per layer at paper dims). The matmul shape
(block_b × d_in)·(d_in × d_out) is MXU-systolic-friendly at the chosen
dims (multiples of 128 lanes).

``linear`` is a ``jax.custom_vjp``: dx/dw/db are themselves Pallas matmul
kernels, so the whole decoder fwd+bwd lowers through L1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]
    y = x @ w_ref[...] + b_ref[...][None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] @ b_ref[...]


def _pad_rows(x, multiple):
    b = x.shape[0]
    rem = b % multiple
    if rem == 0:
        return x, b
    pad = multiple - rem
    return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0), b


def _linear_impl(x, w, b, relu, block_b):
    d_in, d_out = w.shape
    padded, orig_b = _pad_rows(x, block_b)
    grid = padded.shape[0] // block_b
    out = pl.pallas_call(
        functools.partial(_linear_kernel, relu=relu),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[0], d_out), jnp.float32),
        interpret=True,
    )(padded, w, b)
    return out[:orig_b]


def _matmul(a, b):
    """Unblocked Pallas matmul used by the backward pass."""
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def linear(x, w, b, relu=False, block_b=DEFAULT_BLOCK_B):
    """Fused dense layer: ``relu?(x @ w + b)``."""
    return _linear_impl(x, w, b, relu, block_b)


def _linear_vjp_fwd(x, w, b, relu, block_b):
    y = _linear_impl(x, w, b, relu, block_b)
    # For the ReLU backward we need the activation mask; y > 0 encodes it.
    return y, (x, w, y if relu else None)


def _linear_vjp_bwd(relu, block_b, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    dx = _matmul(g, w.T)
    dw = _matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_vjp_fwd, _linear_vjp_bwd)


def vmem_bytes(block_b, d_in, d_out):
    """Static per-grid-step VMEM estimate: x tile + weights + bias + out
    tile, f32."""
    return 4 * (block_b * d_in + d_in * d_out + d_out + block_b * d_out)
