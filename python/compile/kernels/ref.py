"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
signal: ``pytest python/tests`` asserts kernel == ref to float tolerance).
"""

import jax
import jax.numpy as jnp


def codebook_gather_sum_ref(codes, books):
    """Decoder front-end (paper Fig. 2): sum of one codebook row per code
    element.

    codes: (B, m) int32 in [0, c)
    books: (m, c, d_c) float32
    returns (B, d_c) float32
    """
    m = books.shape[0]
    return sum(jnp.take(books[i], codes[:, i], axis=0) for i in range(m))


def codebook_gather_sum_grad_ref(codes, g, books_shape):
    """VJP of the gather-sum w.r.t. the codebooks: scatter-add of the
    output cotangent into the indexed rows."""
    m, c, _d = books_shape
    out = jnp.zeros(books_shape, jnp.float32)
    for i in range(m):
        onehot = jax.nn.one_hot(codes[:, i], c, dtype=jnp.float32)  # (B, c)
        out = out.at[i].add(onehot.T @ g)
    return out


def linear_ref(x, w, b, relu):
    """Dense layer: ``relu?(x @ w + b)``.

    x: (B, d_in), w: (d_in, d_out), b: (d_out,)
    """
    y = x @ w + b
    return jnp.maximum(y, 0.0) if relu else y


def lsh_project_ref(aux, vs):
    """Random-projection block (Algorithm 1 lines 7-8, blocked over bits):
    ``U = A @ V`` for a block of random vectors.

    aux: (n, d), vs: (d, k) -> (n, k)
    """
    return aux @ vs


def lsh_bits_ref(aux, vs):
    """Full dense-aux encode reference: project then binarize at the
    per-column median (paper's threshold choice)."""
    u = lsh_project_ref(aux, vs)  # (n, k)
    med = jnp.median(u, axis=0, keepdims=True)
    return u > med
