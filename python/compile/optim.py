"""Functional AdamW (Loshchilov & Hutter 2018), lowered *inside* every
train-step executable so one PJRT call performs fwd + bwd + update.

State (first/second moments) and the step counter live in rust and are
threaded through each call; non-trainable params (light decoder codebooks,
Table 2's off-GPU storage argument) are masked out of both the gradient
update and the decoupled weight decay.
"""

import jax
import jax.numpy as jnp


def adamw_update(params, grads, ms, vs, step, hyper, trainable):
    """One AdamW step over aligned lists of arrays.

    step: f32 scalar tensor holding the number of *completed* steps.
    hyper: dict with lr, beta1, beta2, eps, weight_decay (python floats,
    burned into the executable; recorded in the manifest).
    trainable: list of python bools (static).
    """
    lr = hyper["lr"]
    b1 = hyper["beta1"]
    b2 = hyper["beta2"]
    eps = hyper["eps"]
    wd = hyper["weight_decay"]
    t = step + 1.0
    new_params, new_ms, new_vs = [], [], []
    for p, g, m, v, trn in zip(params, grads, ms, vs, trainable):
        if not trn:
            new_params.append(p)
            new_ms.append(m)
            new_vs.append(v)
            continue
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * (g * g)
        mhat = m / (1.0 - jnp.power(b1, t))
        vhat = v / (1.0 - jnp.power(b2, t))
        update = mhat / (jnp.sqrt(vhat) + eps) + wd * p
        new_params.append(p - lr * update)
        new_ms.append(m)
        new_vs.append(v)
    return new_params, new_ms, new_vs


def make_train_step(loss_fn, trainable, hyper):
    """Wrap ``loss_fn(params, batch) -> scalar`` into the executable's
    signature: ``(params, ms, vs, step, *batch) ->
    (*new_params, *new_ms, *new_vs, loss)``."""

    def train_step(params, ms, vs, step, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), list(batch))
        new_params, new_ms, new_vs = adamw_update(
            list(params), grads, list(ms), list(vs), step, hyper, trainable
        )
        return tuple(new_params) + tuple(new_ms) + tuple(new_vs) + (loss,)

    return train_step
