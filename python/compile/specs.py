"""Shared spec types for the AOT bridge.

A *model build* is a plain dict the exporter understands:

    {
      "name":            str,
      "params":          [Param, ...]           # canonical order
      "train_inputs":    [Tensor, ...],         # batch inputs of train step
      "train_fn":        f(param_arrays, batch_arrays) -> scalar loss,
      "pred_inputs":     [Tensor, ...],
      "pred_fn":         f(param_arrays, batch_arrays) -> array,
      "pred_output":     Tensor,                # shape/dtype of pred_fn out
      "hyper":           dict,                  # recorded in the manifest
    }

The rust side re-creates parameter buffers from the manifest (same order,
same init rules), so ``Param.init`` must stay in sync with
``rust/src/params``.
"""

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class Param:
    """One parameter tensor.

    init kinds (mirrored by rust/src/params):
      - ``xavier_uniform``: U(-a, a), a = sqrt(6 / (fan_in + fan_out))
        with fan_in/fan_out = first/last shape dims,
      - ``normal``: N(0, std²),
      - ``zeros`` / ``ones``.
    """

    name: str
    shape: Tuple[int, ...]
    init: str = "xavier_uniform"
    std: float = 0.0
    trainable: bool = True


@dataclass(frozen=True)
class Tensor:
    """One non-parameter input or output tensor."""

    name: str
    shape: Tuple[int, ...]
    dtype: str = "f32"  # "f32" | "i32"


def param_json(p: Param) -> dict:
    return {
        "name": p.name,
        "shape": list(p.shape),
        "init": p.init,
        "std": p.std,
        "trainable": p.trainable,
    }


def tensor_json(t: Tensor) -> dict:
    return {"name": t.name, "shape": list(t.shape), "dtype": t.dtype}
