"""Learning-based coding baseline (§5.1): a Gumbel-softmax compositional
autoencoder in the style of Shu & Nakayama (2018).

The encoder maps a pre-trained embedding to ``m`` categorical
distributions over ``c`` codes; a straight-through Gumbel-softmax sample
selects codebook rows; the decoder (same structure as the paper's decoder
MLP) reconstructs the embedding. Gumbel noise arrives as a *uniform* input
tensor (rust supplies it), keeping the exported HLO PRNG-free.

After training, ``pred`` (= encode) emits hard integer codes via argmax —
those feed the same reconstruction pipeline as random/hash codes.
"""

import jax
import jax.numpy as jnp

from . import decoder
from .specs import Param, Tensor

TAU = 1.0
ENC_HIDDEN = 256


def ae_param_specs(c, m, d_c, d_m, d_e, l):
    enc = [
        Param("enc.w1", (d_e, ENC_HIDDEN)),
        Param("enc.b1", (ENC_HIDDEN,), init="zeros"),
        Param("enc.w2", (ENC_HIDDEN, m * c)),
        Param("enc.b2", (m * c,), init="zeros"),
    ]
    dec = decoder.decoder_param_specs(c, m, d_c, d_m, d_e, l, "full")
    return enc + dec


def encode_logits(p, emb, c, m):
    h = jax.nn.relu(emb @ p["enc.w1"] + p["enc.b1"])
    return (h @ p["enc.w2"] + p["enc.b2"]).reshape(emb.shape[0], m, c)


def make_autoencoder(name, c, m, d_c, d_m, d_e, l, batch, optim):
    specs = ae_param_specs(c, m, d_c, d_m, d_e, l)

    def train_fn(params, batch_in):
        p = {s.name: a for s, a in zip(specs, params)}
        emb, uniform = batch_in
        logits = encode_logits(p, emb, c, m)  # (B, m, c)
        gumbel = -jnp.log(-jnp.log(jnp.clip(uniform, 1e-6, 1.0 - 1e-6)))
        soft = jax.nn.softmax((logits + gumbel) / TAU, axis=-1)
        hard = jax.nn.one_hot(jnp.argmax(soft, axis=-1), c, dtype=soft.dtype)
        st = jax.lax.stop_gradient(hard - soft) + soft  # straight-through
        # Soft codebook lookup: (B, m, c) × (m, c, d_c) -> (B, d_c).
        gathered = jnp.einsum("bmc,mcd->bd", st, p["dec.books"])
        h = gathered
        for i in range(l):
            w, b = p[f"dec.mlp{i}.w"], p[f"dec.mlp{i}.b"]
            h = h @ w + b
            if i < l - 1:
                h = jax.nn.relu(h)
        return jnp.mean((h - emb) ** 2)

    def pred_fn(params, batch_in):
        p = {s.name: a for s, a in zip(specs, params)}
        (emb,) = batch_in
        logits = encode_logits(p, emb, c, m)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, m)

    return {
        "name": name,
        "params": specs,
        "train_inputs": [
            Tensor("emb", (batch, d_e), "f32"),
            Tensor("uniform", (batch, m, c), "f32"),
        ],
        "train_fn": train_fn,
        "pred_inputs": [Tensor("emb", (batch, d_e), "f32")],
        "pred_fn": pred_fn,
        "pred_output": Tensor("codes", (batch, m), "i32"),
        "hyper": {
            "task": "autoencoder",
            "c": c,
            "m": m,
            "d_c": d_c,
            "d_m": d_m,
            "d_e": d_e,
            "l": l,
            "batch": batch,
            "tau": TAU,
            "optim": dict(optim),
        },
    }
