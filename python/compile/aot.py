"""AOT exporter: lowers every model variant to HLO **text** +
JSON manifest under ``artifacts/``.

Run once at build time (``make artifacts``); the rust coordinator then
compiles and executes the artifacts via PJRT with no Python anywhere on
the training/serving path.

HLO text (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Usage:
    python -m compile.aot --out ../artifacts [--only PREFIX] [--list]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import autoenc, model, optim
from .specs import param_json, tensor_json

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}

# PyTorch AdamW defaults (paper Appendix B.2, reconstruction/AE training).
ADAMW_DEFAULT = {"lr": 1e-3, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.01}
# GNN training settings (Appendix C.1, §5.3.2): lr=0.01, wd=0.
ADAMW_GNN = {"lr": 1e-2, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0}

# ---------------------------------------------------------------------------
# Variant registry — every artifact the rust side references by name.
# Scale notes (DESIGN.md §10): CPU-sized dims; relative orderings are the
# reproduction target, paper dims are used for the analytic memory tables.
# ---------------------------------------------------------------------------

# (c, m) grid of Table 5 / Appendix B.3. All settings use 128-bit codes.
CM_GRID = [(2, 128), (4, 64), (16, 32), (256, 16)]

# Reconstruction decoder dims (paper: d_c=d_m=512; scaled to 256 for CPU).
RECON = {"d_c": 256, "d_m": 256, "d_e": 128, "l": 3, "batch": 512}

# Table-1 scale: n nodes per synthetic OGB analog, shared across datasets
# so one artifact set serves all of them.
T1 = {
    "n": 1024,
    "n_classes": 8,
    "d_e": 64,
    "hidden": 64,
    "c": 16,
    "m": 32,
    "d_c": 128,
    "d_m": 128,
    "l": 3,
    "variant": "full",
    "e_train": 512,
    "e_pred": 4096,
}

# Minibatch GraphSAGE (Figure 4 / e2e example) scale.
MB = {
    "n": 10000,
    "n_classes": 8,
    "d_e": 64,
    "hidden": 128,
    "batch": 256,
    "k1": 10,
    "k2": 10,
    "c": 16,
    "m": 32,
    "d_c": 128,
    "d_m": 128,
    "l": 3,
    "variant": "full",
}

# Merchant-category task (§5.3) scale: categories Zipf-imbalanced, SAGE
# minibatch, paper hypers c=256, m=16, fanout 5.
MERCHANT = {
    "n": 60000,
    "n_classes": 64,
    "d_e": 64,
    "hidden": 128,
    "batch": 256,
    "k1": 5,
    "k2": 5,
    "c": 256,
    "m": 16,
    "d_c": 128,
    "d_m": 128,
    "l": 3,
    "variant": "full",
}


def build_registry():
    builds = []
    # §5.1 reconstruction decoders, one per (c, m) of Table 5.
    for c, m in CM_GRID:
        builds.append(
            model.make_recon(
                f"recon_c{c}_m{m}",
                c,
                m,
                RECON["d_c"],
                RECON["d_m"],
                RECON["d_e"],
                RECON["l"],
                "full",
                RECON["batch"],
                ADAMW_DEFAULT,
            )
        )
    # Light-variant ablation at the Fig-1 default setting.
    builds.append(
        model.make_recon(
            "recon_light_c16_m32",
            16,
            32,
            RECON["d_c"],
            RECON["d_m"],
            RECON["d_e"],
            RECON["l"],
            "light",
            RECON["batch"],
            ADAMW_DEFAULT,
        )
    )
    # Learned-coding baseline (autoencoder) at the Fig-1 default setting.
    builds.append(
        autoenc.make_autoencoder(
            "ae_c16_m32",
            16,
            32,
            RECON["d_c"],
            RECON["d_m"],
            RECON["d_e"],
            RECON["l"],
            RECON["batch"],
            ADAMW_DEFAULT,
        )
    )
    # §5.2 Table 1: 4 GNNs × {coded, nc} × {nodeclf, linkpred}.
    for kind in ("gcn", "sgc", "gin", "sage"):
        for coded in (True, False):
            tag = "coded" if coded else "nc"
            builds.append(
                model.make_nodeclf_fullbatch(
                    f"node_fb_{kind}_{tag}",
                    kind,
                    coded,
                    T1["n"],
                    T1["n_classes"],
                    T1["d_e"],
                    T1["hidden"],
                    T1["c"],
                    T1["m"],
                    T1["d_c"],
                    T1["d_m"],
                    T1["l"],
                    T1["variant"],
                    ADAMW_GNN,
                )
            )
            builds.append(
                model.make_linkpred_fullbatch(
                    f"link_fb_{kind}_{tag}",
                    kind,
                    coded,
                    T1["n"],
                    T1["d_e"],
                    T1["hidden"],
                    T1["e_train"],
                    T1["e_pred"],
                    T1["c"],
                    T1["m"],
                    T1["d_c"],
                    T1["d_m"],
                    T1["l"],
                    T1["variant"],
                    ADAMW_GNN,
                )
            )
    # §4 minibatch GraphSAGE (Table 1 SAGE rows at scale + e2e example).
    for coded in (True, False):
        tag = "coded" if coded else "nc"
        builds.append(
            model.make_sage_minibatch(
                f"sage_mb_{tag}",
                coded,
                MB["n"],
                MB["n_classes"],
                MB["d_e"],
                MB["hidden"],
                MB["batch"],
                MB["k1"],
                MB["k2"],
                MB["c"],
                MB["m"],
                MB["d_c"],
                MB["d_m"],
                MB["l"],
                MB["variant"],
                ADAMW_GNN,
            )
        )
    # §5.3 merchant-category identification (coded only: the paper states
    # the NC baseline cannot run at this scale).
    builds.append(
        model.make_sage_minibatch(
            "merchant",
            True,
            MERCHANT["n"],
            MERCHANT["n_classes"],
            MERCHANT["d_e"],
            MERCHANT["hidden"],
            MERCHANT["batch"],
            MERCHANT["k1"],
            MERCHANT["k2"],
            MERCHANT["c"],
            MERCHANT["m"],
            MERCHANT["d_c"],
            MERCHANT["d_m"],
            MERCHANT["l"],
            MERCHANT["variant"],
            ADAMW_GNN,
        )
    )
    return builds


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


def export_build(build, outdir):
    specs = build["params"]
    n_params = len(specs)
    trainable = [s.trainable for s in specs]
    hyper = build["hyper"]
    train_step = optim.make_train_step(build["train_fn"], trainable, hyper["optim"])

    def flat_train(*args):
        params = args[:n_params]
        ms = args[n_params : 2 * n_params]
        vs = args[2 * n_params : 3 * n_params]
        step = args[3 * n_params]
        batch = args[3 * n_params + 1 :]
        return train_step(params, ms, vs, step, *batch)

    param_structs = [_struct(s.shape, "f32") for s in specs]
    train_batch_structs = [_struct(t.shape, t.dtype) for t in build["train_inputs"]]
    train_args = (
        param_structs + param_structs + param_structs + [_struct((), "f32")] + train_batch_structs
    )
    # keep_unused: never let jit prune parameter arguments from the HLO
    # signature (e.g. the AE's decoder params are unused by its encode-only
    # pred fn) — the rust caller always supplies the full param list.
    train_hlo = to_hlo_text(jax.jit(flat_train, keep_unused=True).lower(*train_args))

    def flat_pred(*args):
        params = args[:n_params]
        batch = args[n_params:]
        return (build["pred_fn"](list(params), list(batch)),)

    pred_batch_structs = [_struct(t.shape, t.dtype) for t in build["pred_inputs"]]
    pred_hlo = to_hlo_text(
        jax.jit(flat_pred, keep_unused=True).lower(*(param_structs + pred_batch_structs))
    )

    name = build["name"]
    with open(os.path.join(outdir, f"{name}_train.hlo.txt"), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(outdir, f"{name}_pred.hlo.txt"), "w") as f:
        f.write(pred_hlo)
    manifest = {
        "name": name,
        "params": [param_json(s) for s in specs],
        "train_inputs": [tensor_json(t) for t in build["train_inputs"]],
        "pred_inputs": [tensor_json(t) for t in build["pred_inputs"]],
        "pred_output": tensor_json(build["pred_output"]),
        "hyper": hyper,
        "train_outputs": "params, ms, vs, loss",
    }
    with open(os.path.join(outdir, f"{name}.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return name


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="export only variants whose name starts with this")
    ap.add_argument("--list", action="store_true", help="list variant names and exit")
    args = ap.parse_args()

    builds = build_registry()
    if args.list:
        for b in builds:
            print(b["name"])
        return
    os.makedirs(args.out, exist_ok=True)
    names = []
    for b in builds:
        if args.only and not b["name"].startswith(args.only):
            continue
        print(f"[aot] lowering {b['name']} ...", flush=True)
        names.append(export_build(b, args.out))
    with open(os.path.join(args.out, "index.json"), "w") as f:
        json.dump({"artifacts": sorted(names)}, f, indent=2)
    print(f"[aot] exported {len(names)} variants to {args.out}")


if __name__ == "__main__":
    main()
