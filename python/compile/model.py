"""L2 task assembly: every AOT-exported executable is built here.

Each ``make_*`` function returns the build dict described in
``specs.py``. ``aot.py`` turns a build into two artifacts:

  <name>_train.hlo.txt   (params, ms, vs, step, *batch) ->
                         (*params', *ms', *vs', loss)
  <name>_pred.hlo.txt    (params, *pred_batch) -> prediction

plus a JSON manifest with parameter specs (order = rust init order),
input/output tensor specs, and hyper-parameters.
"""

import jax.numpy as jnp

from . import decoder, gnn
from .specs import Param, Tensor


def pdict(specs, arrays):
    """Zip canonical param specs with their arrays."""
    return {s.name: a for s, a in zip(specs, arrays)}


# ---------------------------------------------------------------------------
# §5.1 — pre-trained embedding reconstruction
# ---------------------------------------------------------------------------


def make_recon(name, c, m, d_c, d_m, d_e, l, variant, batch, optim):
    """Decoder trained with MSE against pre-trained embeddings
    (§5.1.2). Codes come from any coder (random / hash / learned) — they
    are runtime inputs, so one executable serves all coding schemes."""
    specs = decoder.decoder_param_specs(c, m, d_c, d_m, d_e, l, variant)

    def train_fn(params, batch_in):
        p = pdict(specs, params)
        codes, target = batch_in
        recon = decoder.decode(p, codes, l, variant)
        return jnp.mean((recon - target) ** 2)

    def pred_fn(params, batch_in):
        p = pdict(specs, params)
        (codes,) = batch_in
        return decoder.decode(p, codes, l, variant)

    return {
        "name": name,
        "params": specs,
        "train_inputs": [
            Tensor("codes", (batch, m), "i32"),
            Tensor("target", (batch, d_e), "f32"),
        ],
        "train_fn": train_fn,
        "pred_inputs": [Tensor("codes", (batch, m), "i32")],
        "pred_fn": pred_fn,
        "pred_output": Tensor("embedding", (batch, d_e), "f32"),
        "hyper": {
            "task": "recon",
            "c": c,
            "m": m,
            "d_c": d_c,
            "d_m": d_m,
            "d_e": d_e,
            "l": l,
            "variant": variant,
            "batch": batch,
            "optim": dict(optim),
        },
    }


# ---------------------------------------------------------------------------
# §5.2 — full-batch node classification / link prediction
# ---------------------------------------------------------------------------


def _features(coded, specs, params, batch_in, l, variant):
    """Shared feature front-end: decode codes (compressed path) or slice
    the explicit embedding table (NC baseline)."""
    p = pdict(specs, params)
    if coded:
        codes = batch_in[0]
        x = decoder.decode(p, codes, l, variant)
        rest = batch_in[1:]
    else:
        x = p["embed.table"]
        rest = batch_in
    return p, x, rest


def _embed_specs(coded, n, d_e, c, m, d_c, d_m, l, variant):
    if coded:
        return decoder.decoder_param_specs(c, m, d_c, d_m, d_e, l, variant)
    return [Param("embed.table", (n, d_e), init="normal", std=0.1)]


def make_nodeclf_fullbatch(
    name, kind, coded, n, n_classes, d_e, hidden, c, m, d_c, d_m, l, variant, optim
):
    """Full-batch node classification (ogbn-* analogs): GCN / SGC / GIN /
    SAGE over dense adj, masked CE loss."""
    gnn_specs_fn, gnn_apply, adj_kind = gnn.FULLBATCH[kind]
    specs = (
        _embed_specs(coded, n, d_e, c, m, d_c, d_m, l, variant)
        + gnn_specs_fn(d_e, hidden)
        + gnn.head_param_specs(hidden, n_classes)
    )

    def logits_fn(params, batch_in):
        p, x, rest = _features(coded, specs, params, batch_in, l, variant)
        adj = rest[0]
        h = gnn_apply(p, x, adj)
        return p, gnn.head_apply(p, h), rest

    def train_fn(params, batch_in):
        _p, logits, rest = logits_fn(params, batch_in)
        _adj, labels, mask = rest
        return gnn.masked_cross_entropy(logits, labels, mask)

    def pred_fn(params, batch_in):
        _p, logits, _rest = logits_fn(params, batch_in)
        return logits

    code_in = [Tensor("codes", (n, m), "i32")] if coded else []
    return {
        "name": name,
        "params": specs,
        "train_inputs": code_in
        + [
            Tensor("adj", (n, n), "f32"),
            Tensor("labels", (n,), "i32"),
            Tensor("mask", (n,), "f32"),
        ],
        "train_fn": train_fn,
        "pred_inputs": code_in + [Tensor("adj", (n, n), "f32")],
        "pred_fn": pred_fn,
        "pred_output": Tensor("logits", (n, n_classes), "f32"),
        "hyper": {
            "task": "nodeclf_fullbatch",
            "gnn": kind,
            "adj": adj_kind,
            "coded": coded,
            "n": n,
            "n_classes": n_classes,
            "d_e": d_e,
            "hidden": hidden,
            "c": c,
            "m": m,
            "d_c": d_c,
            "d_m": d_m,
            "l": l,
            "variant": variant,
            "optim": dict(optim),
        },
    }


def make_linkpred_fullbatch(
    name, kind, coded, n, d_e, hidden, e_train, e_pred, c, m, d_c, d_m, l, variant, optim
):
    """Full-batch link prediction (ogbl-* analogs): encoder + dot-product
    scorer, BCE over sampled positive/negative edge batches."""
    gnn_specs_fn, gnn_apply, adj_kind = gnn.FULLBATCH[kind]
    specs = _embed_specs(coded, n, d_e, c, m, d_c, d_m, l, variant) + gnn_specs_fn(d_e, hidden)

    def encode_nodes(params, batch_in):
        p, x, rest = _features(coded, specs, params, batch_in, l, variant)
        adj = rest[0]
        return gnn_apply(p, x, adj), rest

    def train_fn(params, batch_in):
        h, rest = encode_nodes(params, batch_in)
        _adj, pos, neg = rest
        return gnn.bce_link_loss(h, pos, neg)

    def pred_fn(params, batch_in):
        h, rest = encode_nodes(params, batch_in)
        _adj, edges = rest
        return gnn.edge_scores(h, edges)

    code_in = [Tensor("codes", (n, m), "i32")] if coded else []
    return {
        "name": name,
        "params": specs,
        "train_inputs": code_in
        + [
            Tensor("adj", (n, n), "f32"),
            Tensor("pos_edges", (e_train, 2), "i32"),
            Tensor("neg_edges", (e_train, 2), "i32"),
        ],
        "train_fn": train_fn,
        "pred_inputs": code_in
        + [Tensor("adj", (n, n), "f32"), Tensor("edges", (e_pred, 2), "i32")],
        "pred_fn": pred_fn,
        "pred_output": Tensor("scores", (e_pred,), "f32"),
        "hyper": {
            "task": "linkpred_fullbatch",
            "gnn": kind,
            "adj": adj_kind,
            "coded": coded,
            "n": n,
            "d_e": d_e,
            "hidden": hidden,
            "e_train": e_train,
            "e_pred": e_pred,
            "c": c,
            "m": m,
            "d_c": d_c,
            "d_m": d_m,
            "l": l,
            "variant": variant,
            "optim": dict(optim),
        },
    }


# ---------------------------------------------------------------------------
# §4 / §5.3 — minibatch GraphSAGE (industrial path)
# ---------------------------------------------------------------------------


def make_sage_minibatch(
    name, coded, n, n_classes, d_e, hidden, batch, k1, k2, c, m, d_c, d_m, l, variant, optim
):
    """Minibatch GraphSAGE node classification (Figure 4): fan-out-sampled
    two-hop neighborhoods, embeddings from the decoder (compressed) or an
    explicit n×d_e table (NC). Serves Table 1's SAGE rows, the §5.3
    merchant task, and the end-to-end example."""
    specs = (
        _embed_specs(coded, n, d_e, c, m, d_c, d_m, l, variant)
        + gnn.sage_mb_param_specs(d_e, hidden)
        + gnn.head_param_specs(hidden, n_classes)
    )

    def embed(p, ids_or_codes, count):
        if coded:
            return decoder.decode(p, ids_or_codes, l, variant)
        return jnp.take(p["embed.table"], ids_or_codes, axis=0)

    def logits_fn(params, batch_in):
        p = pdict(specs, params)
        xb = embed(p, batch_in[0], batch)  # (B, d_e)
        xh1 = embed(p, batch_in[1], batch * k1).reshape(batch, k1, d_e)
        xh2 = embed(p, batch_in[2], batch * k1 * k2).reshape(batch, k1, k2, d_e)
        h = gnn.sage_mb_apply(p, xb, xh1, xh2)
        return p, gnn.head_apply(p, h)

    def train_fn(params, batch_in):
        _p, logits = logits_fn(params, batch_in)
        labels = batch_in[3]
        return gnn.cross_entropy(logits, labels)

    def pred_fn(params, batch_in):
        _p, logits = logits_fn(params, batch_in)
        return logits

    if coded:
        node_inputs = [
            Tensor("codes_b", (batch, m), "i32"),
            Tensor("codes_h1", (batch * k1, m), "i32"),
            Tensor("codes_h2", (batch * k1 * k2, m), "i32"),
        ]
    else:
        node_inputs = [
            Tensor("ids_b", (batch,), "i32"),
            Tensor("ids_h1", (batch * k1,), "i32"),
            Tensor("ids_h2", (batch * k1 * k2,), "i32"),
        ]
    return {
        "name": name,
        "params": specs,
        "train_inputs": node_inputs + [Tensor("labels", (batch,), "i32")],
        "train_fn": train_fn,
        "pred_inputs": list(node_inputs),
        "pred_fn": pred_fn,
        "pred_output": Tensor("logits", (batch, n_classes), "f32"),
        "hyper": {
            "task": "sage_minibatch",
            "coded": coded,
            "n": n,
            "n_classes": n_classes,
            "d_e": d_e,
            "hidden": hidden,
            "batch": batch,
            "k1": k1,
            "k2": k2,
            "c": c,
            "m": m,
            "d_c": d_c,
            "d_m": d_m,
            "l": l,
            "variant": variant,
            "optim": dict(optim),
        },
    }
