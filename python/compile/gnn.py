"""L2 GNN architectures (paper Section 5.2): GraphSAGE (mean-pool),
GCN (self-loops + skip connection), SGC (k=2), GIN (2 layers).

Full-batch variants take the node feature matrix ``x (n, d)`` and a dense
``adj (n, n)`` whose normalization is chosen by the rust driver
(``sym_norm`` for GCN/SGC, ``row_norm`` for SAGE's mean aggregator,
``raw`` 0/1 for GIN's sum aggregator — recorded per-artifact in the
manifest). The minibatch GraphSAGE variant (Section 4 / Figure 4) takes
fan-out-sampled neighbor features with static shapes.

All parameters follow the specs.Param convention so rust can initialize
them.
"""

import jax
import jax.numpy as jnp

from .specs import Param

# ---------------------------------------------------------------------------
# Full-batch architectures
# ---------------------------------------------------------------------------


def gcn_param_specs(d_in, hidden, prefix="gnn."):
    """2-layer GCN with self-loops (in Â) and linear skip connections."""
    return [
        Param(prefix + "w1", (d_in, hidden)),
        Param(prefix + "s1", (d_in, hidden)),
        Param(prefix + "b1", (hidden,), init="zeros"),
        Param(prefix + "w2", (hidden, hidden)),
        Param(prefix + "s2", (hidden, hidden)),
        Param(prefix + "b2", (hidden,), init="zeros"),
    ]


def gcn_apply(p, x, adj, prefix="gnn."):
    h = jax.nn.relu(adj @ (x @ p[prefix + "w1"]) + x @ p[prefix + "s1"] + p[prefix + "b1"])
    h = jax.nn.relu(adj @ (h @ p[prefix + "w2"]) + h @ p[prefix + "s2"] + p[prefix + "b2"])
    return h


def sgc_param_specs(d_in, hidden, prefix="gnn."):
    """SGC: logits come from a single linear map of Â²x (k=2, no
    nonlinearity — Wu et al. 2019)."""
    return [
        Param(prefix + "w", (d_in, hidden)),
        Param(prefix + "b", (hidden,), init="zeros"),
    ]


def sgc_apply(p, x, adj, prefix="gnn."):
    return (adj @ (adj @ x)) @ p[prefix + "w"] + p[prefix + "b"]


def gin_param_specs(d_in, hidden, prefix="gnn."):
    """2 GIN layers; each layer is MLP((1+eps)·h + Σ_neighbors h) with a
    2-layer MLP (Xu et al. 2018). eps is trainable."""
    return [
        Param(prefix + "eps1", (1,), init="zeros"),
        Param(prefix + "m1a.w", (d_in, hidden)),
        Param(prefix + "m1a.b", (hidden,), init="zeros"),
        Param(prefix + "m1b.w", (hidden, hidden)),
        Param(prefix + "m1b.b", (hidden,), init="zeros"),
        Param(prefix + "eps2", (1,), init="zeros"),
        Param(prefix + "m2a.w", (hidden, hidden)),
        Param(prefix + "m2a.b", (hidden,), init="zeros"),
        Param(prefix + "m2b.w", (hidden, hidden)),
        Param(prefix + "m2b.b", (hidden,), init="zeros"),
    ]


def gin_apply(p, x, adj, prefix="gnn."):
    def gin_layer(h, eps, wa, ba, wb, bb):
        z = (1.0 + eps) * h + adj @ h
        z = jax.nn.relu(z @ wa + ba)
        return jax.nn.relu(z @ wb + bb)

    h = gin_layer(
        x,
        p[prefix + "eps1"][0],
        p[prefix + "m1a.w"],
        p[prefix + "m1a.b"],
        p[prefix + "m1b.w"],
        p[prefix + "m1b.b"],
    )
    return gin_layer(
        h,
        p[prefix + "eps2"][0],
        p[prefix + "m2a.w"],
        p[prefix + "m2a.b"],
        p[prefix + "m2b.w"],
        p[prefix + "m2b.b"],
    )


def sage_fb_param_specs(d_in, hidden, prefix="gnn."):
    """Full-batch GraphSAGE with mean aggregator:
    h' = relu(W · concat(h, row_norm(A)·h))."""
    return [
        Param(prefix + "w1", (2 * d_in, hidden)),
        Param(prefix + "b1", (hidden,), init="zeros"),
        Param(prefix + "w2", (2 * hidden, hidden)),
        Param(prefix + "b2", (hidden,), init="zeros"),
    ]


def sage_fb_apply(p, x, adj, prefix="gnn."):
    h = jnp.concatenate([x, adj @ x], axis=-1)
    h = jax.nn.relu(h @ p[prefix + "w1"] + p[prefix + "b1"])
    h = jnp.concatenate([h, adj @ h], axis=-1)
    return jax.nn.relu(h @ p[prefix + "w2"] + p[prefix + "b2"])


FULLBATCH = {
    "gcn": (gcn_param_specs, gcn_apply, "sym_norm"),
    "sgc": (sgc_param_specs, sgc_apply, "sym_norm"),
    "gin": (gin_param_specs, gin_apply, "raw"),
    "sage": (sage_fb_param_specs, sage_fb_apply, "row_norm"),
}

# ---------------------------------------------------------------------------
# Minibatch GraphSAGE (Section 4 / Figure 4)
# ---------------------------------------------------------------------------


def sage_mb_param_specs(d_in, hidden, prefix="gnn."):
    """2-layer minibatch GraphSAGE with mean pooling over sampled
    neighbors; layers follow Figure 4 (Aggregate → concat → linear →
    ReLU)."""
    return [
        Param(prefix + "w1", (2 * d_in, hidden)),
        Param(prefix + "b1", (hidden,), init="zeros"),
        Param(prefix + "w2", (2 * hidden, hidden)),
        Param(prefix + "b2", (hidden,), init="zeros"),
    ]


def sage_mb_apply(p, x_b, x_h1, x_h2, prefix="gnn."):
    """x_b (B, d), x_h1 (B, K1, d), x_h2 (B, K1, K2, d) -> (B, hidden)."""

    def layer1(node, nbrs):
        # node (..., d); nbrs (..., K, d)
        agg = jnp.mean(nbrs, axis=-2)
        h = jnp.concatenate([node, agg], axis=-1)
        return jax.nn.relu(h @ p[prefix + "w1"] + p[prefix + "b1"])

    l1_h1 = layer1(x_h1, x_h2)  # (B, K1, hidden)
    l1_b = layer1(x_b, x_h1)  # (B, hidden)
    agg2 = jnp.mean(l1_h1, axis=1)
    h = jnp.concatenate([l1_b, agg2], axis=-1)
    return jax.nn.relu(h @ p[prefix + "w2"] + p[prefix + "b2"])


# ---------------------------------------------------------------------------
# Heads / losses
# ---------------------------------------------------------------------------


def head_param_specs(hidden, n_out, prefix="head."):
    return [
        Param(prefix + "w", (hidden, n_out)),
        Param(prefix + "b", (n_out,), init="zeros"),
    ]


def head_apply(p, h, prefix="head."):
    return h @ p[prefix + "w"] + p[prefix + "b"]


def masked_cross_entropy(logits, labels, mask):
    """Mean CE over mask (full-batch node classification)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def edge_scores(h, edges):
    """Dot-product edge scorer: edges (E, 2) int32 -> (E,)."""
    hu = jnp.take(h, edges[:, 0], axis=0)
    hv = jnp.take(h, edges[:, 1], axis=0)
    return jnp.sum(hu * hv, axis=-1)


def bce_link_loss(h, pos_edges, neg_edges):
    pos = edge_scores(h, pos_edges)
    neg = edge_scores(h, neg_edges)
    # Numerically-stable BCE-with-logits.
    pos_loss = jnp.mean(jax.nn.softplus(-pos))
    neg_loss = jnp.mean(jax.nn.softplus(neg))
    return pos_loss + neg_loss
