"""L2 decoder model (paper Section 3.2, Figure 2).

Binary codes arrive already converted to integer vectors ``(B, m)`` (the
rust coordinator owns the bit-packed store). The decoder is:

    gather+sum over m codebooks (L1 Pallas kernel)
      -> [light only] elementwise rescale by trainable W0
      -> l-layer MLP with ReLU between linear layers (L1 Pallas kernels)
      -> embedding (B, d_e)

Variants (paper):
  - *light*: codebooks frozen (``trainable=False`` — the optimizer masks
    their update), W0 trainable;
  - *full*:  codebooks trainable, no W0.
"""

import math

import jax.numpy as jnp

from .kernels import codebook, mlp
from .specs import Param


def decoder_param_specs(c, m, d_c, d_m, d_e, l, variant, prefix="dec."):
    """Canonical parameter list. MLP layout: d_c -> d_m -> … -> d_e with
    ``l`` linear layers (matches the paper's count
    d_c·d_m + (l−2)·d_m² + d_m·d_e)."""
    assert l >= 2, "paper assumes l >= 2"
    assert variant in ("light", "full")
    specs = [
        Param(
            name=prefix + "books",
            shape=(m, c, d_c),
            init="normal",
            # Sum of m rows should land at unit scale.
            std=1.0 / math.sqrt(m),
            trainable=(variant == "full"),
        )
    ]
    if variant == "light":
        specs.append(Param(name=prefix + "w0", shape=(d_c,), init="ones"))
    dims = [d_c] + [d_m] * (l - 1) + [d_e]
    for i in range(l):
        specs.append(Param(name=prefix + f"mlp{i}.w", shape=(dims[i], dims[i + 1])))
        specs.append(Param(name=prefix + f"mlp{i}.b", shape=(dims[i + 1],), init="zeros"))
    return specs


def decode(p, codes, l, variant, prefix="dec."):
    """Run the decoder. ``p`` maps param name -> array; ``codes`` is
    (B, m) int32. Returns (B, d_e)."""
    h = codebook.gather_sum(codes, p[prefix + "books"])
    if variant == "light":
        h = h * p[prefix + "w0"][None, :]
    for i in range(l):
        relu = i < l - 1  # ReLU *between* linear layers only
        h = mlp.linear(h, p[prefix + f"mlp{i}.w"], p[prefix + f"mlp{i}.b"], relu)
    return h


def decode_ref(p, codes, l, variant, prefix="dec."):
    """Pure-jnp decoder (oracle for python/tests)."""
    from .kernels import ref

    h = ref.codebook_gather_sum_ref(codes, p[prefix + "books"])
    if variant == "light":
        h = h * p[prefix + "w0"][None, :]
    for i in range(l):
        relu = i < l - 1
        h = ref.linear_ref(h, p[prefix + f"mlp{i}.w"], p[prefix + f"mlp{i}.b"], relu)
    return h
