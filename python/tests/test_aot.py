"""AOT exporter contract tests: registry coverage, manifest schema, and a
real lowering round-trip (HLO text non-empty, parseable header, manifest
consistent with the build)."""

import json
import os
import tempfile

import pytest

from compile import aot


def test_registry_covers_every_experiment_family():
    names = [b["name"] for b in aot.build_registry()]
    # §5.1 reconstruction decoders for the full Table-5 (c,m) grid.
    for c, m in aot.CM_GRID:
        assert f"recon_c{c}_m{m}" in names
    # Baselines and ablations.
    assert "ae_c16_m32" in names
    assert "recon_light_c16_m32" in names
    # §5.2 Table-1 grid: 4 GNNs × coded/nc × nodeclf/linkpred.
    for kind in ("gcn", "sgc", "gin", "sage"):
        for tag in ("coded", "nc"):
            assert f"node_fb_{kind}_{tag}" in names
            assert f"link_fb_{kind}_{tag}" in names
    # §4 minibatch pipeline + §5.3 merchant task.
    assert "sage_mb_coded" in names and "sage_mb_nc" in names
    assert "merchant" in names
    # No duplicate names (rust loads by name).
    assert len(names) == len(set(names))


def test_registry_shapes_are_consistent():
    for b in aot.build_registry():
        param_names = [p.name for p in b["params"]]
        assert len(param_names) == len(set(param_names)), b["name"]
        for p in b["params"]:
            assert all(dim > 0 for dim in p.shape), (b["name"], p.name)
            assert p.init in ("xavier_uniform", "normal", "zeros", "ones")
        for t in b["train_inputs"] + b["pred_inputs"]:
            assert t.dtype in ("f32", "i32"), (b["name"], t.name)
        hyper = b["hyper"]
        assert "optim" in hyper and "lr" in hyper["optim"], b["name"]


def test_coded_variants_code_inputs_match_cm():
    for b in aot.build_registry():
        h = b["hyper"]
        if h.get("task") == "recon":
            codes = b["train_inputs"][0]
            assert codes.shape == (h["batch"], h["m"])
        if h.get("task") == "sage_minibatch" and h.get("coded"):
            cb, ch1, ch2 = b["train_inputs"][:3]
            assert cb.shape == (h["batch"], h["m"])
            assert ch1.shape == (h["batch"] * h["k1"], h["m"])
            assert ch2.shape == (h["batch"] * h["k1"] * h["k2"], h["m"])


@pytest.mark.parametrize("prefix", ["recon_c16_m32", "ae_c16_m32"])
def test_export_roundtrip(prefix):
    builds = [b for b in aot.build_registry() if b["name"] == prefix]
    assert len(builds) == 1
    with tempfile.TemporaryDirectory() as tmp:
        name = aot.export_build(builds[0], tmp)
        train_path = os.path.join(tmp, f"{name}_train.hlo.txt")
        pred_path = os.path.join(tmp, f"{name}_pred.hlo.txt")
        with open(train_path) as f:
            train_hlo = f.read()
        with open(pred_path) as f:
            pred_hlo = f.read()
        # HLO text sanity: module header + entry computation present.
        assert train_hlo.startswith("HloModule"), train_hlo[:40]
        assert pred_hlo.startswith("HloModule")
        assert "ENTRY" in train_hlo and "ENTRY" in pred_hlo
        with open(os.path.join(tmp, f"{name}.json")) as f:
            manifest = json.load(f)
        assert manifest["name"] == name
        assert len(manifest["params"]) == len(builds[0]["params"])
        # Param order in the manifest must match the build order exactly
        # (it defines the executable argument order for rust).
        for spec, rec in zip(builds[0]["params"], manifest["params"]):
            assert rec["name"] == spec.name
            assert tuple(rec["shape"]) == tuple(spec.shape)


def test_train_arg_count_matches_convention():
    """The exported train step takes 3P+1+B args and returns 3P+1 values
    — the contract rust/src/params relies on."""
    import jax

    builds = [b for b in aot.build_registry() if b["name"] == "recon_c16_m32"]
    b = builds[0]
    n_params = len(b["params"])
    import jax.numpy as jnp

    from compile import optim

    step_fn = optim.make_train_step(
        b["train_fn"], [s.trainable for s in b["params"]], b["hyper"]["optim"]
    )
    params = [jnp.zeros(s.shape, jnp.float32) for s in b["params"]]
    zeros = [jnp.zeros(s.shape, jnp.float32) for s in b["params"]]
    batch = [
        jnp.zeros(t.shape, jnp.int32 if t.dtype == "i32" else jnp.float32)
        for t in b["train_inputs"]
    ]
    out = step_fn(params, zeros, zeros, jnp.float32(0), *batch)
    assert len(out) == 3 * n_params + 1
    assert out[-1].shape == ()
