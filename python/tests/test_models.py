"""L2 model correctness: decoder vs oracle, GNN shapes/losses, AdamW
behaviour, autoencoder training signal, and param-spec consistency with
the paper's formulas."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import autoenc, decoder, gnn, model, optim
from compile.specs import Param


def init_params(specs, key):
    arrays = []
    for i, s in enumerate(specs):
        k = jax.random.fold_in(key, i)
        if s.init == "zeros":
            arrays.append(jnp.zeros(s.shape, jnp.float32))
        elif s.init == "ones":
            arrays.append(jnp.ones(s.shape, jnp.float32))
        elif s.init == "normal":
            arrays.append(s.std * jax.random.normal(k, s.shape, jnp.float32))
        else:  # xavier_uniform
            fan_in, fan_out = s.shape[0], s.shape[-1]
            a = np.sqrt(6.0 / (fan_in + fan_out))
            arrays.append(jax.random.uniform(k, s.shape, jnp.float32, -a, a))
    return arrays


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["light", "full"])
def test_decode_matches_ref(variant):
    c, m, d_c, d_m, d_e, l = 16, 8, 32, 24, 12, 3
    specs = decoder.decoder_param_specs(c, m, d_c, d_m, d_e, l, variant)
    arrays = init_params(specs, jax.random.PRNGKey(0))
    p = {s.name: a for s, a in zip(specs, arrays)}
    codes = jax.random.randint(jax.random.PRNGKey(1), (40, m), 0, c, jnp.int32)
    out = decoder.decode(p, codes, l, variant)
    expect = decoder.decode_ref(p, codes, l, variant)
    assert out.shape == (40, d_e)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_decoder_param_count_matches_paper_formula():
    # Section 3.2: full = m·c·d_c + d_c·d_m + (l-2)·d_m² + d_m·d_e (+biases).
    c, m, d_c, d_m, d_e, l = 256, 16, 512, 512, 64, 3
    specs = decoder.decoder_param_specs(c, m, d_c, d_m, d_e, l, "full")
    weights = sum(
        int(np.prod(s.shape)) for s in specs if s.name.endswith(".w") or s.name == "dec.books"
    )
    assert weights == m * c * d_c + d_c * d_m + (l - 2) * d_m * d_m + d_m * d_e


def test_light_codebooks_frozen_full_trainable():
    for variant, expect in (("light", False), ("full", True)):
        specs = decoder.decoder_param_specs(4, 4, 8, 8, 4, 2, variant)
        books = next(s for s in specs if s.name == "dec.books")
        assert books.trainable is expect
    light = decoder.decoder_param_specs(4, 4, 8, 8, 4, 2, "light")
    assert any(s.name == "dec.w0" for s in light)
    full = decoder.decoder_param_specs(4, 4, 8, 8, 4, 2, "full")
    assert not any(s.name == "dec.w0" for s in full)


# ---------------------------------------------------------------------------
# GNN applies
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["gcn", "sgc", "gin", "sage"])
def test_fullbatch_gnn_shapes(kind):
    n, d, h = 30, 8, 16
    specs_fn, apply_fn, _adj = gnn.FULLBATCH[kind]
    specs = specs_fn(d, h)
    p = {s.name: a for s, a in zip(specs, init_params(specs, jax.random.PRNGKey(2)))}
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d), jnp.float32)
    adj = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (n, n), jnp.float32))
    out = apply_fn(p, x, adj)
    assert out.shape == (n, h)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_sage_mb_shapes_and_permutation_invariance():
    b, k1, k2, d, h = 6, 4, 3, 8, 16
    specs = gnn.sage_mb_param_specs(d, h)
    p = {s.name: a for s, a in zip(specs, init_params(specs, jax.random.PRNGKey(5)))}
    key = jax.random.PRNGKey(6)
    xb = jax.random.normal(key, (b, d))
    xh1 = jax.random.normal(jax.random.fold_in(key, 1), (b, k1, d))
    xh2 = jax.random.normal(jax.random.fold_in(key, 2), (b, k1, k2, d))
    out = gnn.sage_mb_apply(p, xb, xh1, xh2)
    assert out.shape == (b, h)
    # Mean aggregation ⇒ permuting the second-hop neighbors changes nothing.
    perm = jax.random.permutation(jax.random.fold_in(key, 3), k2)
    out_p = gnn.sage_mb_apply(p, xb, xh1, xh2[:, :, perm, :])
    np.testing.assert_allclose(out, out_p, rtol=1e-5, atol=1e-5)


def test_masked_cross_entropy_ignores_masked_rows():
    logits = jnp.array([[10.0, -10.0], [-10.0, 10.0], [0.0, 0.0]])
    labels = jnp.array([0, 1, 0])
    full = gnn.masked_cross_entropy(logits, labels, jnp.array([1.0, 1.0, 0.0]))
    assert float(full) < 1e-3
    # Masking in the bad row raises the loss.
    with_bad = gnn.masked_cross_entropy(logits, labels, jnp.array([1.0, 1.0, 1.0]))
    assert float(with_bad) > float(full)


def test_bce_link_loss_prefers_separated_scores():
    h_good = jnp.array([[1.0, 0.0], [1.0, 0.0], [-1.0, 0.0]])
    pos = jnp.array([[0, 1]], dtype=jnp.int32)
    neg = jnp.array([[0, 2]], dtype=jnp.int32)
    good = gnn.bce_link_loss(h_good, pos, neg)
    bad = gnn.bce_link_loss(h_good, neg, pos)  # swapped: pos scored low
    assert float(good) < float(bad)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    hyper = {"lr": 0.1, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0}
    target = jnp.array([3.0, -2.0])

    def loss_fn(params, batch):
        return jnp.sum((params[0] - target) ** 2)

    step_fn = optim.make_train_step(loss_fn, [True], hyper)
    p = [jnp.zeros(2)]
    m = [jnp.zeros(2)]
    v = [jnp.zeros(2)]
    for t in range(300):
        out = step_fn(p, m, v, jnp.float32(t))
        p, m, v = [out[0]], [out[1]], [out[2]]
    np.testing.assert_allclose(p[0], target, atol=0.05)


def test_adamw_mask_freezes_param():
    hyper = {"lr": 0.1, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.01}

    def loss_fn(params, batch):
        return jnp.sum(params[0] ** 2) + jnp.sum(params[1] ** 2)

    step_fn = optim.make_train_step(loss_fn, [False, True], hyper)
    p = [jnp.ones(3), jnp.ones(3)]
    m = [jnp.zeros(3)] * 2
    v = [jnp.zeros(3)] * 2
    out = step_fn(p, m, v, jnp.float32(0))
    frozen, trained = out[0], out[1]
    np.testing.assert_allclose(frozen, jnp.ones(3))  # untouched, incl. no wd
    assert float(jnp.max(trained)) < 1.0


def test_adamw_weight_decay_decoupled():
    hyper = {"lr": 0.5, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.1}

    def loss_fn(params, batch):
        return jnp.sum(0.0 * params[0])  # zero gradient

    step_fn = optim.make_train_step(loss_fn, [True], hyper)
    p = [jnp.ones(2) * 4.0]
    out = step_fn(p, [jnp.zeros(2)], [jnp.zeros(2)], jnp.float32(0))
    # Pure decay: p' = p − lr·wd·p = 4 · (1 − 0.05).
    np.testing.assert_allclose(out[0], jnp.ones(2) * 4.0 * 0.95, rtol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end training signals (tiny versions of the exported variants)
# ---------------------------------------------------------------------------


def run_steps(build, batches, key, n_steps):
    specs = build["params"]
    params = init_params(specs, key)
    ms = [jnp.zeros(s.shape, jnp.float32) for s in specs]
    vs = [jnp.zeros(s.shape, jnp.float32) for s in specs]
    step_fn = jax.jit(
        optim.make_train_step(
            build["train_fn"], [s.trainable for s in specs], build["hyper"]["optim"]
        )
    )
    n = len(specs)
    losses = []
    for t in range(n_steps):
        out = step_fn(params, ms, vs, jnp.float32(t), *batches)
        params = list(out[:n])
        ms = list(out[n : 2 * n])
        vs = list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    return losses, params


def test_recon_build_trains():
    build = model.make_recon(
        "t", 8, 8, 16, 16, 12, 3, "full", 64,
        {"lr": 3e-3, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0},
    )
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (64, 8), 0, 8, jnp.int32)
    target = jax.random.normal(jax.random.fold_in(key, 1), (64, 12))
    losses, _ = run_steps(build, [codes, target], jax.random.PRNGKey(9), 60)
    assert losses[-1] < losses[0] * 0.7, f"no training signal: {losses[0]} -> {losses[-1]}"


@pytest.mark.parametrize("kind", ["gcn", "sgc", "gin", "sage"])
@pytest.mark.parametrize("coded", [True, False])
def test_nodeclf_fullbatch_trains(kind, coded):
    n, k = 48, 3
    build = model.make_nodeclf_fullbatch(
        "t", kind, coded, n, k, 8, 16, 4, 8, 16, 16, 2, "full",
        {"lr": 1e-2, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0},
    )
    key = jax.random.PRNGKey(4)
    labels = jax.random.randint(key, (n,), 0, k, jnp.int32)
    # Block-diagonal-ish adjacency correlated with labels.
    same = (labels[:, None] == labels[None, :]).astype(jnp.float32)
    adj = same / jnp.maximum(same.sum(1, keepdims=True), 1.0)
    mask = jnp.ones((n,), jnp.float32)
    batch = [adj, labels, mask]
    if coded:
        codes = jax.random.randint(jax.random.fold_in(key, 2), (n, 8), 0, 4, jnp.int32)
        batch = [codes] + batch
    losses, _ = run_steps(build, batch, jax.random.PRNGKey(8), 40)
    assert losses[-1] < losses[0], f"{kind}/coded={coded}: {losses[0]} -> {losses[-1]}"


def test_linkpred_fullbatch_trains():
    n = 40
    build = model.make_linkpred_fullbatch(
        "t", "gcn", True, n, 8, 16, 16, 8, 4, 8, 16, 16, 2, "full",
        {"lr": 1e-2, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0},
    )
    key = jax.random.PRNGKey(5)
    codes = jax.random.randint(key, (n, 8), 0, 4, jnp.int32)
    adj = jnp.eye(n)
    pos = jax.random.randint(jax.random.fold_in(key, 1), (16, 2), 0, n, jnp.int32)
    neg = jax.random.randint(jax.random.fold_in(key, 2), (16, 2), 0, n, jnp.int32)
    losses, _ = run_steps(build, [codes, adj, pos, neg], jax.random.PRNGKey(3), 40)
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("coded", [True, False])
def test_sage_minibatch_trains(coded):
    n, k, b, k1, k2 = 100, 3, 16, 3, 2
    build = model.make_sage_minibatch(
        "t", coded, n, k, 8, 16, b, k1, k2, 4, 8, 16, 16, 2, "full",
        {"lr": 1e-2, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0},
    )
    key = jax.random.PRNGKey(6)
    labels = jax.random.randint(key, (b,), 0, k, jnp.int32)
    if coded:
        mk = lambda i, rows: jax.random.randint(jax.random.fold_in(key, i), (rows, 8), 0, 4, jnp.int32)
        batch = [mk(1, b), mk(2, b * k1), mk(3, b * k1 * k2), labels]
    else:
        mk = lambda i, rows: jax.random.randint(jax.random.fold_in(key, i), (rows,), 0, n, jnp.int32)
        batch = [mk(1, b), mk(2, b * k1), mk(3, b * k1 * k2), labels]
    losses, _ = run_steps(build, batch, jax.random.PRNGKey(2), 40)
    assert losses[-1] < losses[0]


def test_autoencoder_trains_and_encodes():
    build = autoenc.make_autoencoder(
        "t", 4, 6, 16, 16, 12, 2, 32,
        {"lr": 3e-3, "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "weight_decay": 0.0},
    )
    key = jax.random.PRNGKey(7)
    emb = jax.random.normal(key, (32, 12))
    uniform = jax.random.uniform(jax.random.fold_in(key, 1), (32, 6, 4))
    losses, params = run_steps(build, [emb, uniform], jax.random.PRNGKey(1), 80)
    assert losses[-1] < losses[0]
    codes = build["pred_fn"](params, [emb])
    assert codes.shape == (32, 6)
    assert codes.dtype == jnp.int32
    assert int(codes.min()) >= 0 and int(codes.max()) < 4
