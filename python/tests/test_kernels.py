"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the session testing contract; every
assertion is kernel == ref to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import codebook, lshproj, mlp, ref


def rand_codes(key, b, m, c):
    return jax.random.randint(key, (b, m), 0, c, jnp.int32)


# ---------------------------------------------------------------------------
# codebook gather+sum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,m", [(2, 128), (4, 64), (16, 32), (256, 16)])
def test_gather_sum_paper_cm_grid(c, m):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    b, d = 64, 32
    codes = rand_codes(k1, b, m, c)
    books = jax.random.normal(k2, (m, c, d), jnp.float32)
    out = codebook.gather_sum(codes, books)
    expect = ref.codebook_gather_sum_ref(codes, books)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 300),
    m=st.integers(1, 12),
    log_c=st.integers(1, 6),
    d=st.integers(1, 48),
)
def test_gather_sum_hypothesis_shapes(b, m, log_c, d):
    c = 2**log_c
    key = jax.random.PRNGKey(b * 1000 + m * 100 + log_c * 10 + d)
    k1, k2 = jax.random.split(key)
    codes = rand_codes(k1, b, m, c)
    books = jax.random.normal(k2, (m, c, d), jnp.float32)
    out = codebook.gather_sum(codes, books)
    expect = ref.codebook_gather_sum_ref(codes, books)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_gather_sum_both_strategies_agree():
    """one-hot (c<=16) and take (c>16) paths must agree with the oracle."""
    key = jax.random.PRNGKey(7)
    for c in (8, 64):
        k1, k2 = jax.random.split(jax.random.fold_in(key, c))
        codes = rand_codes(k1, 32, 4, c)
        books = jax.random.normal(k2, (4, c, 16), jnp.float32)
        np.testing.assert_allclose(
            codebook.gather_sum(codes, books),
            ref.codebook_gather_sum_ref(codes, books),
            rtol=1e-5,
            atol=1e-5,
        )


def test_gather_sum_grad_matches_ref():
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    b, m, c, d = 40, 6, 16, 24
    codes = rand_codes(k1, b, m, c)
    books = jax.random.normal(k2, (m, c, d), jnp.float32)

    def loss(bk):
        return jnp.sum(jnp.sin(codebook.gather_sum(codes, bk)))

    def loss_ref(bk):
        return jnp.sum(jnp.sin(ref.codebook_gather_sum_ref(codes, bk)))

    g = jax.grad(loss)(books)
    g_ref = jax.grad(loss_ref)(books)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-5)


def test_gather_sum_batch_not_multiple_of_block():
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    b = codebook.DEFAULT_BLOCK_B + 17
    codes = rand_codes(k1, b, 4, 16)
    books = jax.random.normal(k2, (4, 16, 8), jnp.float32)
    out = codebook.gather_sum(codes, books)
    assert out.shape == (b, 8)
    np.testing.assert_allclose(out, ref.codebook_gather_sum_ref(codes, books), rtol=1e-5)


def test_vmem_estimate_within_budget():
    # Largest paper configuration must fit VMEM (~16 MB) comfortably.
    assert codebook.vmem_bytes(128, 16, 256, 512) < 16 * 2**20
    assert codebook.vmem_bytes(128, 128, 2, 512) < 16 * 2**20


# ---------------------------------------------------------------------------
# fused linear
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("relu", [False, True])
def test_linear_matches_ref(relu):
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (200, 48), jnp.float32)
    w = jax.random.normal(k2, (48, 32), jnp.float32)
    b = jax.random.normal(k3, (32,), jnp.float32)
    np.testing.assert_allclose(
        mlp.linear(x, w, b, relu), ref.linear_ref(x, w, b, relu), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 260), d_in=st.integers(1, 64), d_out=st.integers(1, 64))
def test_linear_hypothesis_shapes(b, d_in, d_out):
    key = jax.random.PRNGKey(b * 10000 + d_in * 100 + d_out)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (b, d_in), jnp.float32)
    w = jax.random.normal(k2, (d_in, d_out), jnp.float32)
    bias = jax.random.normal(k3, (d_out,), jnp.float32)
    np.testing.assert_allclose(
        mlp.linear(x, w, bias, True), ref.linear_ref(x, w, bias, True), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("relu", [False, True])
def test_linear_grads_match_jnp(relu):
    key = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (50, 20), jnp.float32)
    w = jax.random.normal(k2, (20, 12), jnp.float32)
    b = jax.random.normal(k3, (12,), jnp.float32)

    def loss(x, w, b):
        return jnp.sum(mlp.linear(x, w, b, relu) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.linear_ref(x, w, b, relu) ** 2)

    gx, gw, gb = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    rx, rw, rb = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb, rb, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# LSH projection
# ---------------------------------------------------------------------------


def test_lsh_project_matches_ref():
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    aux = jax.random.normal(k1, (700, 40), jnp.float32)
    vs = jax.random.normal(k2, (40, 24), jnp.float32)
    np.testing.assert_allclose(
        lshproj.project(aux, vs), ref.lsh_project_ref(aux, vs), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 600), d=st.integers(1, 50), k=st.integers(1, 33))
def test_lsh_project_hypothesis(n, d, k):
    key = jax.random.PRNGKey(n * 1000 + d * 50 + k)
    k1, k2 = jax.random.split(key)
    aux = jax.random.normal(k1, (n, d), jnp.float32)
    vs = jax.random.normal(k2, (d, k), jnp.float32)
    np.testing.assert_allclose(
        lshproj.project(aux, vs), ref.lsh_project_ref(aux, vs), rtol=1e-3, atol=1e-3
    )
