//! Native-backend verification: finite-difference gradient checks on
//! tiny manifests, bit-determinism across thread counts, pipelined ==
//! serial training, and checkpoint save→load→resume equivalence.
//!
//! None of these need artifacts — they are the tier-1 proof that the
//! pure-Rust backward pass and fused AdamW implement the paper's train
//! step correctly.

use hashgnn::cfg::OptimCfg;
use hashgnn::params::ParamStore;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::native::spec::{ReconBuild, SageMbBuild};
use hashgnn::runtime::native::NativeModel;
use hashgnn::runtime::{Manifest, Model, Tensor};
use hashgnn::train::{self, TrainOpts};

// ---------------------------------------------------------------------------
// Batch builders (deterministic)
// ---------------------------------------------------------------------------

fn codes_tensor(rows: usize, m: usize, c: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows * m).map(|_| rng.index(c) as i32).collect();
    Tensor::i32(vec![rows, m], data).unwrap()
}

fn ids_tensor(rows: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows).map(|_| rng.index(n) as i32).collect();
    Tensor::i32(vec![rows], data).unwrap()
}

fn f32_tensor(shape: Vec<usize>, std: f32, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n: usize = shape.iter().product();
    let mut data = vec![0.0f32; n];
    rng.fill_normal_f32(&mut data, 0.0, std);
    Tensor::f32(shape, data).unwrap()
}

fn tiny_clf_build(coded: bool) -> SageMbBuild {
    SageMbBuild {
        name: "t_clf".into(),
        coded,
        link: false,
        n: 30,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn clf_batch(build: &SageMbBuild, seed: u64) -> Vec<Tensor> {
    let (b, k1, k2) = (build.batch, build.k1, build.k2);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x51);
    let labels: Vec<i32> = (0..b).map(|_| rng.index(build.n_classes) as i32).collect();
    let mut batch = if build.coded {
        vec![
            codes_tensor(b, build.m, build.c, seed),
            codes_tensor(b * k1, build.m, build.c, seed ^ 1),
            codes_tensor(b * k1 * k2, build.m, build.c, seed ^ 2),
        ]
    } else {
        vec![
            ids_tensor(b, build.n, seed),
            ids_tensor(b * k1, build.n, seed ^ 1),
            ids_tensor(b * k1 * k2, build.n, seed ^ 2),
        ]
    };
    batch.push(Tensor::i32(vec![b], labels).unwrap());
    batch
}

fn link_batch(build: &SageMbBuild, seed: u64) -> Vec<Tensor> {
    let (b, k1, k2) = (build.batch, build.k1, build.k2);
    let mut batch = Vec::with_capacity(9);
    for set in 0..3u64 {
        batch.push(codes_tensor(b, build.m, build.c, seed ^ (set * 10)));
        batch.push(codes_tensor(b * k1, build.m, build.c, seed ^ (set * 10 + 1)));
        batch.push(codes_tensor(b * k1 * k2, build.m, build.c, seed ^ (set * 10 + 2)));
    }
    batch
}

// ---------------------------------------------------------------------------
// Finite-difference gradient check
// ---------------------------------------------------------------------------

/// Compare analytic gradients against central differences on a sample of
/// coordinates per trainable parameter. ReLU kinks can make individual
/// coordinates disagree, so the assertion is on the agreement rate, which
/// a systematically wrong backward pass (missing term, wrong transpose,
/// dropped mask) cannot reach.
fn grad_check(manifest: &Manifest, batch: &[Tensor], seed: u64) {
    let model = NativeModel::from_manifest(manifest).unwrap();
    let store = ParamStore::init(manifest, seed);
    let (loss0, grads) = model.loss_and_grads(&store.params, batch, 1).unwrap();
    assert!(loss0.is_finite());
    let eps = 1e-2f32;
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xF1D0);
    let mut checked = 0usize;
    let mut agreed = 0usize;
    for (i, spec) in manifest.params.iter().enumerate() {
        if !spec.trainable {
            // Frozen params must report zero gradient.
            assert!(grads[i].iter().all(|&g| g == 0.0), "{}: frozen grad nonzero", spec.name);
            continue;
        }
        let n = spec.n_elements();
        for _ in 0..6.min(n) {
            let j = rng.index(n);
            let loss_at = |delta: f32| -> f32 {
                let mut params = store.params.clone();
                if let Tensor::F32 { data, .. } = &mut params[i] {
                    data[j] += delta;
                }
                model.loss_and_grads(&params, batch, 1).unwrap().0
            };
            let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
            let an = grads[i][j];
            let tol = 3e-3 + 0.08 * an.abs().max(fd.abs());
            checked += 1;
            if (fd - an).abs() <= tol {
                agreed += 1;
            } else {
                eprintln!("  mismatch {}[{j}]: fd={fd:.6} analytic={an:.6}", spec.name);
            }
        }
    }
    assert!(checked >= 12, "gradcheck sampled too few coordinates ({checked})");
    let rate = agreed as f64 / checked as f64;
    assert!(rate >= 0.85, "gradient agreement only {agreed}/{checked}");
}

#[test]
fn gradcheck_recon_decoder_full() {
    let build = ReconBuild {
        name: "t_recon".into(),
        c: 4,
        m: 3,
        d_c: 5,
        d_m: 6,
        d_e: 4,
        l: 2,
        light: false,
        batch: 6,
        optim: OptimCfg::adamw_default(),
    };
    let manifest = build.manifest();
    let batch = vec![
        codes_tensor(6, 3, 4, 9),
        f32_tensor(vec![6, 4], 0.5, 10),
    ];
    grad_check(&manifest, &batch, 3);
}

#[test]
fn gradcheck_recon_decoder_light() {
    let build = ReconBuild {
        name: "t_recon_l".into(),
        c: 4,
        m: 4,
        d_c: 5,
        d_m: 6,
        d_e: 3,
        l: 3,
        light: true,
        batch: 5,
        optim: OptimCfg::adamw_default(),
    };
    let manifest = build.manifest();
    let batch = vec![
        codes_tensor(5, 4, 4, 21),
        f32_tensor(vec![5, 3], 0.5, 22),
    ];
    grad_check(&manifest, &batch, 4);
}

#[test]
fn gradcheck_sage_clf_coded() {
    let build = tiny_clf_build(true);
    let manifest = build.manifest();
    grad_check(&manifest, &clf_batch(&build, 17), 5);
}

#[test]
fn gradcheck_sage_clf_nc_table() {
    let build = tiny_clf_build(false);
    let manifest = build.manifest();
    grad_check(&manifest, &clf_batch(&build, 19), 6);
}

#[test]
fn gradcheck_sage_link_head() {
    let mut build = tiny_clf_build(true);
    build.link = true;
    build.batch = 3;
    let manifest = build.manifest();
    grad_check(&manifest, &link_batch(&build, 23), 7);
}

// ---------------------------------------------------------------------------
// Determinism + training-loop invariants
// ---------------------------------------------------------------------------

/// Train `n_steps` with a per-step-seeded source; returns (losses, store).
/// `step_offset` shifts the batch stream (used by the resume test).
fn run_training(
    model: &Model,
    mut store: ParamStore,
    build: &SageMbBuild,
    n_steps: u64,
    step_offset: u64,
    pipeline: bool,
) -> (Vec<f32>, ParamStore) {
    let b = build.clone();
    let source = move |step: u64| clf_batch(&b, 1000 + step + step_offset);
    let mut opts = TrainOpts::new(n_steps);
    opts.pipeline = pipeline;
    let log = train::train(model, &mut store, source, opts).unwrap();
    (log.losses, store)
}

fn assert_stores_identical(a: &ParamStore, b: &ParamStore) {
    assert_eq!(a.step, b.step);
    assert_eq!(a.params, b.params);
    assert_eq!(a.adam_m, b.adam_m);
    assert_eq!(a.adam_v, b.adam_v);
}

#[test]
fn training_is_bit_identical_across_thread_counts() {
    let build = tiny_clf_build(true);
    let manifest = build.manifest();
    let m1 = Model::native(manifest.clone(), 1).unwrap();
    let m8 = Model::native(manifest.clone(), 8).unwrap();
    let (l1, s1) = run_training(&m1, ParamStore::init(&manifest, 42), &build, 5, 0, false);
    let (l8, s8) = run_training(&m8, ParamStore::init(&manifest, 42), &build, 5, 0, false);
    assert_eq!(l1.len(), 5);
    for (a, b) in l1.iter().zip(&l8) {
        assert_eq!(a.to_bits(), b.to_bits(), "loss curves must match bitwise");
    }
    assert_stores_identical(&s1, &s8);
}

#[test]
fn pipelined_and_serial_training_agree_natively() {
    let build = tiny_clf_build(true);
    let manifest = build.manifest();
    let model = Model::native(manifest.clone(), 2).unwrap();
    let (lp, sp) = run_training(&model, ParamStore::init(&manifest, 7), &build, 6, 0, true);
    let (ls, ss) = run_training(&model, ParamStore::init(&manifest, 7), &build, 6, 0, false);
    assert_eq!(lp, ls, "pipelining must not change the math");
    assert_stores_identical(&sp, &ss);
}

#[test]
fn checkpoint_save_load_resume_matches_continuous_run() {
    let build = tiny_clf_build(true);
    let manifest = build.manifest();
    let model = Model::native(manifest.clone(), 1).unwrap();
    // Continuous: 6 steps.
    let (l_full, s_full) =
        run_training(&model, ParamStore::init(&manifest, 13), &build, 6, 0, false);
    // Split: 3 steps, checkpoint roundtrip, 3 more (batch stream offset 3).
    let (l_a, s_a) = run_training(&model, ParamStore::init(&manifest, 13), &build, 3, 0, false);
    let dir = std::env::temp_dir().join("hashgnn_native_resume");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ckpt.bin");
    s_a.save(&path).unwrap();
    let restored = ParamStore::load(&path).unwrap();
    assert_eq!(restored.step, 3);
    let (l_b, s_b) = run_training(&model, restored, &build, 3, 3, false);
    let mut l_split = l_a;
    l_split.extend(l_b);
    assert_eq!(l_full, l_split, "resumed loss curve must match continuous run");
    assert_stores_identical(&s_full, &s_b);
}

#[test]
fn native_loss_decreases_on_fixed_batch() {
    // The native analog of the HLO-gated recon smoke: repeated steps on
    // one fixed batch must drive the loss down hard.
    let build = ReconBuild {
        name: "t_recon_fit".into(),
        c: 4,
        m: 4,
        d_c: 8,
        d_m: 8,
        d_e: 4,
        l: 2,
        light: false,
        batch: 8,
        // GNN settings (lr = 0.01) so 40 steps visibly overfit the batch.
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let model = Model::native(manifest.clone(), 0).unwrap();
    let mut store = ParamStore::init(&manifest, 1);
    let batch = vec![codes_tensor(8, 4, 4, 2), f32_tensor(vec![8, 4], 0.3, 3)];
    let first = train::run_step(&model, &mut store, &batch).unwrap();
    let mut last = first;
    for _ in 0..40 {
        last = train::run_step(&model, &mut store, &batch).unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    assert!(last < first * 0.5, "loss did not decrease: {first} -> {last}");
    assert_eq!(store.step, 41);
}
