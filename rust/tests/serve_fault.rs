//! Failure paths of the fault-tolerant serving stack, in-process:
//!
//! 1. load-shed responses arrive **in position** with exact counters —
//!    over-long lines (`line_too_long`), blown per-request deadlines
//!    (`deadline`), and admission-queue overflow (`overloaded`) — and
//!    none of them ends the session;
//! 2. graceful shutdown drains the pending cross-batcher (every queued
//!    request is answered before the ack) and reports the drain count;
//! 3. the concurrent TCP front answers N simultaneous connections
//!    byte-identically to N sequential piped sessions, per connection,
//!    in per-connection request order;
//! 4. a [`RemoteRouter`] over worker sockets degrades to *partial*
//!    service when one worker dies mid-flight — dead-shard ids answer
//!    exactly `shard_unavailable`, live-shard ids keep serving
//!    bit-identical bytes — and re-admits the worker after a passing
//!    health probe;
//! 5. corrupted and truncated worker responses (deterministic
//!    [`FaultPlan`] injection) are retried on a fresh connection and
//!    never served — damaged bytes cannot poison the session.
//!
//! Real `kill -9` process tests live in `tests/serve_workers.rs`; these
//! use in-process workers (threads running [`serve_concurrent`]) so
//! every ordinal in a fault plan is exactly reproducible.

use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::spec::{FullBatchBuild, ReconBuild, SageMbBuild};
use hashgnn::ser;
use hashgnn::serve::server::{run_ndjson, serve_concurrent};
use hashgnn::serve::{
    FaultPlan, LoopStats, RemoteCfg, RemoteRouter, ServeOpts, ServeSession, ServerCfg, Serving,
    ServingBundle,
};
use hashgnn::tasks::coding::{make_codes, Aux};

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn opts(threads: usize) -> ServeOpts {
    ServeOpts { threads, cache_capacity: 64, seed: 5, ..Default::default() }
}

fn recon_bundle() -> ServingBundle {
    let m = ReconBuild {
        name: "fp_recon".into(),
        c: 4,
        m: 3,
        d_c: 5,
        d_m: 6,
        d_e: 2,
        l: 2,
        light: false,
        batch: 3,
        optim: OptimCfg::adamw_default(),
    }
    .manifest();
    let store = ParamStore::init(&m, 4);
    let graph = sbm(SbmCfg::new(30, 3, 6.0, 2.0), 11).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 11).unwrap();
    ServingBundle::new(m, &store, Some(codes), vec![], 30).unwrap()
}

fn sage_bundle() -> ServingBundle {
    let build = SageMbBuild {
        name: "fp_mb".into(),
        coded: true,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 9).unwrap();
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

fn fb_bundle() -> ServingBundle {
    let build = FullBatchBuild {
        name: "fp_fb".into(),
        gnn: GnnKind::Gcn,
        coded: true,
        link: false,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 4, 8.0, 2.0), 3).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 5).unwrap(), 3).unwrap();
    let store = ParamStore::init(&manifest, 21);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

/// One piped session; responses as raw lines plus the exact counters.
fn run_session_raw(
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    input: &str,
) -> (Vec<String>, LoopStats) {
    let mut out: Vec<u8> = Vec::new();
    let stats =
        run_ndjson(backend, cfg, Cursor::new(input.as_bytes().to_vec()), &mut out).unwrap();
    (String::from_utf8(out).unwrap().lines().map(String::from).collect(), stats)
}

/// One flush for the whole session (huge fill + huge budget): every
/// response lands at a control-op drain, so counters are deterministic.
fn one_flush_cfg() -> ServerCfg {
    ServerCfg { max_batch: 1000, max_delay: Duration::from_secs(60), ..Default::default() }
}

// ---------------------------------------------------------------------------
// 1. Load-shed responses in position, exact counters
// ---------------------------------------------------------------------------

#[test]
fn oversized_line_is_shed_in_position_and_session_survives() {
    let mut session = ServeSession::new(recon_bundle(), opts(1)).unwrap();
    let cfg = ServerCfg { max_line_bytes: 64, ..one_flush_cfg() };
    let input = format!(
        "{}\n{}\n{}\n{}\n",
        r#"{"op": "embed", "nodes": [1]}"#,
        "x".repeat(200),
        r#"{"op": "embed", "nodes": [3]}"#,
        r#"{"op": "shutdown"}"#,
    );
    let (lines, stats) = run_session_raw(&mut session, &cfg, &input);
    assert_eq!(lines.len(), 4, "one response per input line: {lines:?}");
    let l0 = ser::parse(&lines[0]).unwrap();
    assert!(l0.get("embeddings").is_ok(), "line before the oversized one serves normally");
    let l1 = ser::parse(&lines[1]).unwrap();
    assert_eq!(l1.get("error").unwrap().as_str().unwrap(), "line_too_long");
    let l2 = ser::parse(&lines[2]).unwrap();
    assert!(l2.get("embeddings").is_ok(), "line after the oversized one serves normally");
    let l3 = ser::parse(&lines[3]).unwrap();
    assert!(l3.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(stats.requests, 4);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.responses, 3);
    assert_eq!(stats.drained, 3, "both embeds and the shed answer at the shutdown drain");
}

#[test]
fn zero_deadline_sheds_every_data_request_with_exact_counters() {
    let mut session = ServeSession::new(recon_bundle(), opts(1)).unwrap();
    let cfg = ServerCfg { deadline: Some(Duration::ZERO), ..one_flush_cfg() };
    let input = concat!(
        "{\"op\": \"embed\", \"nodes\": [1, 2]}\n",
        "{\"op\": \"score\", \"edges\": [[0, 1]]}\n",
        "{\"op\": \"stats\"}\n",
        "{\"op\": \"shutdown\"}\n",
    );
    let (lines, stats) = run_session_raw(&mut session, &cfg, input);
    assert_eq!(lines.len(), 4);
    for line in &lines[..2] {
        let v = ser::parse(line).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "deadline", "{line}");
    }
    let s = ser::parse(&lines[2]).unwrap();
    assert_eq!(s.get("shed_deadline").unwrap().as_usize().unwrap(), 2);
    assert_eq!(s.get("errors").unwrap().as_usize().unwrap(), 2);
    assert_eq!(s.get("drained_requests").unwrap().as_usize().unwrap(), 2);
    assert_eq!(stats.shed_deadline, 2);
    assert_eq!(stats.errors, 2);
    assert_eq!(stats.responses, 2, "stats + shutdown still answer");
}

#[test]
fn queue_overflow_sheds_overloaded_in_position() {
    let mut session = ServeSession::new(recon_bundle(), opts(1)).unwrap();
    let cfg = ServerCfg { queue_cap: 2, ..one_flush_cfg() };
    let input = concat!(
        "{\"op\": \"embed\", \"nodes\": [1]}\n",
        "{\"op\": \"embed\", \"nodes\": [2]}\n",
        "{\"op\": \"embed\", \"nodes\": [3]}\n",
        "{\"op\": \"embed\", \"nodes\": [4]}\n",
        "{\"op\": \"stats\"}\n",
        "{\"op\": \"shutdown\"}\n",
    );
    let (lines, stats) = run_session_raw(&mut session, &cfg, input);
    assert_eq!(lines.len(), 6);
    assert!(ser::parse(&lines[0]).unwrap().get("embeddings").is_ok());
    assert!(ser::parse(&lines[1]).unwrap().get("embeddings").is_ok());
    for line in &lines[2..4] {
        let v = ser::parse(line).unwrap();
        assert_eq!(
            v.get("error").unwrap().as_str().unwrap(),
            "overloaded",
            "requests over the cap shed in their own position: {line}"
        );
    }
    let s = ser::parse(&lines[4]).unwrap();
    assert_eq!(s.get("shed_overload").unwrap().as_usize().unwrap(), 2);
    assert_eq!(
        s.get("queue_depth").unwrap().as_usize().unwrap(),
        4,
        "stats snapshots the depth before its own drain"
    );
    assert_eq!(stats.shed_overload, 2);
    assert_eq!(stats.errors, 2);
    assert_eq!(stats.responses, 4, "two embeds + stats + shutdown");
}

// ---------------------------------------------------------------------------
// 2. Graceful shutdown drains
// ---------------------------------------------------------------------------

#[test]
fn shutdown_answers_pending_requests_before_the_ack() {
    let mut session = ServeSession::new(recon_bundle(), opts(1)).unwrap();
    let cfg = one_flush_cfg();
    let input = "{\"op\": \"embed\", \"nodes\": [1, 2]}\n{\"op\": \"shutdown\"}\n";
    let (lines, stats) = run_session_raw(&mut session, &cfg, input);
    assert_eq!(lines.len(), 2);
    assert!(
        ser::parse(&lines[0]).unwrap().get("embeddings").is_ok(),
        "the queued embed answers BEFORE the ack"
    );
    assert!(ser::parse(&lines[1]).unwrap().get("ok").unwrap().as_bool().unwrap());
    assert_eq!(stats.drained, 1);
    assert_eq!(stats.batch.drain_flushes, 1);
    assert_eq!(stats.responses, 2);
    assert_eq!(stats.errors, 0);
}

// ---------------------------------------------------------------------------
// 3. Concurrent front vs sequential sessions: byte parity
// ---------------------------------------------------------------------------

#[test]
fn concurrent_connections_answer_byte_identically_to_sequential_sessions() {
    let bundle = fb_bundle();
    let cfg = ServerCfg {
        max_batch: 1000,
        max_delay: Duration::from_millis(5),
        ..Default::default()
    };
    let inputs: Vec<String> = vec![
        concat!(
            "{\"op\": \"embed\", \"nodes\": [0, 1, 2], \"id\": \"c1a\"}\n",
            "{\"op\": \"score\", \"edges\": [[0, 1], [2, 3]]}\n",
            "{\"op\": \"embed\", \"nodes\": [3]}\n",
        )
        .to_string(),
        concat!(
            "{\"op\": \"embed\", \"nodes\": [2, 3, 4]}\n",
            "{\"op\": \"classes\", \"nodes\": [5, 0]}\n",
            "{\"op\": \"score\", \"edges\": [[4, 5]]}\n",
        )
        .to_string(),
        concat!(
            "{\"op\": \"embed\", \"nodes\": [0, 5, 9]}\n",
            "{\"op\": \"embed\", \"nodes\": [59, 7]}\n",
        )
        .to_string(),
    ];
    // Reference: each client's stream through a fresh sequential session.
    let mut expected = Vec::new();
    for inp in &inputs {
        let mut s = ServeSession::new(bundle.clone(), opts(1)).unwrap();
        let (lines, _) = run_session_raw(&mut s, &cfg, inp);
        expected.push(lines);
    }
    let n_data: u64 = inputs.iter().map(|i| i.lines().count() as u64).sum();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // Clients race each other; each reads exactly one response per line.
    let handles: Vec<_> = inputs
        .iter()
        .cloned()
        .map(|inp| {
            std::thread::spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                sock.write_all(inp.as_bytes()).unwrap();
                sock.flush().unwrap();
                let n = inp.lines().count();
                let mut r = BufReader::new(sock);
                let mut got = Vec::new();
                for _ in 0..n {
                    let mut line = String::new();
                    assert!(r.read_line(&mut line).unwrap() > 0, "server closed early");
                    got.push(line.trim_end().to_string());
                }
                got
            })
        })
        .collect();
    // Coordinator: wait for every client, then shut the server down.
    let coord = std::thread::spawn(move || {
        let results: Vec<Vec<String>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
        let mut ack = String::new();
        BufReader::new(sock).read_line(&mut ack).unwrap();
        (results, ack)
    });
    // The engine (and the backend) stay on THIS thread: no Send bound.
    let mut session = ServeSession::new(bundle, opts(1)).unwrap();
    let stats = serve_concurrent(listener, &mut session, &cfg, 0, None).unwrap();
    let (results, ack) = coord.join().unwrap();

    assert!(ser::parse(ack.trim()).unwrap().get("ok").unwrap().as_bool().unwrap());
    for (got, want) in results.iter().zip(&expected) {
        assert_eq!(got, want, "concurrent responses must be byte-identical to sequential");
    }
    assert_eq!(stats.requests, n_data + 1, "every data line + the shutdown");
    assert_eq!(stats.responses, n_data + 1);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shed_overload, 0);
    assert_eq!(stats.dropped_conns, 0);
}

// ---------------------------------------------------------------------------
// 4. Remote router: partial service + health-check re-admission
// ---------------------------------------------------------------------------

fn spawn_worker(
    listener: TcpListener,
    bundle: ServingBundle,
    fault: Option<FaultPlan>,
) -> std::thread::JoinHandle<LoopStats> {
    let cfg = ServerCfg {
        max_batch: 1000,
        max_delay: Duration::from_millis(2),
        ..Default::default()
    };
    std::thread::spawn(move || {
        let mut session = ServeSession::new(bundle, opts(1)).unwrap();
        serve_concurrent(listener, &mut session, &cfg, 0, fault).unwrap()
    })
}

fn shutdown_worker(addr: std::net::SocketAddr) {
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    let mut ack = String::new();
    let _ = BufReader::new(sock).read_line(&mut ack);
}

fn worker_up(router: &RemoteRouter, i: usize) -> bool {
    router.stats_json().get("workers").unwrap().as_arr().unwrap()[i]
        .get("up")
        .unwrap()
        .as_bool()
        .unwrap()
}

#[test]
fn dead_worker_degrades_to_partial_service_and_readmits_after_health_check() {
    let bundle = sage_bundle();
    let shards = bundle.split_shards(2).unwrap(); // [0, 30) and [30, 60)
    let la = TcpListener::bind("127.0.0.1:0").unwrap();
    let lb = TcpListener::bind("127.0.0.1:0").unwrap();
    let (aa, ab) = (la.local_addr().unwrap(), lb.local_addr().unwrap());
    let wa = spawn_worker(la, shards[0].clone(), None);
    // Worker B's response ordinals: #1 handshake, #2 first embed; then
    // #3/#4 are DROPPED — with retries=1 that exhausts the budget and
    // marks B down. #5 (the health probe) and later answer normally.
    let wb = spawn_worker(
        lb,
        shards[1].clone(),
        Some(FaultPlan::parse("drop:3,drop:4").unwrap()),
    );
    let rcfg = RemoteCfg {
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_millis(400),
        retries: 1,
        backoff: Duration::from_millis(10),
        health_every: Duration::ZERO, // re-probe on every routing decision
        max_line_bytes: 1 << 20,
        ..Default::default()
    };
    let mut router = RemoteRouter::connect(&[aa.to_string(), ab.to_string()], rcfg).unwrap();
    let mut local = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    let ids: Vec<u32> = vec![0, 29, 30, 59, 15, 45];
    let d = router.embed_dim();

    // Full fleet: served bytes are identical to the local session —
    // f32 → shortest-round-trip text → f32 is exact.
    let want = local.embed_nodes(&ids).unwrap();
    let got = router.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&got, &want), "remote bytes must equal local bytes");

    // B drops both attempts: partial service. Dead-shard ids answer
    // exactly `shard_unavailable`; live-shard rows stay bit-identical.
    let part = router.embed_nodes_partial(&ids).unwrap();
    for (k, &id) in ids.iter().enumerate() {
        if id < 30 {
            assert!(!part.failed.contains_key(&id), "live shard must keep serving id {id}");
            assert!(bits_equal(&part.rows[k * d..(k + 1) * d], &want[k * d..(k + 1) * d]));
        } else {
            assert_eq!(part.failed.get(&id).unwrap(), "shard_unavailable");
        }
    }
    assert!(!worker_up(&router, 1), "exhausted retries must mark the worker down");
    assert!(worker_up(&router, 0));

    // Next call probes B (health_every = 0), the probe answers, and the
    // worker is re-admitted: full service, still bit-identical.
    let again = router.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&again, &want), "re-admitted worker must serve the same bytes");
    assert!(worker_up(&router, 1), "a passing health check re-admits the worker");

    // Classes route worker-side (the head lives with the parameters).
    let (_, remote_classes) = router.classes_for_ids(&ids).unwrap();
    let (_, local_classes) = local.predict_classes(&ids).unwrap();
    assert_eq!(remote_classes, local_classes);

    shutdown_worker(aa);
    shutdown_worker(ab);
    wa.join().unwrap();
    wb.join().unwrap();
}

// ---------------------------------------------------------------------------
// 5. Damaged responses are retried, never served
// ---------------------------------------------------------------------------

#[test]
fn corrupt_and_truncated_responses_are_retried_on_a_fresh_connection() {
    let bundle = sage_bundle();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // #1 handshake; #2 corrupted (unparseable JSON, framing intact);
    // #4 truncated (half a line, no newline — the client read times out).
    let w = spawn_worker(
        listener,
        bundle.clone(),
        Some(FaultPlan::parse("corrupt:2,truncate:4").unwrap()),
    );
    let rcfg = RemoteCfg {
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_millis(400),
        retries: 2,
        backoff: Duration::from_millis(5),
        health_every: Duration::ZERO,
        max_line_bytes: 1 << 20,
        ..Default::default()
    };
    let mut router = RemoteRouter::connect(&[addr.to_string()], rcfg).unwrap();
    let mut local = ServeSession::new(bundle, opts(1)).unwrap();
    let ids: Vec<u32> = vec![3, 7, 3, 59];
    let want = local.embed_nodes(&ids).unwrap();

    // Corrupt response #2 fails the parse, tears down the pooled
    // connection, and the retry (#3, clean) serves exact bytes.
    let got = router.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&got, &want), "a corrupted response must never reach the caller");

    // Truncated response #4 has no newline: the bounded read times out,
    // the retry (#5, clean) serves exact bytes on a fresh connection.
    let got2 = router.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&got2, &want), "a torn response must never reach the caller");

    assert!(worker_up(&router, 0), "transient damage must not permanently bench the worker");
    shutdown_worker(addr);
    w.join().unwrap();
}
