//! Property-based tests over coordinator invariants (session contract:
//! proptest-style checks on routing, batching, state). Uses the in-repo
//! `testing` harness (no proptest in the offline crate set); every failure
//! reports a replayable case seed.

use hashgnn::cfg::{CodingCfg, EncodeCfg};
use hashgnn::codes::{random_codes, CodeTable};
use hashgnn::graph::generate::{barabasi_albert, sbm, SbmCfg};
use hashgnn::graph::{split_nodes, NeighborSampler};
use hashgnn::lsh::{self, median_in_place, Threshold};
use hashgnn::rng::Rng;
use hashgnn::ser;
use hashgnn::testing::{check, PropConfig};

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, seed: 0xDEC0DE }
}

#[test]
fn prop_code_roundtrip_bits_ints() {
    // For any (c, m) and any random codes: int → bits → int is identity.
    check("code roundtrip", cfg(60), |rng| {
        let log_c = 1 + rng.index(8);
        let c = 1usize << log_c;
        let m = 1 + rng.index(32);
        let n = 1 + rng.index(40);
        let coding = CodingCfg::new(c, m).map_err(|e| e.to_string())?;
        let codes: Vec<i32> = (0..n * m).map(|_| rng.index(c) as i32).collect();
        let table = CodeTable::from_int_codes(&codes, n, coding).map_err(|e| e.to_string())?;
        for row in 0..n {
            let got = table.int_code(row);
            if got != codes[row * m..(row + 1) * m] {
                return Err(format!("row {row}: {got:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_median_splits_half() {
    // The LSH threshold invariant: strictly-above count ≤ n/2 and
    // at least one element is ≤ the median.
    check("median split", cfg(100), |rng| {
        let n = 1 + rng.index(400);
        let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 5.0) as f32).collect();
        let mut buf = xs.clone();
        let t = median_in_place(&mut buf);
        let above = xs.iter().filter(|&&x| x > t).count();
        if above > n / 2 {
            return Err(format!("n={n} above={above}"));
        }
        if !xs.iter().any(|&x| x <= t) {
            return Err("median not attained".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lsh_bit_balance() {
    // Every LSH bit column (median threshold) selects ≤ half the rows.
    check("lsh bit balance", cfg(8), |rng| {
        let n = 50 + rng.index(300);
        let d = 4 + rng.index(24);
        let mut data = vec![0.0f32; n * d];
        let mean = (rng.f64() * 4.0 - 2.0) as f32;
        rng.fill_normal_f32(&mut data, mean, 1.0);
        let aux = lsh::DenseAux::new(&data, n, d);
        let coding = CodingCfg::new(2, 16).unwrap();
        let t = lsh::encode(&aux, coding, Threshold::Median, rng.next_u64())
            .map_err(|e| e.to_string())?;
        for bit in 0..16 {
            let ones = (0..n).filter(|&r| t.bits.get(r, bit)).count();
            if ones > n / 2 {
                return Err(format!("bit {bit}: {ones}/{n} ones"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_bit_identical_across_execution_plans() {
    // The parallel engine's contract: for any aux source, shape, seed and
    // threshold, encode output never depends on (threads, block_bits),
    // and the blocked/parallel paths equal the bit-by-bit reference.
    check("encode plan independence", cfg(6), |rng| {
        let n = 20 + rng.index(180);
        let d = 3 + rng.index(20);
        let mut data = vec![0.0f32; n * d];
        rng.fill_normal_f32(&mut data, (rng.f64() - 0.5) as f32, 1.0);
        let seed = rng.next_u64();
        let coding = CodingCfg::new(4, 1 + rng.index(40)).map_err(|e| e.to_string())?;
        let threshold =
            if rng.bool_with(0.5) { lsh::Threshold::Median } else { lsh::Threshold::Zero };

        let dense = lsh::DenseAux::new(&data, n, d);
        let graph = barabasi_albert(n, 1 + rng.index(3), rng.next_u64()).map_err(|e| e.to_string())?;
        let ref_dense = lsh::encode(&dense, coding, threshold, seed).map_err(|e| e.to_string())?;
        let ref_csr =
            lsh::encode(graph.adj(), coding, threshold, seed).map_err(|e| e.to_string())?;
        for threads in [1usize, 2, 8] {
            for block_bits in [1usize, 8, 64] {
                let plan = EncodeCfg::new(threads, block_bits);
                let got = lsh::encode_with(&dense, coding, threshold, seed, plan)
                    .map_err(|e| e.to_string())?;
                if got.bits != ref_dense.bits {
                    return Err(format!("dense diverged: threads={threads} block={block_bits}"));
                }
                let got = lsh::encode_with(graph.adj(), coding, threshold, seed, plan)
                    .map_err(|e| e.to_string())?;
                if got.bits != ref_csr.bits {
                    return Err(format!("csr diverged: threads={threads} block={block_bits}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sampler_stays_in_neighborhood() {
    // Batching invariant: every sampled hop-1 node is a neighbor (or the
    // node itself when isolated); shapes are exactly (B·k1), (B·k1·k2).
    check("sampler neighborhood", cfg(20), |rng| {
        let n = 20 + rng.index(200);
        let g = barabasi_albert(n, 1 + rng.index(3), rng.next_u64()).map_err(|e| e.to_string())?;
        let k1 = 1 + rng.index(6);
        let k2 = 1 + rng.index(4);
        let b = 1 + rng.index(16);
        let batch: Vec<u32> = (0..b).map(|_| rng.index(n) as u32).collect();
        let sampler = NeighborSampler::new(&g, k1, k2);
        let s = sampler.sample_seeded(&batch, rng.next_u64());
        if s.hop1.len() != b * k1 || s.hop2.len() != b * k1 * k2 {
            return Err("shape mismatch".into());
        }
        for (i, &u) in batch.iter().enumerate() {
            for j in 0..k1 {
                let v = s.hop1[i * k1 + j];
                if v != u && !g.neighbors(u as usize).contains(&v) {
                    return Err(format!("{v} not a neighbor of {u}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_split_is_partition() {
    // State invariant: splits partition the node set for any fractions.
    check("split partition", cfg(60), |rng| {
        let n = 1 + rng.index(500);
        let ft = rng.f64() * 0.8;
        let fv = rng.f64() * (1.0 - ft);
        let s = split_nodes(n, ft, fv, rng.next_u64()).map_err(|e| e.to_string())?;
        if s.total() != n {
            return Err(format!("total {} != {n}", s.total()));
        }
        let mut all: Vec<u32> = s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        all.sort_unstable();
        all.dedup();
        if all.len() != n {
            return Err("overlap between splits".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    // Serialization invariant: parse(render(v)) == v for random JSON.
    fn random_json(rng: &mut hashgnn::rng::Xoshiro256pp, depth: usize) -> ser::Json {
        let pick = if depth == 0 { rng.index(4) } else { rng.index(6) };
        match pick {
            0 => ser::Json::Null,
            1 => ser::Json::Bool(rng.bool_with(0.5)),
            2 => ser::Json::Num((rng.index(2_000_001) as f64 - 1e6) / 64.0),
            3 => {
                let len = rng.index(12);
                let s: String = (0..len)
                    .map(|_| char::from_u32(32 + rng.index(90) as u32).unwrap())
                    .collect();
                ser::Json::Str(s)
            }
            4 => ser::Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => ser::Json::Obj(
                (0..rng.index(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", cfg(150), |rng| {
        let v = random_json(rng, 3);
        let s = ser::to_string_pretty(&v);
        let back = ser::parse(&s).map_err(|e| format!("{e}\n{s}"))?;
        if back != v {
            return Err(format!("roundtrip mismatch:\n{s}"));
        }
        Ok(())
    });
}

#[test]
fn prop_random_coding_is_reproducible_and_seed_sensitive() {
    check("random coding determinism", cfg(30), |rng| {
        let coding = CodingCfg::new(4, 8).unwrap();
        let n = 1 + rng.index(100);
        let seed = rng.next_u64();
        let a = random_codes(n, coding, seed);
        let b = random_codes(n, coding, seed);
        if a.bits != b.bits {
            return Err("same seed differs".into());
        }
        let c = random_codes(n, coding, seed ^ 1);
        if n > 4 && a.bits == c.bits {
            return Err("different seed identical".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sbm_labels_within_range() {
    check("sbm labels", cfg(10), |rng| {
        let k = 2 + rng.index(6);
        let n = k * (10 + rng.index(40));
        let g = sbm(SbmCfg::new(n, k, 8.0, 2.0), rng.next_u64()).map_err(|e| e.to_string())?;
        let labels = g.labels().ok_or("missing labels")?;
        if labels.iter().any(|&l| l as usize >= k) {
            return Err("label out of range".into());
        }
        Ok(())
    });
}
