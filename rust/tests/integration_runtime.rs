//! Integration: AOT artifacts → PJRT compile → execute → train.
//!
//! Requires `make artifacts` to have run (skips cleanly otherwise so
//! `cargo test` stays green on a fresh checkout).

use std::sync::Arc;

use hashgnn::cfg::CodingCfg;
use hashgnn::codes::random_codes;
use hashgnn::embed::gaussian_mixture;
use hashgnn::params::ParamStore;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::{Engine, Tensor};
use hashgnn::tasks::recon;
use hashgnn::train::{self, TrainOpts};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("index.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn engine_loads_and_reports_platform() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    assert_eq!(engine.platform().to_lowercase(), "cpu");
}

#[test]
fn recon_train_step_runs_and_loss_decreases() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let b = model.manifest.hyper_usize("batch").unwrap();
    let m = model.manifest.hyper_usize("m").unwrap();
    let d_e = model.manifest.hyper_usize("d_e").unwrap();

    let mut store = ParamStore::init(&model.manifest, 1);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    // Fixed batch: loss on the same batch must drop under repeated steps.
    let codes: Vec<i32> = (0..b * m).map(|_| rng.index(16) as i32).collect();
    let mut target = vec![0.0f32; b * d_e];
    rng.fill_normal_f32(&mut target, 0.0, 0.3);
    let batch = vec![
        Tensor::i32(vec![b, m], codes).unwrap(),
        Tensor::f32(vec![b, d_e], target).unwrap(),
    ];
    let first = train::run_step(&model, &mut store, &batch).unwrap();
    assert!(first.is_finite());
    let mut last = first;
    for _ in 0..20 {
        last = train::run_step(&model, &mut store, &batch).unwrap();
    }
    assert!(last < first * 0.8, "loss did not decrease: {first} -> {last}");
    assert_eq!(store.step, 21);
}

#[test]
fn recon_predict_shape_matches_manifest() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let b = model.manifest.hyper_usize("batch").unwrap();
    let m = model.manifest.hyper_usize("m").unwrap();
    let store = ParamStore::init(&model.manifest, 1);
    let codes = Tensor::i32(vec![b, m], vec![0i32; b * m]).unwrap();
    let out = train::predict(&model, &store, &[codes]).unwrap();
    assert_eq!(out.shape(), model.manifest.pred_output.shape.as_slice());
}

#[test]
fn wrong_batch_shape_is_rejected_before_execution() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let mut store = ParamStore::init(&model.manifest, 1);
    let bad = vec![Tensor::i32(vec![3, 3], vec![0; 9]).unwrap()];
    let err = train::run_step(&model, &mut store, &bad).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("manifest"), "{msg}");
}

#[test]
fn pipelined_training_reduces_recon_loss_on_real_embeddings() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let coding = CodingCfg::new(16, 32).unwrap();
    let set = gaussian_mixture(2000, 128, 8, 0.25, 5);
    let codes = random_codes(2000, coding, 7);
    let (_store, log) = recon::train_decoder(&model, &codes, &set, 3, 11).unwrap();
    let early: f32 = log.losses[..3].iter().sum::<f32>() / 3.0;
    let late = log.tail_mean(3);
    assert!(late < early, "no training signal: {early} -> {late}");
}

#[test]
fn pipelined_and_serial_training_agree() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let b = model.manifest.hyper_usize("batch").unwrap();
    let m = model.manifest.hyper_usize("m").unwrap();
    let d_e = model.manifest.hyper_usize("d_e").unwrap();

    let make_source = move || {
        move |step: u64| {
            let mut rng = Xoshiro256pp::seed_from_u64(100 + step);
            let codes: Vec<i32> = (0..b * m).map(|_| rng.index(16) as i32).collect();
            let mut target = vec![0.0f32; b * d_e];
            rng.fill_normal_f32(&mut target, 0.0, 0.3);
            vec![
                Tensor::i32(vec![b, m], codes).unwrap(),
                Tensor::f32(vec![b, d_e], target).unwrap(),
            ]
        }
    };
    let mut s1 = ParamStore::init(&model.manifest, 3);
    let mut s2 = ParamStore::init(&model.manifest, 3);
    let mut o_pipe = TrainOpts::new(6);
    o_pipe.pipeline = true;
    let mut o_serial = TrainOpts::new(6);
    o_serial.pipeline = false;
    let l1 = train::train(&model, &mut s1, make_source(), o_pipe).unwrap();
    let l2 = train::train(&model, &mut s2, make_source(), o_serial).unwrap();
    assert_eq!(l1.losses, l2.losses, "pipelining must not change the math");
    assert_eq!(s1.params, s2.params);
}

#[test]
fn sage_minibatch_artifact_end_to_end_smoke() {
    require_artifacts!();
    use hashgnn::graph::generate::{sbm, SbmCfg};
    use hashgnn::tasks::sage::{self, Features, SageTask};

    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("sage_mb_coded").unwrap();
    let n = model.manifest.hyper_usize("n").unwrap();
    let c = model.manifest.hyper_usize("c").unwrap();
    let m = model.manifest.hyper_usize("m").unwrap();
    let g = Arc::new(sbm(SbmCfg::new(n, 8, 12.0, 2.0), 3).unwrap());
    let labels = Arc::new(g.labels().unwrap().to_vec());
    let coding = CodingCfg::new(c, m).unwrap();
    let codes =
        hashgnn::lsh::encode(g.adj(), coding, hashgnn::lsh::Threshold::Median, 5).unwrap();
    let task = SageTask {
        graph: g.clone(),
        labels,
        features: Features::Codes(Arc::new(codes)),
        train_nodes: Arc::new((0..n as u32).collect()),
    };
    let batcher = sage::SageBatcher::new(task, &model, 9).unwrap();
    let mut store = ParamStore::init(&model.manifest, 1);
    let opts = TrainOpts::new(3);
    let log = train::train(&model, &mut store, batcher, opts).unwrap();
    assert_eq!(log.losses.len(), 3);
    assert!(log.losses.iter().all(|l| l.is_finite()));
}
