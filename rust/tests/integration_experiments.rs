//! Integration: experiment drivers against real artifacts, scaled to
//! test-suite budgets. Skips cleanly when artifacts are missing.

use hashgnn::cfg::{Coder, CodingCfg, GnnKind};
use hashgnn::embed::gaussian_mixture;
use hashgnn::runtime::Engine;
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::tasks::{linkpred, recon, T1Dataset};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_dir().join("index.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn recon_hash_beats_random_on_clustered_embeddings() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let coding = CodingCfg::new(16, 32).unwrap();
    let set = gaussian_mixture(3000, 128, 8, 0.25, 9);
    let labels = set.labels.clone().unwrap();
    let eval_k = 1000;
    let mut nmi = std::collections::HashMap::new();
    for coder in [Coder::Random, Coder::Hash] {
        let aux = match coder {
            Coder::Random => Aux::None { n: set.n },
            _ => Aux::Dense { data: &set.data, n: set.n, d: set.d },
        };
        let codes = make_codes(&aux, coder, coding, 5).unwrap();
        let (store, _) = recon::train_decoder(&model, &codes, &set, 4, 3).unwrap();
        let emb = recon::reconstruct(&model, &store, &codes, eval_k).unwrap();
        let score = recon::clustering_nmi(&emb, eval_k, 128, &labels, 8, 1);
        nmi.insert(coder.as_str(), score);
    }
    // The Figure-1 shape: hash above random (margin depends on budget, so
    // require strict ordering only).
    assert!(
        nmi["hash"] > nmi["random"],
        "hash {:.3} should beat random {:.3}",
        nmi["hash"],
        nmi["random"]
    );
}

#[test]
fn nodeclf_cell_produces_sane_accuracy() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Arxiv.generate(11).unwrap();
    let opts = RunOpts { epochs: 30, eval_every: 10, seed: 7 };
    let out = nodeclf::run_fullbatch(&engine, GnnKind::Gcn, Frontend::Hash, &graph, opts).unwrap();
    // 8 classes → chance 0.125; the hash front-end must do far better.
    assert!(out.test > 0.4, "hash/gcn test acc {:.3} too low", out.test);
    assert!(out.final_loss.is_finite());
}

#[test]
fn nodeclf_nc_baseline_learns_fast() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Products.generate(11).unwrap();
    let opts = RunOpts { epochs: 10, eval_every: 5, seed: 7 };
    let out = nodeclf::run_fullbatch(&engine, GnnKind::Sgc, Frontend::Nc, &graph, opts).unwrap();
    assert!(out.test > 0.5, "nc/sgc test acc {:.3}", out.test);
}

#[test]
fn linkpred_cell_runs_and_scores() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Ddi.generate(13).unwrap();
    let opts = RunOpts { epochs: 10, eval_every: 5, seed: 7 };
    let out =
        linkpred::run_fullbatch(&engine, GnnKind::Gcn, Frontend::Hash, &graph, 20, opts).unwrap();
    assert!(out.final_loss.is_finite());
    assert!((0.0..=1.0).contains(&out.test_hits));
}

#[test]
fn all_manifest_artifacts_load_and_validate() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let idx = hashgnn::ser::from_file(&artifacts_dir().join("index.json")).unwrap();
    let names = idx.get("artifacts").unwrap().as_arr().unwrap();
    assert!(names.len() >= 20, "expected the full variant registry");
    // Compile a representative subset end-to-end (full set is covered by
    // the benches; compiling all 25 here would double test wallclock).
    for name in ["recon_c2_m128", "node_fb_gin_coded", "link_fb_sage_nc", "sage_mb_nc"] {
        let model = engine.load(name).unwrap();
        assert_eq!(model.manifest.name, name);
        assert!(!model.manifest.params.is_empty());
        // Every param spec must have a nonempty shape product.
        for p in &model.manifest.params {
            assert!(p.n_elements() > 0, "{name}: empty param {}", p.name);
        }
    }
}
