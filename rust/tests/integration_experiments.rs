//! Integration: experiment drivers end to end.
//!
//! The native-backend tests run unconditionally — they synthesize their
//! models in pure Rust and exercise the §4/§5 pipelines with no artifacts
//! on disk. The HLO variants (full-batch GNNs, exported executables) stay
//! gated on `make artifacts` as before.

use std::sync::Arc;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind, OptimCfg};
use hashgnn::embed::gaussian_mixture;
use hashgnn::runtime::native::spec::{ReconBuild, SageMbBuild};
use hashgnn::runtime::{Engine, Model};
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::tasks::sage::{self, Features, SageTask};
use hashgnn::tasks::{linkpred, recon, T1Dataset};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_dir().join("index.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

// ---------------------------------------------------------------------------
// Native backend — always runs (no artifacts required)
// ---------------------------------------------------------------------------

/// A CPU-budget §4 build over the Arxiv-analog graph (n = 1024).
fn small_sage_build(coded: bool) -> SageMbBuild {
    SageMbBuild {
        name: "it_sage".into(),
        coded,
        link: false,
        n: 1024,
        n_classes: 8,
        d_e: 16,
        hidden: 16,
        batch: 32,
        k1: 3,
        k2: 2,
        c: 16,
        m: 8,
        d_c: 16,
        d_m: 16,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

#[test]
fn native_sage_pipeline_trains_end_to_end() {
    // The §4 pipeline with zero artifacts: SBM graph → Algorithm-1 codes
    // → minibatch SAGE through the full train::train pipeline (pipelined
    // producer) with per-epoch validation, then held-out evaluation.
    let build = small_sage_build(true);
    let model = Model::native(build.manifest(), 0).unwrap();
    assert_eq!(model.backend_name(), "native");
    let g = Arc::new(T1Dataset::Arxiv.generate(11).unwrap());
    let labels = Arc::new(g.labels().unwrap().to_vec());
    let coding = CodingCfg::new(build.c, build.m).unwrap();
    let codes = Arc::new(make_codes(&Aux::Graph(&g), Coder::Hash, coding, 5).unwrap());
    let split = hashgnn::graph::split_nodes(1024, 0.7, 0.1, 3).unwrap();
    let task = SageTask {
        graph: g.clone(),
        labels: labels.clone(),
        features: Features::Codes(codes.clone()),
        train_nodes: Arc::new(split.train.clone()),
    };
    let run = sage::train_sage(&model, task, 4, &split.val, 9, 0).unwrap();
    assert!(run.losses.iter().all(|l| l.is_finite()));
    let early: f32 = run.losses[..5.min(run.losses.len())].iter().sum::<f32>()
        / 5.min(run.losses.len()) as f32;
    let late = {
        let log = hashgnn::train::TrainLog { losses: run.losses.clone() };
        log.tail_mean(5)
    };
    assert!(late < early, "no training signal: {early} -> {late}");
    assert!(late < 2.0, "CE stuck at chance (ln 8 ≈ 2.08): {late}");
    // Held-out metrics with the best-validation parameters.
    let batcher = sage::SageBatcher::new(
        SageTask {
            graph: g,
            labels,
            features: Features::Codes(codes),
            train_nodes: Arc::new(split.train),
        },
        &model,
        9,
    )
    .unwrap();
    let test = sage::evaluate(&model, &run.store, &batcher, &split.test, 17).unwrap();
    assert!((0.0..=1.0).contains(&test.accuracy));
    assert!(test.accuracy > 0.15, "hash features should beat 8-class chance: {}", test.accuracy);
}

#[test]
fn native_nc_baseline_trains_end_to_end() {
    let build = small_sage_build(false);
    let model = Model::native(build.manifest(), 0).unwrap();
    let g = Arc::new(T1Dataset::Arxiv.generate(13).unwrap());
    let labels = Arc::new(g.labels().unwrap().to_vec());
    let split = hashgnn::graph::split_nodes(1024, 0.7, 0.1, 5).unwrap();
    let task = SageTask {
        graph: g,
        labels,
        features: Features::Ids,
        train_nodes: Arc::new(split.train),
    };
    let run = sage::train_sage(&model, task, 3, &[], 21, 0).unwrap();
    let early = run.losses[0];
    let late = {
        let log = hashgnn::train::TrainLog { losses: run.losses.clone() };
        log.tail_mean(5)
    };
    assert!(late < early, "NC table should overfit quickly: {early} -> {late}");
}

#[test]
fn native_linkpred_head_learns_to_rank_edges() {
    let mut build = small_sage_build(true);
    build.link = true;
    build.batch = 16;
    let model = Model::native(build.manifest(), 0).unwrap();
    let g = Arc::new(T1Dataset::Collab.generate(7).unwrap());
    let coding = CodingCfg::new(build.c, build.m).unwrap();
    let codes = Arc::new(make_codes(&Aux::Graph(&g), Coder::Hash, coding, 5).unwrap());
    let edges = Arc::new(g.undirected_edges());
    let (store, log) =
        linkpred::train_sage_link(&model, g.clone(), codes.clone(), edges.clone(), 40, 3, 0)
            .unwrap();
    assert!(log.losses.iter().all(|l| l.is_finite()));
    assert!(
        log.tail_mean(5) < log.losses[0],
        "BPR loss did not decrease: {} -> {}",
        log.losses[0],
        log.tail_mean(5)
    );
    // Training edges must outscore uniform non-edges on average.
    let pos: Vec<(u32, u32)> = edges.iter().copied().take(64).collect();
    let mut rng = hashgnn::rng::Xoshiro256pp::seed_from_u64(31);
    use hashgnn::rng::Rng;
    let mut neg = Vec::with_capacity(64);
    while neg.len() < 64 {
        let u = rng.index(1024);
        let v = rng.index(1024);
        if u != v && !g.has_edge(u, v) {
            neg.push((u as u32, v as u32));
        }
    }
    let pos_scores = linkpred::score_edges_mb(&model, &store, &g, &codes, &pos, 41).unwrap();
    let neg_scores = linkpred::score_edges_mb(&model, &store, &g, &codes, &neg, 43).unwrap();
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
    assert!(
        mean(&pos_scores) > mean(&neg_scores),
        "pos {} should outscore neg {}",
        mean(&pos_scores),
        mean(&neg_scores)
    );
}

#[test]
fn native_recon_hash_beats_random_on_clustered_embeddings() {
    // The Figure-1 shape on the native backend: LSH codes over clustered
    // embeddings must reconstruct better-separated clusters than random
    // codes, measured by k-means NMI.
    let build = ReconBuild {
        name: "it_recon".into(),
        c: 16,
        m: 16,
        d_c: 64,
        d_m: 64,
        d_e: 32,
        l: 2,
        light: false,
        batch: 128,
        optim: OptimCfg::adamw_default(),
    };
    let model = Model::native(build.manifest(), 0).unwrap();
    let coding = CodingCfg::new(16, 16).unwrap();
    let set = gaussian_mixture(1500, 32, 8, 0.25, 9);
    let labels = set.labels.clone().unwrap();
    let eval_k = 600;
    let mut nmi = std::collections::HashMap::new();
    for coder in [Coder::Random, Coder::Hash] {
        let aux = match coder {
            Coder::Random => Aux::None { n: set.n },
            _ => Aux::Dense { data: &set.data, n: set.n, d: set.d },
        };
        let codes = make_codes(&aux, coder, coding, 5).unwrap();
        let (store, _) = recon::train_decoder(&model, &codes, &set, 6, 3).unwrap();
        let emb = recon::reconstruct(&model, &store, &codes, eval_k).unwrap();
        let score = recon::clustering_nmi(&emb, eval_k, 32, &labels, 8, 1);
        nmi.insert(coder.as_str(), score);
    }
    assert!(
        nmi["hash"] > nmi["random"],
        "hash {:.3} should beat random {:.3}",
        nmi["hash"],
        nmi["random"]
    );
}

// ---------------------------------------------------------------------------
// HLO backend — gated on exported artifacts
// ---------------------------------------------------------------------------

#[test]
fn recon_hash_beats_random_on_clustered_embeddings() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let model = engine.load("recon_c16_m32").unwrap();
    let coding = CodingCfg::new(16, 32).unwrap();
    let set = gaussian_mixture(3000, 128, 8, 0.25, 9);
    let labels = set.labels.clone().unwrap();
    let eval_k = 1000;
    let mut nmi = std::collections::HashMap::new();
    for coder in [Coder::Random, Coder::Hash] {
        let aux = match coder {
            Coder::Random => Aux::None { n: set.n },
            _ => Aux::Dense { data: &set.data, n: set.n, d: set.d },
        };
        let codes = make_codes(&aux, coder, coding, 5).unwrap();
        let (store, _) = recon::train_decoder(&model, &codes, &set, 4, 3).unwrap();
        let emb = recon::reconstruct(&model, &store, &codes, eval_k).unwrap();
        let score = recon::clustering_nmi(&emb, eval_k, 128, &labels, 8, 1);
        nmi.insert(coder.as_str(), score);
    }
    // The Figure-1 shape: hash above random (margin depends on budget, so
    // require strict ordering only).
    assert!(
        nmi["hash"] > nmi["random"],
        "hash {:.3} should beat random {:.3}",
        nmi["hash"],
        nmi["random"]
    );
}

#[test]
fn nodeclf_cell_produces_sane_accuracy() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Arxiv.generate(11).unwrap();
    let opts = RunOpts { epochs: 30, eval_every: 10, seed: 7 };
    let out = nodeclf::run_fullbatch(&engine, GnnKind::Gcn, Frontend::Hash, &graph, opts).unwrap();
    // 8 classes → chance 0.125; the hash front-end must do far better.
    assert!(out.test > 0.4, "hash/gcn test acc {:.3} too low", out.test);
    assert!(out.final_loss.is_finite());
}

#[test]
fn nodeclf_nc_baseline_learns_fast() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Products.generate(11).unwrap();
    let opts = RunOpts { epochs: 10, eval_every: 5, seed: 7 };
    let out = nodeclf::run_fullbatch(&engine, GnnKind::Sgc, Frontend::Nc, &graph, opts).unwrap();
    assert!(out.test > 0.5, "nc/sgc test acc {:.3}", out.test);
}

#[test]
fn linkpred_cell_runs_and_scores() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let graph = T1Dataset::Ddi.generate(13).unwrap();
    let opts = RunOpts { epochs: 10, eval_every: 5, seed: 7 };
    let out =
        linkpred::run_fullbatch(&engine, GnnKind::Gcn, Frontend::Hash, &graph, 20, opts).unwrap();
    assert!(out.final_loss.is_finite());
    assert!((0.0..=1.0).contains(&out.test_hits));
}

#[test]
fn all_manifest_artifacts_load_and_validate() {
    require_artifacts!();
    let engine = Engine::cpu(artifacts_dir()).unwrap();
    let idx = hashgnn::ser::from_file(&artifacts_dir().join("index.json")).unwrap();
    let names = idx.get("artifacts").unwrap().as_arr().unwrap();
    assert!(names.len() >= 20, "expected the full variant registry");
    // Compile a representative subset end-to-end (full set is covered by
    // the benches; compiling all 25 here would double test wallclock).
    for name in ["recon_c2_m128", "node_fb_gin_coded", "link_fb_sage_nc", "sage_mb_nc"] {
        let model = engine.load(name).unwrap();
        assert_eq!(model.manifest.name, name);
        assert!(!model.manifest.params.is_empty());
        // Every param spec must have a nonempty shape product.
        for p in &model.manifest.params {
            assert!(p.n_elements() > 0, "{name}: empty param {}", p.name);
        }
    }
}
