//! Inference/training forward parity — the fwd/bwd-split contract.
//!
//! For every model family the inference-only surface
//! ([`InferModel`]) must reproduce the training-time forward **bit for
//! bit**: the loss it computes equals the loss the fused train step
//! emits for the same parameters and batch, and its
//! embeddings/logits/scores equal the train-fused predict path — at
//! thread counts {1, 8}. Covered: decoder recon, minibatch SAGE
//! (clf + link), and all four full-batch architectures (clf for each,
//! link for GCN and SAGE).

use std::sync::Arc;

use hashgnn::cfg::{GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::native::infer::InferModel;
use hashgnn::runtime::native::spec::{FullBatchBuild, ReconBuild, SageMbBuild};
use hashgnn::runtime::native::NativeModel;
use hashgnn::runtime::{Manifest, Tensor};
use hashgnn::sparse::Csr;

// ---------------------------------------------------------------------------
// Deterministic batch builders
// ---------------------------------------------------------------------------

fn codes_tensor(rows: usize, m: usize, c: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows * m).map(|_| rng.index(c) as i32).collect();
    Tensor::i32(vec![rows, m], data).unwrap()
}

fn ids_tensor(rows: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows).map(|_| rng.index(n) as i32).collect();
    Tensor::i32(vec![rows], data).unwrap()
}

fn f32_tensor(shape: Vec<usize>, std: f32, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n: usize = shape.iter().product();
    let mut data = vec![0.0f32; n];
    rng.fill_normal_f32(&mut data, 0.0, std);
    Tensor::f32(shape, data).unwrap()
}

fn edges_tensor(e: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..e * 2).map(|_| rng.index(n) as i32).collect();
    Tensor::i32(vec![e, 2], data).unwrap()
}

// ---------------------------------------------------------------------------
// Parity harness
// ---------------------------------------------------------------------------

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

/// Loss parity: `InferModel::loss` equals the loss the fused train step
/// emits, bitwise, at threads {1, 8}; plus thread-count invariance.
fn assert_loss_parity(manifest: &Manifest, batch: &[Tensor], adj: Option<&Arc<Csr>>) {
    let nm = NativeModel::from_manifest(manifest).unwrap();
    let im = InferModel::from_manifest(manifest).unwrap();
    if let Some(a) = adj {
        nm.bind_adjacency(a.clone()).unwrap();
        im.bind_adjacency(a.clone()).unwrap();
    }
    let store = ParamStore::init(manifest, 33);
    let mut reference: Option<u32> = None;
    for threads in [1usize, 8] {
        let outs = nm.train_step(&store.train_inputs(batch), threads).unwrap();
        let train_loss = outs.last().unwrap().scalar().unwrap();
        let infer_loss = im.loss(&store.params, batch, threads).unwrap();
        assert_eq!(
            train_loss.to_bits(),
            infer_loss.to_bits(),
            "{}: fwd-only loss {infer_loss} != train-step loss {train_loss} (threads={threads})",
            manifest.name
        );
        match reference {
            None => reference = Some(train_loss.to_bits()),
            Some(r) => assert_eq!(r, train_loss.to_bits(), "{}: thread variance", manifest.name),
        }
    }
}

/// Prediction parity: the named `InferModel` method equals the
/// train-fused predict executable output, bitwise, at threads {1, 8}.
fn assert_pred_parity(
    manifest: &Manifest,
    pred_batch: &[Tensor],
    adj: Option<&Arc<Csr>>,
    call: impl Fn(&InferModel, &[Tensor], &[Tensor], usize) -> Tensor,
) {
    let nm = NativeModel::from_manifest(manifest).unwrap();
    let im = InferModel::from_manifest(manifest).unwrap();
    if let Some(a) = adj {
        nm.bind_adjacency(a.clone()).unwrap();
        im.bind_adjacency(a.clone()).unwrap();
    }
    let store = ParamStore::init(manifest, 33);
    let mut reference: Option<Vec<u32>> = None;
    for threads in [1usize, 8] {
        let trained = nm.predict(&store.params, pred_batch, threads).unwrap();
        let inferred = call(&im, &store.params, pred_batch, threads);
        assert_eq!(trained.shape(), inferred.shape(), "{}: shape", manifest.name);
        assert_bits_equal(
            trained.as_f32().unwrap(),
            inferred.as_f32().unwrap(),
            &format!("{} (threads={threads})", manifest.name),
        );
        let bits: Vec<u32> = inferred.as_f32().unwrap().iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(*r, bits, "{}: thread variance", manifest.name),
        }
    }
}

// ---------------------------------------------------------------------------
// Decoder recon
// ---------------------------------------------------------------------------

#[test]
fn recon_decoder_parity() {
    for light in [false, true] {
        let manifest = ReconBuild {
            name: format!("p_recon{}", if light { "_l" } else { "" }),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 4,
            l: 2,
            light,
            batch: 6,
            optim: OptimCfg::adamw_default(),
        }
        .manifest();
        let codes = codes_tensor(6, 3, 4, 9);
        let batch = vec![codes.clone(), f32_tensor(vec![6, 4], 0.5, 10)];
        assert_loss_parity(&manifest, &batch, None);
        assert_pred_parity(&manifest, &[codes], None, |im, p, b, t| {
            im.embed_nodes(p, b, t).unwrap()
        });
    }
}

// ---------------------------------------------------------------------------
// Minibatch SAGE (clf + link, coded + nc)
// ---------------------------------------------------------------------------

fn mb_build(coded: bool, link: bool) -> SageMbBuild {
    SageMbBuild {
        name: format!("p_mb_{}{}", if coded { "c" } else { "nc" }, if link { "_l" } else { "" }),
        coded,
        link,
        n: 30,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn mb_node_set(build: &SageMbBuild, seed: u64) -> Vec<Tensor> {
    let (b, k1, k2) = (build.batch, build.k1, build.k2);
    if build.coded {
        vec![
            codes_tensor(b, build.m, build.c, seed),
            codes_tensor(b * k1, build.m, build.c, seed ^ 1),
            codes_tensor(b * k1 * k2, build.m, build.c, seed ^ 2),
        ]
    } else {
        vec![
            ids_tensor(b, build.n, seed),
            ids_tensor(b * k1, build.n, seed ^ 1),
            ids_tensor(b * k1 * k2, build.n, seed ^ 2),
        ]
    }
}

#[test]
fn sage_minibatch_clf_parity() {
    for coded in [true, false] {
        let build = mb_build(coded, false);
        let manifest = build.manifest();
        let mut rng = Xoshiro256pp::seed_from_u64(0x51);
        let labels: Vec<i32> =
            (0..build.batch).map(|_| rng.index(build.n_classes) as i32).collect();
        let node_set = mb_node_set(&build, 17);
        let mut batch = node_set.clone();
        batch.push(Tensor::i32(vec![build.batch], labels).unwrap());
        assert_loss_parity(&manifest, &batch, None);
        assert_pred_parity(&manifest, &node_set, None, |im, p, b, t| {
            im.predict_classes(p, b, t).unwrap()
        });
        // embed_nodes serves the (batch, hidden) representations.
        let im = InferModel::from_manifest(&manifest).unwrap();
        let store = ParamStore::init(&manifest, 33);
        let h = im.embed_nodes(&store.params, &node_set, 1).unwrap();
        assert_eq!(h.shape(), &[build.batch, build.hidden]);
    }
}

#[test]
fn sage_minibatch_link_parity() {
    let build = mb_build(true, true);
    let manifest = build.manifest();
    let mut train_batch = mb_node_set(&build, 23);
    train_batch.extend(mb_node_set(&build, 31));
    train_batch.extend(mb_node_set(&build, 47));
    assert_loss_parity(&manifest, &train_batch, None);
    let mut pred_batch = mb_node_set(&build, 23);
    pred_batch.extend(mb_node_set(&build, 31));
    assert_pred_parity(&manifest, &pred_batch, None, |im, p, b, t| {
        im.score_edges(p, b, t).unwrap()
    });
}

// ---------------------------------------------------------------------------
// Full-batch grid (all four architectures)
// ---------------------------------------------------------------------------

fn fb_build(gnn: GnnKind, coded: bool, link: bool) -> FullBatchBuild {
    FullBatchBuild {
        name: format!("p_fb_{}_{}", gnn.as_str(), if link { "l" } else { "c" }),
        gnn,
        coded,
        link,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn fb_adj(manifest: &Manifest, n: usize, seed: u64) -> Arc<Csr> {
    let g = sbm(SbmCfg::new(n, 4, 6.0, 2.0), seed).unwrap();
    Arc::new(g.adj().normalized(manifest.hyper_str("adj").unwrap()).unwrap())
}

#[test]
fn fullbatch_clf_parity_all_architectures() {
    for gnn in GnnKind::all() {
        let build = fb_build(gnn, true, false);
        let manifest = build.manifest();
        let adj = fb_adj(&manifest, build.n, 5);
        let codes = codes_tensor(build.n, build.m, build.c, 7);
        let mut rng = Xoshiro256pp::seed_from_u64(0x77);
        let labels: Vec<i32> =
            (0..build.n).map(|_| rng.index(build.n_classes) as i32).collect();
        let mask: Vec<f32> =
            (0..build.n).map(|i| if i % 4 == 0 { 0.0 } else { 1.0 }).collect();
        let batch = vec![
            codes.clone(),
            Tensor::i32(vec![build.n], labels).unwrap(),
            Tensor::f32(vec![build.n], mask).unwrap(),
        ];
        assert_loss_parity(&manifest, &batch, Some(&adj));
        assert_pred_parity(&manifest, &[codes], Some(&adj), |im, p, b, t| {
            im.predict_classes(p, b, t).unwrap()
        });
    }
}

#[test]
fn fullbatch_nc_clf_parity() {
    // NC front-end: features come straight from the table parameter.
    let build = fb_build(GnnKind::Gin, false, false);
    let manifest = build.manifest();
    let adj = fb_adj(&manifest, build.n, 6);
    let mut rng = Xoshiro256pp::seed_from_u64(0x78);
    let labels: Vec<i32> = (0..build.n).map(|_| rng.index(build.n_classes) as i32).collect();
    let batch = vec![
        Tensor::i32(vec![build.n], labels).unwrap(),
        Tensor::f32(vec![build.n], vec![1.0; build.n]).unwrap(),
    ];
    assert_loss_parity(&manifest, &batch, Some(&adj));
    assert_pred_parity(&manifest, &[], Some(&adj), |im, p, b, t| {
        im.predict_classes(p, b, t).unwrap()
    });
}

#[test]
fn fullbatch_link_parity() {
    for gnn in [GnnKind::Gcn, GnnKind::Sage] {
        let build = fb_build(gnn, true, true);
        let manifest = build.manifest();
        let adj = fb_adj(&manifest, build.n, 8);
        let codes = codes_tensor(build.n, build.m, build.c, 11);
        let batch = vec![
            codes.clone(),
            edges_tensor(build.e_train, build.n, 13),
            edges_tensor(build.e_train, build.n, 14),
        ];
        assert_loss_parity(&manifest, &batch, Some(&adj));
        let pred = vec![codes, edges_tensor(build.e_pred, build.n, 15)];
        assert_pred_parity(&manifest, &pred, Some(&adj), |im, p, b, t| {
            im.score_edges(p, b, t).unwrap()
        });
    }
}
