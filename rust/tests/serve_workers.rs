//! Multi-process shard serving against REAL worker processes, end to
//! end through the shipped binary (`CARGO_BIN_EXE_hashgnn`):
//!
//! 1. two `serve --shard-worker` processes over saved `HGNS0001` shard
//!    files, a [`RemoteRouter`] in front — embeddings and classes are
//!    **bit-identical** to the unsharded in-process session;
//! 2. a worker rejects ids outside its owned range per line (the raw
//!    socket session keeps serving afterwards);
//! 3. `kill -9` one worker mid-fleet: the router degrades to partial
//!    service — dead-shard ids answer exactly `shard_unavailable`,
//!    live-shard ids keep their exact bytes;
//! 4. restart the dead worker (fresh process, fresh kernel-assigned
//!    port): a new router over the restarted fleet serves the full id
//!    space bit-identically again.
//!
//! Workers bind `127.0.0.1:0` and advertise via `--port-file`, so the
//! test never races a fixed port and never trips TIME_WAIT.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use hashgnn::cfg::{Coder, CodingCfg, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::spec::SageMbBuild;
use hashgnn::ser;
use hashgnn::serve::{
    RemoteCfg, RemoteRouter, ServeOpts, ServeSession, Serving, ServingBundle,
};
use hashgnn::tasks::coding::{make_codes, Aux};

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn opts(threads: usize) -> ServeOpts {
    ServeOpts { threads, cache_capacity: 64, seed: 5, ..Default::default() }
}

fn tmpdir() -> PathBuf {
    // Unique per process: parallel `cargo test` runs must not collide.
    let dir = std::env::temp_dir().join(format!("hashgnn_serve_workers_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn sage_bundle() -> ServingBundle {
    let build = SageMbBuild {
        name: "sw_mb".into(),
        coded: true,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 9).unwrap();
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

/// Spawn one shard worker on a kernel-assigned port; return the child
/// and the address it advertised through `--port-file`.
fn spawn_worker(shard: &Path, tag: &str) -> (Child, String) {
    let port_file = tmpdir().join(format!("{tag}.port"));
    let _ = std::fs::remove_file(&port_file);
    let child = Command::new(env!("CARGO_BIN_EXE_hashgnn"))
        .args([
            "serve",
            "--shard-worker",
            "--listen",
            "127.0.0.1:0",
            "--port-file",
            port_file.to_str().unwrap(),
            "--bundle",
            shard.to_str().unwrap(),
            "--max-delay-ms",
            "2",
            "--threads",
            "1",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()
        .expect("spawn shard worker");
    wait_for_port_file(child, &port_file)
}

/// Block until the worker writes its bound address (or dies trying).
fn wait_for_port_file(mut child: Child, port_file: &Path) -> (Child, String) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(text) = std::fs::read_to_string(port_file) {
            let addr = text.trim().to_string();
            if !addr.is_empty() {
                return (child, addr);
            }
        }
        if let Some(status) = child.try_wait().unwrap() {
            panic!("shard worker exited before binding: {status}");
        }
        assert!(Instant::now() < deadline, "worker never wrote {}", port_file.display());
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn rcfg() -> RemoteCfg {
    RemoteCfg {
        connect_timeout: Duration::from_secs(5),
        request_timeout: Duration::from_secs(5),
        retries: 1,
        backoff: Duration::from_millis(20),
        health_every: Duration::ZERO,
        ..Default::default()
    }
}

fn worker_up(router: &RemoteRouter, i: usize) -> bool {
    router.stats_json().get("workers").unwrap().as_arr().unwrap()[i]
        .get("up")
        .unwrap()
        .as_bool()
        .unwrap()
}

/// One raw NDJSON exchange on a fresh socket; returns the response line.
fn raw_request(addr: &str, line: &str) -> String {
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(line.as_bytes()).unwrap();
    sock.write_all(b"\n").unwrap();
    let mut resp = String::new();
    BufReader::new(sock).read_line(&mut resp).unwrap();
    resp.trim_end().to_string()
}

#[test]
fn pipelined_fanout_matches_sequential_walk_over_real_workers() {
    let bundle = sage_bundle();
    // Own subdirectory: the kill/restart test removes its dir when done,
    // and both tests run in parallel under one `cargo test` process.
    let dir = tmpdir().join("fanout");
    std::fs::create_dir_all(&dir).unwrap();
    let shard_paths: Vec<PathBuf> = bundle
        .split_shards(3)
        .unwrap()
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let p = dir.join(format!("fw.shard-{i}-of-3"));
            s.save(&p).unwrap();
            p
        })
        .collect();
    let workers: Vec<(Child, String)> = shard_paths
        .iter()
        .enumerate()
        .map(|(i, p)| spawn_worker(p, &format!("fw{i}")))
        .collect();
    let addrs: Vec<String> = workers.iter().map(|(_, a)| a.clone()).collect();

    // Ids spanning all three shards, interleaved and repeated.
    let ids: Vec<u32> = vec![0, 21, 41, 59, 5, 25, 45, 0, 30];
    let mut local = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    let want = local.embed_nodes(&ids).unwrap();

    // Pipelined (the default): write all shard requests, then read all.
    let mut piped = RemoteRouter::connect(&addrs, rcfg()).unwrap();
    let got = piped.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&got, &want), "pipelined fan-out must serve the local bytes");
    let report = piped.take_fanout_report().expect("pipelined flush must record a report");
    assert_eq!(report.width, 3, "all three shards were in flight at once");
    assert_eq!(report.shard_wait_us.len(), 3);

    // Sequential walk: one request outstanding at a time.
    let mut seq =
        RemoteRouter::connect(&addrs, RemoteCfg { fanout: false, ..rcfg() }).unwrap();
    let got_seq = seq.embed_nodes(&ids).unwrap();
    assert!(
        bits_equal(&got_seq, &got),
        "sequential and pipelined fan-out must serve identical bytes"
    );
    let report = seq.take_fanout_report().expect("sequential flush must record a report");
    assert_eq!(report.width, 1, "sequential walk keeps one request in flight");
    assert_eq!(report.shard_wait_us.len(), 3, "every shard is still timed");

    // Classes flow through the same per-shard decode path.
    let (_, remote_classes) = piped.classes_for_ids(&ids).unwrap();
    let (_, local_classes) = local.predict_classes(&ids).unwrap();
    assert_eq!(remote_classes, local_classes);

    for (mut w, _) in workers {
        w.kill().unwrap();
        w.wait().unwrap();
    }
}

#[test]
fn real_worker_processes_survive_kill_and_restart() {
    let bundle = sage_bundle();
    // Own subdirectory: sibling tests share the per-process tmpdir root,
    // so removing it wholesale at the end would race them.
    let dir = tmpdir().join("killrestart");
    std::fs::create_dir_all(&dir).unwrap();
    let shard_paths: Vec<PathBuf> = bundle
        .split_shards(2)
        .unwrap()
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let p = dir.join(format!("sw.shard-{i}-of-2"));
            s.save(&p).unwrap();
            p
        })
        .collect();
    let (mut w0, addr0) = spawn_worker(&shard_paths[0], "w0");
    let (mut w1, addr1) = spawn_worker(&shard_paths[1], "w1");

    let ids: Vec<u32> = vec![0, 29, 30, 59, 15, 45];
    let mut local = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    let want = local.embed_nodes(&ids).unwrap();
    let d = local.embed_dim();

    // --- full fleet: byte parity through two real processes ---
    let mut router = RemoteRouter::connect(&[addr0.clone(), addr1.clone()], rcfg()).unwrap();
    let got = router.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&got, &want), "sharded processes must serve the local bytes");
    let (_, remote_classes) = router.classes_for_ids(&ids).unwrap();
    let (_, local_classes) = local.predict_classes(&ids).unwrap();
    assert_eq!(remote_classes, local_classes);

    // --- a worker polices its owned range, and the session survives ---
    let resp = raw_request(&addr1, r#"{"op": "embed", "nodes": [0]}"#);
    let msg = ser::parse(&resp).unwrap();
    let err = msg.get("error").unwrap().as_str().unwrap().to_string();
    assert!(
        err.contains("owned range [30, 60)"),
        "worker 1 must reject id 0 with its owned range, got: {resp}"
    );
    let resp = raw_request(&addr1, r#"{"op": "embed", "nodes": [30]}"#);
    assert!(
        ser::parse(&resp).unwrap().get("embeddings").is_ok(),
        "the rejection must not poison the worker: {resp}"
    );

    // --- kill -9 worker 0: partial service, exact bytes for the rest ---
    w0.kill().unwrap();
    w0.wait().unwrap();
    let part = router.embed_nodes_partial(&ids).unwrap();
    for (k, &id) in ids.iter().enumerate() {
        if id < 30 {
            assert_eq!(part.failed.get(&id).unwrap(), "shard_unavailable");
        } else {
            assert!(!part.failed.contains_key(&id), "live shard must keep serving id {id}");
            assert!(
                bits_equal(&part.rows[k * d..(k + 1) * d], &want[k * d..(k + 1) * d]),
                "live-shard bytes must not change while the fleet is degraded"
            );
        }
    }
    assert!(!worker_up(&router, 0), "killed worker must be marked down");
    assert!(worker_up(&router, 1));

    // --- restart on a fresh port: a new fleet serves everything again ---
    let (mut w0b, addr0b) = spawn_worker(&shard_paths[0], "w0b");
    let mut revived = RemoteRouter::connect(&[addr0b, addr1], rcfg()).unwrap();
    let again = revived.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&again, &want), "restarted fleet must serve the exact original bytes");

    w0b.kill().unwrap();
    w0b.wait().unwrap();
    w1.kill().unwrap();
    w1.wait().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
