//! Hash-embedding front-end verification (multihash / bloom / poshash):
//! finite-difference gradient checks on tiny minibatch and full-batch
//! manifests (classification and link heads), bit-determinism across
//! thread counts, and scratch-reuse == fresh-allocation equivalence.
//!
//! Mirrors `tests/native_backend.rs` — same FD protocol, same tolerances
//! — over the three new `FeatSource::HashEmb` front-ends.

use std::sync::Arc;

use hashgnn::cfg::OptimCfg;
use hashgnn::params::ParamStore;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::native::hashemb::HashKind;
use hashgnn::runtime::native::spec::{FullBatchBuild, HashFrontEnd, SageMbBuild};
use hashgnn::runtime::native::NativeModel;
use hashgnn::runtime::{Manifest, Tensor};

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

fn ids_tensor(rows: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows).map(|_| rng.index(n) as i32).collect();
    Tensor::i32(vec![rows], data).unwrap()
}

/// Tiny front-end config: a 7-row pool, 2 probes, and (poshash only) a
/// 3-row position table. Small enough that probe collisions — the very
/// thing the backward scatters must handle — are guaranteed.
fn tiny_fe(kind: HashKind) -> HashFrontEnd {
    HashFrontEnd {
        kind,
        k: 2,
        b: 7,
        bp: if kind == HashKind::Pos { 3 } else { 0 },
        seed: 99,
    }
}

fn tiny_mb_build(link: bool) -> SageMbBuild {
    SageMbBuild {
        name: "t_hclf".into(),
        coded: false,
        link,
        n: 30,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: if link { 3 } else { 4 },
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn mb_clf_batch(build: &SageMbBuild, seed: u64) -> Vec<Tensor> {
    let (b, k1, k2) = (build.batch, build.k1, build.k2);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x51);
    let labels: Vec<i32> = (0..b).map(|_| rng.index(build.n_classes) as i32).collect();
    vec![
        ids_tensor(b, build.n, seed),
        ids_tensor(b * k1, build.n, seed ^ 1),
        ids_tensor(b * k1 * k2, build.n, seed ^ 2),
        Tensor::i32(vec![b], labels).unwrap(),
    ]
}

fn mb_link_batch(build: &SageMbBuild, seed: u64) -> Vec<Tensor> {
    let (b, k1, k2) = (build.batch, build.k1, build.k2);
    let mut batch = Vec::with_capacity(9);
    for set in 0..3u64 {
        batch.push(ids_tensor(b, build.n, seed ^ (set * 10)));
        batch.push(ids_tensor(b * k1, build.n, seed ^ (set * 10 + 1)));
        batch.push(ids_tensor(b * k1 * k2, build.n, seed ^ (set * 10 + 2)));
    }
    batch
}

/// Deterministic position map covering every bucket of the manifest's
/// `hemb_bp`-row table (only poshash manifests carry the hyper).
fn test_pos_map(manifest: &Manifest) -> Arc<Vec<u32>> {
    let n = manifest.hyper_usize("n").unwrap();
    let bp = manifest.hyper_usize("hemb_bp").unwrap();
    Arc::new((0..n).map(|v| ((v * 7 + 3) % bp) as u32).collect())
}

/// Build the model and, for poshash, bind its position map.
fn model_for(manifest: &Manifest) -> NativeModel {
    let model = NativeModel::from_manifest(manifest).unwrap();
    if model.needs_pos_map() {
        model.bind_pos_map(test_pos_map(manifest)).unwrap();
    }
    model
}

// ---------------------------------------------------------------------------
// Finite-difference gradient check (same protocol as native_backend.rs)
// ---------------------------------------------------------------------------

fn grad_check(manifest: &Manifest, batch: &[Tensor], seed: u64) {
    let model = model_for(manifest);
    let store = ParamStore::init(manifest, seed);
    let (loss0, grads) = model.loss_and_grads(&store.params, batch, 1).unwrap();
    assert!(loss0.is_finite());
    let eps = 1e-2f32;
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xF1D0);
    let mut checked = 0usize;
    let mut agreed = 0usize;
    for (i, spec) in manifest.params.iter().enumerate() {
        if !spec.trainable {
            assert!(grads[i].iter().all(|&g| g == 0.0), "{}: frozen grad nonzero", spec.name);
            continue;
        }
        let n = spec.n_elements();
        for _ in 0..6.min(n) {
            let j = rng.index(n);
            let loss_at = |delta: f32| -> f32 {
                let mut params = store.params.clone();
                if let Tensor::F32 { data, .. } = &mut params[i] {
                    data[j] += delta;
                }
                model.loss_and_grads(&params, batch, 1).unwrap().0
            };
            let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
            let an = grads[i][j];
            let tol = 3e-3 + 0.08 * an.abs().max(fd.abs());
            checked += 1;
            if (fd - an).abs() <= tol {
                agreed += 1;
            } else {
                eprintln!("  mismatch {}[{j}]: fd={fd:.6} analytic={an:.6}", spec.name);
            }
        }
    }
    assert!(checked >= 12, "gradcheck sampled too few coordinates ({checked})");
    let rate = agreed as f64 / checked as f64;
    assert!(rate >= 0.85, "gradient agreement only {agreed}/{checked}");
}

const KINDS: [HashKind; 3] = [HashKind::Multi, HashKind::Bloom, HashKind::Pos];

#[test]
fn gradcheck_minibatch_clf_all_hash_frontends() {
    for (i, kind) in KINDS.into_iter().enumerate() {
        let build = tiny_mb_build(false);
        let manifest = build.manifest_hash(&tiny_fe(kind));
        eprintln!("gradcheck clf: {}", kind.as_str());
        grad_check(&manifest, &mb_clf_batch(&build, 17 + i as u64), 5 + i as u64);
    }
}

#[test]
fn gradcheck_minibatch_link_all_hash_frontends() {
    for (i, kind) in KINDS.into_iter().enumerate() {
        let build = tiny_mb_build(true);
        let manifest = build.manifest_hash(&tiny_fe(kind));
        eprintln!("gradcheck link: {}", kind.as_str());
        grad_check(&manifest, &mb_link_batch(&build, 23 + i as u64), 7 + i as u64);
    }
}

#[test]
fn gradcheck_fullbatch_clf_all_hash_frontends() {
    // Exercises the fwd_full/bwd_full arms: ids are implicitly 0..n, the
    // adjacency is a bound CSR, and the whole graph is one batch.
    let n = 24;
    let graph = hashgnn::graph::generate::sbm(
        hashgnn::graph::generate::SbmCfg::new(n, 3, 6.0, 2.0),
        11,
    )
    .unwrap();
    for (i, kind) in KINDS.into_iter().enumerate() {
        let build = FullBatchBuild {
            name: "t_hfb".into(),
            gnn: hashgnn::cfg::GnnKind::Gin,
            coded: false,
            link: false,
            n,
            n_classes: 3,
            d_e: 4,
            hidden: 5,
            c: 4,
            m: 3,
            d_c: 4,
            d_m: 6,
            l: 2,
            light: false,
            e_train: 8,
            e_pred: 16,
            optim: OptimCfg::adamw_gnn(),
        };
        let manifest = build.manifest_hash(&tiny_fe(kind));
        let model = model_for(&manifest);
        let adj = Arc::new(graph.adj().normalized(manifest.hyper_str("adj").unwrap()).unwrap());
        model.bind_adjacency(adj).unwrap();

        let labels: Vec<i32> =
            graph.labels().unwrap().iter().map(|&l| l as i32).collect();
        let mut mask = vec![0.0f32; n];
        for v in 0..n {
            if v % 3 != 0 {
                mask[v] = 1.0;
            }
        }
        let batch = vec![
            Tensor::i32(vec![n], labels).unwrap(),
            Tensor::f32(vec![n], mask).unwrap(),
        ];

        // Inline FD check against the bound-adjacency model (grad_check
        // builds its own model, which would lose the binding).
        let store = ParamStore::init(&manifest, 31 + i as u64);
        let (loss0, grads) = model.loss_and_grads(&store.params, &batch, 1).unwrap();
        assert!(loss0.is_finite(), "{}: non-finite loss", kind.as_str());
        let eps = 1e-2f32;
        let mut rng = Xoshiro256pp::seed_from_u64(0xF1D0 + i as u64);
        let (mut checked, mut agreed) = (0usize, 0usize);
        for (p, spec) in manifest.params.iter().enumerate() {
            if !spec.trainable {
                continue;
            }
            let count = spec.n_elements();
            for _ in 0..6.min(count) {
                let j = rng.index(count);
                let loss_at = |delta: f32| -> f32 {
                    let mut params = store.params.clone();
                    if let Tensor::F32 { data, .. } = &mut params[p] {
                        data[j] += delta;
                    }
                    model.loss_and_grads(&params, &batch, 1).unwrap().0
                };
                let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
                let an = grads[p][j];
                let tol = 3e-3 + 0.08 * an.abs().max(fd.abs());
                checked += 1;
                if (fd - an).abs() <= tol {
                    agreed += 1;
                } else {
                    eprintln!(
                        "  {} mismatch {}[{j}]: fd={fd:.6} analytic={an:.6}",
                        kind.as_str(),
                        spec.name
                    );
                }
            }
        }
        assert!(checked >= 12, "{}: sampled too few ({checked})", kind.as_str());
        let rate = agreed as f64 / checked as f64;
        assert!(rate >= 0.85, "{}: agreement only {agreed}/{checked}", kind.as_str());
    }
}

// ---------------------------------------------------------------------------
// Determinism invariants
// ---------------------------------------------------------------------------

#[test]
fn hash_frontend_grads_are_bit_identical_across_thread_counts() {
    for kind in KINDS {
        let build = tiny_mb_build(false);
        let manifest = build.manifest_hash(&tiny_fe(kind));
        let batch = mb_clf_batch(&build, 41);
        let model = model_for(&manifest);
        let store = ParamStore::init(&manifest, 42);
        let (l1, g1) = model.loss_and_grads(&store.params, &batch, 1).unwrap();
        let (l8, g8) = model.loss_and_grads(&store.params, &batch, 8).unwrap();
        assert_eq!(l1.to_bits(), l8.to_bits(), "{}: loss differs by thread count", kind.as_str());
        for (a, b) in g1.iter().zip(&g8) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}: grad bits differ", kind.as_str());
            }
        }
    }
}

#[test]
fn hash_frontend_scratch_reuse_matches_fresh_allocation() {
    // A model's step scratch is recycled across calls; the second call on
    // a warm model must produce the same bits as the first call on a
    // fresh one.
    for kind in KINDS {
        let build = tiny_mb_build(false);
        let manifest = build.manifest_hash(&tiny_fe(kind));
        let store = ParamStore::init(&manifest, 43);
        let warmup = mb_clf_batch(&build, 50);
        let batch = mb_clf_batch(&build, 51);

        let warm = model_for(&manifest);
        warm.loss_and_grads(&store.params, &warmup, 2).unwrap();
        let (lw, gw) = warm.loss_and_grads(&store.params, &batch, 2).unwrap();

        let fresh = model_for(&manifest);
        let (lf, gf) = fresh.loss_and_grads(&store.params, &batch, 2).unwrap();

        assert_eq!(lw.to_bits(), lf.to_bits(), "{}: warm loss != fresh loss", kind.as_str());
        for (a, b) in gw.iter().zip(&gf) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}: scratch reuse changed grads", kind.as_str());
            }
        }
    }
}

#[test]
fn poshash_refuses_to_run_without_a_position_map() {
    let build = tiny_mb_build(false);
    let manifest = build.manifest_hash(&tiny_fe(HashKind::Pos));
    let model = NativeModel::from_manifest(&manifest).unwrap();
    assert!(model.needs_pos_map());
    let store = ParamStore::init(&manifest, 1);
    let err = model.loss_and_grads(&store.params, &mb_clf_batch(&build, 1), 1).unwrap_err();
    assert!(format!("{err}").contains("position map"), "{err}");
    // Binding a wrong-length map is rejected; the right one is accepted
    // and rebinding the same map is idempotent.
    assert!(model.bind_pos_map(Arc::new(vec![0u32; 5])).is_err());
    let map = test_pos_map(&manifest);
    model.bind_pos_map(map.clone()).unwrap();
    model.bind_pos_map(map).unwrap();
    // A *different* map cannot silently replace the bound one.
    let other = Arc::new(vec![0u32; build.n]);
    assert!(model.bind_pos_map(other).is_err());
    // Non-poshash front-ends refuse any map.
    let bloom = NativeModel::from_manifest(&tiny_mb_build(false).manifest_hash(&tiny_fe(HashKind::Bloom))).unwrap();
    assert!(!bloom.needs_pos_map());
    assert!(bloom.bind_pos_map(Arc::new(vec![0u32; 30])).is_err());
}
