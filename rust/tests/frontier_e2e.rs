//! End-to-end frontier sweep: train every front-end in the family —
//! coded (`hash`), uncompressed (`nc`), and the three hash-embedding
//! competitors — on the same Table-1 SBM analog at matched byte budgets,
//! and check the emitted accuracy-vs-bytes frontier is complete.

use hashgnn::ser;
use hashgnn::tasks::frontier::{self, FrontierOpts};
use hashgnn::tasks::nodeclf::{Frontend, RunOpts};
use hashgnn::tasks::T1Dataset;

#[test]
fn frontier_sweep_is_complete_and_every_coder_learns() {
    // Products is the strongest-community analog — every front-end that
    // works at all clears chance comfortably in few epochs.
    let opts = FrontierOpts {
        coders: Frontend::frontier().to_vec(),
        dataset: T1Dataset::Products,
        run: RunOpts { epochs: 20, eval_every: 5, seed: 7 },
        threads: 0,
        ..FrontierOpts::default()
    };
    let rows = frontier::run_frontier(&opts).unwrap();

    // Monotone-complete: one row per requested coder, in request order.
    assert_eq!(rows.len(), opts.coders.len());
    for (row, &fe) in rows.iter().zip(&opts.coders) {
        assert_eq!(row.coder, frontier::coder_label(fe));
        assert_eq!(row.front_end, fe.artifact_tag());
        assert!(row.bytes > 0, "{}: empty byte cost", row.coder);
        assert!(row.loss.is_finite(), "{}: non-finite loss", row.coder);
        // 8-class SBM → chance is 0.125; every front-end must beat it
        // with margin on the easiest analog.
        assert!(
            row.acc > 1.5 / 8.0,
            "{}: acc {:.3} does not clear 1.5× chance",
            row.coder,
            row.acc
        );
    }

    // Bytes-fair: no hash front-end exceeds the coded budget it was
    // matched against, and nc reports the raw table.
    let coded = rows.iter().find(|r| r.coder == "hash").unwrap().bytes;
    let nc = rows.iter().find(|r| r.coder == "nc").unwrap().bytes;
    assert_eq!(nc, 4 * 1024 * 64);
    for r in rows.iter().filter(|r| r.front_end != "coded" && r.front_end != "nc") {
        assert!(r.bytes <= coded, "{}: {} > coded budget {coded}", r.coder, r.bytes);
    }

    // The JSON artifact carries every row with non-empty fields.
    let json = frontier::rows_to_json(&rows, &opts);
    let text = ser::to_string_compact(&json);
    assert!(text.contains("\"bench\":\"frontier\""), "{text}");
    for fe in Frontend::frontier() {
        assert!(
            text.contains(&format!("\"coder\":\"{}\"", frontier::coder_label(fe))),
            "missing row for {} in {text}",
            frontier::coder_label(fe)
        );
    }
    assert!(text.contains("\"bytes\":"), "{text}");
    assert!(text.contains("\"acc\":"), "{text}");
}

#[test]
fn frontier_quick_smoke_matches_ci_contract() {
    // The `--quick` config CI runs: two coders, short budget. Keep this
    // test a faithful mirror of scripts/ci wiring.
    let mut opts = FrontierOpts::quick();
    opts.threads = 0;
    assert_eq!(opts.coders, vec![Frontend::Nc, Frontend::Bloom]);
    let rows = frontier::run_frontier(&opts).unwrap();
    assert_eq!(rows.len(), 2);
    for row in &rows {
        assert!(row.acc > 1.0 / 8.0, "{}: quick run below chance", row.coder);
        assert!(row.bytes > 0);
    }
}

#[test]
fn frontier_rejects_empty_and_linkpred_configs() {
    let mut opts = FrontierOpts::default();
    opts.coders.clear();
    assert!(frontier::run_frontier(&opts).is_err());
    let mut opts = FrontierOpts::quick();
    opts.dataset = T1Dataset::Collab;
    let err = frontier::run_frontier(&opts).unwrap_err();
    assert!(format!("{err}").contains("link-prediction"), "{err}");
}
