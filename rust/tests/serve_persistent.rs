//! Sharded serving + persistent server loop, end to end:
//!
//! 1. `export --shards K` semantics: a [`ShardRouter`] over the split
//!    bundles serves embeddings, scores and class predictions
//!    **bit-identically** to the unsharded [`ServeSession`] at thread
//!    counts {1, 8}, for every model family (decoder, minibatch SAGE
//!    coded + NC, full-batch GNN);
//! 2. shard files round-trip through the `HGNS0001` header, corruption
//!    and truncation fail loudly, and incomplete/mixed shard sets are
//!    constructor errors;
//! 3. the NDJSON persistent loop survives a multi-request piped session
//!    — batching across requests, demuxing per request, answering
//!    errors in position, reporting exact flush/coalescing counters —
//!    and a sharded backend produces byte-identical response lines;
//! 4. latency-budget and fill triggers fire through the real loop (the
//!    pure state-machine cases live in `serve/batcher.rs` unit tests).

use std::io::Cursor;
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::time::Duration;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::spec::{FullBatchBuild, ReconBuild, SageMbBuild};
use hashgnn::ser;
use hashgnn::serve::server::{run_loop, run_ndjson};
use hashgnn::serve::{
    load_backend, ServeOpts, ServeSession, ServerCfg, Serving, ServingBundle, ShardRouter,
};
use hashgnn::tasks::coding::{make_codes, Aux};

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn opts(threads: usize) -> ServeOpts {
    ServeOpts { threads, cache_capacity: 64, seed: 5, ..Default::default() }
}

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join("hashgnn_serve_persistent");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------------------------------------------------------------------------
// Bundle builders (one per model family)
// ---------------------------------------------------------------------------

fn recon_bundle() -> ServingBundle {
    let m = ReconBuild {
        name: "sp_recon".into(),
        c: 4,
        m: 3,
        d_c: 5,
        d_m: 6,
        d_e: 2,
        l: 2,
        light: false,
        batch: 3,
        optim: OptimCfg::adamw_default(),
    }
    .manifest();
    let store = ParamStore::init(&m, 4);
    let graph = sbm(SbmCfg::new(30, 3, 6.0, 2.0), 11).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 11).unwrap();
    ServingBundle::new(m, &store, Some(codes), vec![], 30).unwrap()
}

fn sage_bundle(coded: bool) -> ServingBundle {
    let build = SageMbBuild {
        name: "sp_mb".into(),
        coded,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let codes = if coded {
        Some(
            make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 9)
                .unwrap(),
        )
    } else {
        None
    };
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, codes, graph.undirected_edges(), 60).unwrap()
}

fn fb_bundle() -> ServingBundle {
    let build = FullBatchBuild {
        name: "sp_fb".into(),
        gnn: GnnKind::Gcn,
        coded: true,
        link: false,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 4, 8.0, 2.0), 3).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 5).unwrap(), 3).unwrap();
    let store = ParamStore::init(&manifest, 21);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

// ---------------------------------------------------------------------------
// 1. Sharded vs unsharded bit-parity
// ---------------------------------------------------------------------------

/// Query ids spanning every shard of an n-node split, with duplicates
/// and both range boundaries.
fn spanning_ids(n: u32) -> Vec<u32> {
    vec![0, n - 1, n / 2, 1, n / 2, n / 3, 2 * n / 3, 0, n - 2]
}

fn assert_shard_parity(bundle: &ServingBundle, k: usize, classes: bool) {
    let n = bundle.n_nodes as u32;
    let ids = spanning_ids(n);
    let edges = [(0u32, n - 1), (n / 2, 1), (n - 1, n - 1)];
    for threads in [1usize, 8] {
        let mut base = ServeSession::new(bundle.clone(), opts(threads)).unwrap();
        let mut router = ShardRouter::new(bundle.split_shards(k).unwrap(), opts(threads)).unwrap();
        assert_eq!(router.n_shards(), k);

        let a = base.embed_nodes(&ids).unwrap();
        let b = router.embed_nodes(&ids).unwrap();
        assert!(bits_equal(&a, &b), "threads {threads}: sharded embeddings changed bytes");

        let sa = base.score_edges(&edges).unwrap();
        let sb = router.score_edges(&edges).unwrap();
        assert!(bits_equal(&sa, &sb), "threads {threads}: sharded scores changed bytes");

        if classes {
            let (la, ca) = base.predict_classes(&ids).unwrap();
            let (lb, cb) = router.predict_classes(&ids).unwrap();
            assert!(bits_equal(&la, &lb), "threads {threads}: sharded logits changed bytes");
            assert_eq!(ca, cb);
        }
    }
}

#[test]
fn decoder_shards_serve_bit_identically() {
    assert_shard_parity(&recon_bundle(), 3, false);
}

#[test]
fn sage_coded_shards_serve_bit_identically() {
    assert_shard_parity(&sage_bundle(true), 3, true);
}

#[test]
fn sage_nc_shards_serve_bit_identically() {
    assert_shard_parity(&sage_bundle(false), 2, true);
}

#[test]
fn fullbatch_shards_serve_bit_identically() {
    assert_shard_parity(&fb_bundle(), 2, true);
}

#[test]
fn fanout_modes_serve_identical_bytes_and_report_width() {
    let bundle = sage_bundle(true);
    let ids = spanning_ids(60);
    // Same split, same threads — only the dispatch strategy differs.
    let mut par = ShardRouter::new(bundle.split_shards(3).unwrap(), opts(2)).unwrap();
    let mut seq = ShardRouter::new(
        bundle.split_shards(3).unwrap(),
        ServeOpts { fanout: false, ..opts(2) },
    )
    .unwrap();
    let a = par.embed_nodes(&ids).unwrap();
    let b = seq.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&a, &b), "parallel fan-out changed served bytes");
    // The routers report how the flush was dispatched: width = active
    // shards when parallel, 1 when sequential; one wait per active shard
    // either way. The report drains on take.
    let ra = par.take_fanout_report().expect("parallel flush reports");
    assert_eq!(ra.width, 3);
    assert_eq!(ra.shard_wait_us.len(), 3);
    assert!(par.take_fanout_report().is_none(), "report drains on take");
    let rb = seq.take_fanout_report().expect("sequential flush reports too");
    assert_eq!(rb.width, 1);
    assert_eq!(rb.shard_wait_us.len(), 3);
    // A single-shard sub-request never fans out, whatever the mode.
    par.embed_nodes(&[0, 1]).unwrap();
    assert_eq!(par.take_fanout_report().unwrap().width, 1);
    // The NDJSON stats line surfaces the width and the shard-wait
    // percentiles the flush recorded.
    let cfg =
        ServerCfg { max_batch: 1000, max_delay: Duration::from_secs(60), ..Default::default() };
    let input = concat!(
        "{\"op\": \"embed\", \"nodes\": [0, 25, 55]}\n",
        "{\"op\": \"stats\"}\n",
        "{\"op\": \"shutdown\"}\n",
    );
    let lines = run_session(&mut par, &cfg, input);
    assert_eq!(lines[1].get("fanout_width").unwrap().as_usize().unwrap(), 3);
    assert!(lines[1].get("shard_wait_p50_us").is_ok());
    assert!(lines[1].get("shard_wait_p99_us").is_ok());
}

/// A 60-node ring sage bundle: the two-hop closure of a 20-node owned
/// range is provably 24 nodes, so slicing is verifiable exactly.
fn ring_sage_bundle() -> ServingBundle {
    let build = SageMbBuild {
        name: "sp_ring".into(),
        coded: true,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let edges: Vec<(u32, u32)> = (0..60u32).map(|i| (i, (i + 1) % 60)).collect();
    let codes = hashgnn::codes::random_codes(60, CodingCfg::new(4, 3).unwrap(), 17);
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, Some(codes), edges, 60).unwrap()
}

#[test]
fn sage_shards_slice_edges_and_codes() {
    let bundle = ring_sage_bundle();
    let shards = bundle.split_shards(3).unwrap();
    // Middle shard owns [20, 40): edges touch owned ∪ N(owned) =
    // {19..=40} (23 of 60 ring edges), codes cover the two-hop closure
    // {18..=41} (24 of 60 nodes).
    let mid = &shards[1];
    let info = mid.shard.as_ref().unwrap();
    assert_eq!((info.lo, info.hi), (20, 40));
    assert_eq!(mid.edges.len(), 23, "edge slice = incident to owned ∪ N(owned)");
    assert_eq!(info.present.len(), 24, "code closure = owned ∪ 2-hop neighborhood");
    assert_eq!(info.present.first().copied(), Some(18));
    assert_eq!(info.present.last().copied(), Some(41));
    assert_eq!(mid.codes.as_ref().unwrap().n(), 24);
    // The split still serves bit-identically.
    assert_shard_parity(&bundle, 3, true);
    // A shard session refuses ids outside its owned range instead of
    // serving them wrong.
    let mut s1 = ServeSession::new(mid.clone(), opts(1)).unwrap();
    let (lo, hi) = s1.owned_range();
    assert!(s1.embed_nodes(&[lo]).is_ok());
    let err = s1.embed_nodes(&[hi]).unwrap_err();
    assert!(format!("{err}").contains("owned range"), "{err}");
}

// ---------------------------------------------------------------------------
// 2. Shard file round-trip, corruption, set validation
// ---------------------------------------------------------------------------

#[test]
fn shard_files_roundtrip_and_reject_corruption() {
    let bundle = sage_bundle(true);
    let shards = bundle.split_shards(2).unwrap();
    let dir = tmpdir();
    let paths: Vec<PathBuf> =
        (0..2).map(|i| dir.join(format!("mb.bundle.shard-{i}-of-2"))).collect();
    for (s, p) in shards.iter().zip(&paths) {
        s.save(p).unwrap();
    }
    // Round-trip: the router loads the set and serves parity bytes.
    let mut router = ShardRouter::load(&paths, opts(1)).unwrap();
    let mut base = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    let ids = spanning_ids(60);
    assert!(bits_equal(&base.embed_nodes(&ids).unwrap(), &router.embed_nodes(&ids).unwrap()));

    // Corrupt one payload byte: the per-file checksum catches it.
    let mut bytes = std::fs::read(&paths[0]).unwrap();
    let mid = 24 + (bytes.len() - 24) / 2;
    bytes[mid] ^= 0x40;
    let bad = dir.join("corrupt.shard");
    std::fs::write(&bad, &bytes).unwrap();
    let err = ServingBundle::load(&bad).unwrap_err();
    assert!(format!("{err}").contains("checksum"), "{err}");
    // Truncation dies on the size check.
    let whole = std::fs::read(&paths[0]).unwrap();
    std::fs::write(&bad, &whole[..whole.len() / 2]).unwrap();
    assert!(ServingBundle::load(&bad).is_err());

    // Incomplete set: one shard alone is rejected by the loader...
    let err = load_backend(&paths[..1], opts(1)).unwrap_err();
    assert!(format!("{err}").contains("pass all"), "{err}");
    // ...and by the router.
    let one = ServingBundle::load(&paths[0]).unwrap();
    assert!(ShardRouter::new(vec![one.clone()], opts(1)).is_err());
    // Duplicated index.
    assert!(ShardRouter::new(vec![one.clone(), one.clone()], opts(1)).is_err());
    // Mixed exports (different manifest).
    let other = fb_bundle().split_shards(2).unwrap();
    assert!(ShardRouter::new(vec![one, other[1].clone()], opts(1)).is_err());
    // A whole-graph bundle is not a shard.
    assert!(ShardRouter::new(vec![bundle], opts(1)).is_err());
}

// ---------------------------------------------------------------------------
// 3. Persistent NDJSON loop e2e
// ---------------------------------------------------------------------------

const SESSION_INPUT: &str = concat!(
    "{\"op\": \"embed\", \"nodes\": [1, 2, 1], \"id\": \"a\"}\n",
    "{\"op\": \"score\", \"edges\": [[1, 2], [3, 4]], \"id\": \"b\"}\n",
    "{\"op\": \"classes\", \"nodes\": [2, 3]}\n",
    "this is not json\n",
    "{\"op\": \"embed\", \"nodes\": [999]}\n",
    "{\"op\": \"stats\"}\n",
    "{\"op\": \"shutdown\"}\n",
);

fn run_session(backend: &mut dyn Serving, cfg: &ServerCfg, input: &str) -> Vec<ser::Json> {
    let mut out: Vec<u8> = Vec::new();
    run_ndjson(backend, cfg, Cursor::new(input.as_bytes().to_vec()), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    text.lines().map(|l| ser::parse(l).expect("every output line is JSON")).collect()
}

#[test]
fn persistent_loop_survives_a_mixed_session_with_exact_counters() {
    let bundle = fb_bundle();
    let mut session = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    // Huge budget + huge fill: the whole session flushes once, at the
    // stats drain, which makes every counter deterministic.
    let cfg = ServerCfg { max_batch: 1000, max_delay: Duration::from_secs(60), ..Default::default() };
    let lines = run_session(&mut session, &cfg, SESSION_INPUT);
    assert_eq!(lines.len(), 7, "one response line per input line");

    // Responses in request order, echoes attached.
    assert_eq!(lines[0].get("op").unwrap().as_str().unwrap(), "embed");
    assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
    assert_eq!(lines[1].get("op").unwrap().as_str().unwrap(), "score");
    assert_eq!(lines[1].get("id").unwrap().as_str().unwrap(), "b");
    assert_eq!(lines[2].get("op").unwrap().as_str().unwrap(), "classes");
    assert!(lines[3].get("error").is_ok(), "malformed JSON answers in position");
    let msg = lines[4].get("error").unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("out of range"), "{msg}");
    assert_eq!(lines[6].get("op").unwrap().as_str().unwrap(), "shutdown");

    // Served embeddings equal a fresh session's bytes (batching across
    // requests never changes values).
    let mut fresh = ServeSession::new(bundle, opts(1)).unwrap();
    let expect = fresh.embed_nodes(&[1, 2, 1]).unwrap();
    let d = fresh.embed_dim();
    let rows = lines[0].get("embeddings").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 3);
    for (i, row) in rows.iter().enumerate() {
        let got = row.as_f64_vec().unwrap();
        assert_eq!(got.len(), d);
        for (j, &g) in got.iter().enumerate() {
            assert_eq!(g, expect[i * d + j] as f64, "row {i} dim {j}");
        }
    }

    // Exact counters: 9 node references (3 + 4 + 2), 4 distinct → 5
    // coalesced away; one drain flush (the stats barrier); 6 requests
    // seen by then; 3 data responses + the stats response itself; 2
    // errors.
    let stats = &lines[5];
    assert_eq!(stats.get("op").unwrap().as_str().unwrap(), "stats");
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 6);
    assert_eq!(stats.get("responses").unwrap().as_usize().unwrap(), 4);
    assert_eq!(stats.get("errors").unwrap().as_usize().unwrap(), 2);
    assert_eq!(stats.get("flushes").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("drain_flushes").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("fill_flushes").unwrap().as_usize().unwrap(), 0);
    assert_eq!(stats.get("budget_expiries").unwrap().as_usize().unwrap(), 0);
    assert_eq!(stats.get("coalesced_nodes").unwrap().as_usize().unwrap(), 5);
    assert_eq!(stats.get("unique_nodes").unwrap().as_usize().unwrap(), 4);
    assert!(stats.get("cache").unwrap().get("misses").is_ok());
}

#[test]
fn sharded_backend_answers_a_session_byte_identically() {
    let bundle = fb_bundle();
    let cfg = ServerCfg { max_batch: 1000, max_delay: Duration::from_secs(60), ..Default::default() };
    let mut session = ServeSession::new(bundle.clone(), opts(1)).unwrap();
    let mut router = ShardRouter::new(bundle.split_shards(2).unwrap(), opts(1)).unwrap();
    let a = run_session(&mut session, &cfg, SESSION_INPUT);
    let b = run_session(&mut router, &cfg, SESSION_INPUT);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        if i == 5 {
            // The stats line differs only in the backend's cache object
            // (the router reports per-shard aggregates + a shard count).
            assert_eq!(
                x.get("coalesced_nodes").unwrap(),
                y.get("coalesced_nodes").unwrap()
            );
            assert_eq!(x.get("flushes").unwrap(), y.get("flushes").unwrap());
            continue;
        }
        assert_eq!(x, y, "response line {i} differs between sharded and unsharded");
    }
}

#[test]
fn fill_trigger_flushes_midstream() {
    let bundle = recon_bundle();
    let mut session = ServeSession::new(bundle, opts(1)).unwrap();
    // 3 distinct pending ids force a fill flush before EOF.
    let cfg = ServerCfg { max_batch: 3, max_delay: Duration::from_secs(60), ..Default::default() };
    let input = concat!(
        "{\"op\": \"embed\", \"nodes\": [0, 1, 2]}\n",
        "{\"op\": \"embed\", \"nodes\": [3]}\n",
        "{\"op\": \"stats\"}\n",
    );
    let lines = run_session(&mut session, &cfg, input);
    assert_eq!(lines.len(), 3);
    let stats = &lines[2];
    assert_eq!(stats.get("fill_flushes").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("drain_flushes").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("flushes").unwrap().as_usize().unwrap(), 2);
    assert_eq!(stats.get("unique_nodes").unwrap().as_usize().unwrap(), 4);
}

#[test]
fn latency_budget_flushes_while_the_connection_stays_open() {
    let bundle = recon_bundle();
    let mut session = ServeSession::new(bundle, opts(1)).unwrap();
    let cfg = ServerCfg { max_batch: 1000, max_delay: Duration::from_millis(20), ..Default::default() };
    let (tx, rx) = channel::<std::io::Result<String>>();
    tx.send(Ok("{\"op\": \"embed\", \"nodes\": [5]}\n".to_string())).unwrap();
    // A slow follower: the first request's budget must expire long before
    // this arrives, even though the channel never closes in between.
    let follower = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(300));
        tx.send(Ok("{\"op\": \"shutdown\"}\n".to_string())).unwrap();
    });
    let mut out: Vec<u8> = Vec::new();
    let stats = run_loop(&mut session, &cfg, &rx, &mut out).unwrap();
    follower.join().unwrap();
    assert_eq!(stats.batch.budget_expiries, 1, "budget fired while idle-but-open");
    assert_eq!(stats.batch.fill_flushes, 0);
    assert_eq!(stats.batch.drain_flushes, 0, "shutdown found an empty queue");
    let text = String::from_utf8(out).unwrap();
    assert_eq!(text.lines().count(), 2, "embed response + shutdown ack");
}

// ---------------------------------------------------------------------------
// 4. TCP mode over a real socket
// ---------------------------------------------------------------------------

#[test]
fn tcp_listener_serves_one_ndjson_connection() {
    use std::io::{BufRead, BufReader, Write};

    let bundle = recon_bundle();
    let mut session = ServeSession::new(bundle, opts(1)).unwrap();
    let cfg = ServerCfg { max_batch: 8, max_delay: Duration::from_millis(5), ..Default::default() };
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .write_all(
                b"{\"op\": \"embed\", \"nodes\": [1, 2]}\n{\"op\": \"score\", \"edges\": [[1, 2]]}\n{\"op\": \"shutdown\"}\n",
            )
            .unwrap();
        stream.flush().unwrap();
        let mut lines = Vec::new();
        let mut reader = BufReader::new(stream);
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            lines.push(line);
        }
        lines
    });

    let stats =
        hashgnn::serve::server::serve_listener(listener, &mut session, &cfg, 1).unwrap();
    let lines = client.join().unwrap();
    assert_eq!(lines.len(), 3);
    let first = ser::parse(&lines[0]).unwrap();
    assert_eq!(first.get("op").unwrap().as_str().unwrap(), "embed");
    let last = ser::parse(&lines[2]).unwrap();
    assert_eq!(last.get("op").unwrap().as_str().unwrap(), "shutdown");
    assert_eq!(stats.requests, 3);
    assert!(stats.batch.flushes >= 1);
}
