//! Serving subsystem end-to-end: bundle export → load → session, with
//! the three invariants the serving layer must preserve on top of the
//! model-level parity (`tests/infer_parity.rs` proves InferModel ==
//! training forward):
//!
//! 1. the cache never changes bytes (cold == warm == any grouping);
//! 2. thread counts never change bytes (threads 1 == 8);
//! 3. the batcher/cache bookkeeping is exact (capacity bound, LRU
//!    eviction order, hit/miss counts).

use std::sync::Arc;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::Graph;
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::infer::InferModel;
use hashgnn::runtime::native::spec::{self, FullBatchBuild, SageMbBuild};
use hashgnn::runtime::Tensor;
use hashgnn::serve::{ServeOpts, ServeSession, ServingBundle};
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::serve::{export_bundle, ExportOpts};

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

// ---------------------------------------------------------------------------
// Full-batch session
// ---------------------------------------------------------------------------

fn fb_bundle(link: bool) -> ServingBundle {
    let build = FullBatchBuild {
        name: "e2e_fb".into(),
        gnn: GnnKind::Gcn,
        coded: true,
        link,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 4, 8.0, 2.0), 3).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 5).unwrap(), 3).unwrap();
    let store = ParamStore::init(&manifest, 21);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

fn session(bundle: ServingBundle, threads: usize, cache: usize) -> ServeSession {
    ServeSession::new(bundle, ServeOpts { threads, cache_capacity: cache, seed: 5, ..Default::default() }).unwrap()
}

#[test]
fn fullbatch_session_matches_infer_model_bitwise() {
    let bundle = fb_bundle(false);
    // Reference: the InferModel over the same rebuilt adjacency + codes.
    let rebuilt = Graph::from_edge_iter(bundle.n_nodes, bundle.edges.iter()).unwrap();
    let adj = Arc::new(
        rebuilt.adj().normalized(bundle.manifest.hyper_str("adj").unwrap()).unwrap(),
    );
    let im = InferModel::from_manifest(&bundle.manifest).unwrap();
    im.bind_adjacency(adj).unwrap();
    let codes = bundle.codes.as_ref().unwrap();
    let ids_all: Vec<u32> = (0..60).collect();
    let mut buf = Vec::new();
    codes.gather_int_codes(&ids_all, &mut buf);
    let codes_t = Tensor::i32(vec![60, 5], buf).unwrap();
    let params = bundle.params.to_tensors().unwrap();
    let h_ref = im.embed_nodes(&params, &[codes_t.clone()], 1).unwrap();
    let h_ref = h_ref.as_f32().unwrap();
    let d = im.embed_dim();

    let mut s = session(bundle.clone(), 1, 32);
    let query = [7u32, 0, 59, 7];
    let served = s.embed_nodes(&query).unwrap();
    for (i, &id) in query.iter().enumerate() {
        assert!(
            bits_equal(
                &served[i * d..(i + 1) * d],
                &h_ref[id as usize * d..(id as usize + 1) * d]
            ),
            "served row {i} (node {id}) != full-batch forward row"
        );
    }
    // Edge scores through the cache == edge_dot over the same H rows ==
    // the training link scorer's math.
    let edges = [(7u32, 0u32), (59, 59)];
    let scores = s.score_edges(&edges).unwrap();
    for (k, &(u, v)) in edges.iter().enumerate() {
        let (u, v) = (u as usize, v as usize);
        let mut acc = 0.0f32;
        for j in 0..d {
            acc += h_ref[u * d + j] * h_ref[v * d + j];
        }
        assert_eq!(scores[k].to_bits(), acc.to_bits());
    }
    // Class predictions equal the full-batch head over the same rows.
    let logits_ref = im.predict_classes(&params, &[codes_t], 1).unwrap();
    let logits_ref = logits_ref.as_f32().unwrap();
    let k = 4usize;
    let (logits, classes) = s.predict_classes(&query).unwrap();
    assert_eq!(classes.len(), 4);
    for (i, &id) in query.iter().enumerate() {
        assert!(
            bits_equal(
                &logits[i * k..(i + 1) * k],
                &logits_ref[id as usize * k..(id as usize + 1) * k]
            ),
            "served logits for node {id} != full-batch head output"
        );
    }
}

#[test]
fn fullbatch_link_session_scores_and_rejects_classes() {
    let bundle = fb_bundle(true);
    let mut s = session(bundle, 2, 16);
    let scores = s.score_edges(&[(1, 2), (3, 4)]).unwrap();
    assert_eq!(scores.len(), 2);
    assert!(scores.iter().all(|v| v.is_finite()));
    assert!(s.predict_classes(&[1]).is_err(), "link models have no class head");
}

#[test]
fn serving_is_cache_grouping_and_thread_invariant() {
    let bundle = fb_bundle(false);
    let query = [3u32, 11, 3, 42, 0];
    // Cold (cache disabled), warm (cached, queried twice), threaded, and
    // one-by-one sessions must all serve identical bytes.
    let mut cold = session(bundle.clone(), 1, 0);
    let a = cold.embed_nodes(&query).unwrap();
    let mut warm = session(bundle.clone(), 1, 64);
    let b1 = warm.embed_nodes(&query).unwrap();
    let b2 = warm.embed_nodes(&query).unwrap();
    let mut threaded = session(bundle.clone(), 8, 64);
    let c = threaded.embed_nodes(&query).unwrap();
    let mut one_by_one = session(bundle.clone(), 1, 64);
    let mut d_out = Vec::new();
    for &id in &query {
        d_out.extend(one_by_one.embed_nodes(&[id]).unwrap());
    }
    assert!(bits_equal(&a, &b1), "cold vs warm first pass");
    assert!(bits_equal(&b1, &b2), "first vs second (cached) pass");
    assert!(bits_equal(&a, &c), "threads 1 vs 8");
    assert!(bits_equal(&a, &d_out), "batched vs one-by-one");
    // Counter bookkeeping: 5 lookups, 4 unique entries; second pass all hits.
    let s = warm.cache_stats();
    assert_eq!((s.misses, s.hits, s.len), (5, 5, 4));
}

#[test]
fn cache_eviction_respects_capacity_in_a_live_session() {
    let bundle = fb_bundle(false);
    let mut s = session(bundle, 1, 2);
    let full = s.embed_nodes(&[1, 2, 3]).unwrap();
    let st = s.cache_stats();
    assert_eq!(st.len, 2, "capacity is a hard bound");
    assert_eq!(st.misses, 3);
    assert_eq!(st.evictions, 1, "inserting the third entry evicts the LRU");
    // 1 was evicted (oldest insert); 2 and 3 are resident.
    let again = s.embed_nodes(&[2, 3, 1]).unwrap();
    let st = s.cache_stats();
    assert_eq!(st.hits, 2, "2 and 3 hit");
    assert_eq!(st.misses, 4, "1 recomputed");
    // Bytes unchanged regardless of the eviction churn.
    assert!(bits_equal(&full[0..s.embed_dim()], &again[2 * s.embed_dim()..]));
}

// ---------------------------------------------------------------------------
// Minibatch SAGE session (per-node seeded fan-out)
// ---------------------------------------------------------------------------

fn sage_bundle(coded: bool) -> ServingBundle {
    let build = SageMbBuild {
        name: "e2e_mb".into(),
        coded,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let codes = if coded {
        Some(
            make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 9)
                .unwrap(),
        )
    } else {
        None
    };
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, codes, graph.undirected_edges(), 60).unwrap()
}

#[test]
fn sage_session_embeddings_are_request_grouping_invariant() {
    for coded in [true, false] {
        let bundle = sage_bundle(coded);
        let query = [10u32, 3, 55, 10, 7, 21];
        let mut batched = session(bundle.clone(), 1, 64);
        let a = batched.embed_nodes(&query).unwrap();
        // Per-node seeded sampling: each node's neighborhood is a function
        // of (seed, id) only, so serving one node at a time — different
        // batch composition, different padding — yields identical bytes.
        let mut single = session(bundle.clone(), 1, 0);
        let mut b = Vec::new();
        for &id in &query {
            b.extend(single.embed_nodes(&[id]).unwrap());
        }
        assert!(bits_equal(&a, &b), "coded={coded}: grouping changed served bytes");
        let mut threaded = session(bundle.clone(), 8, 64);
        let c = threaded.embed_nodes(&query).unwrap();
        assert!(bits_equal(&a, &c), "coded={coded}: threads changed served bytes");
        // Warm replay.
        let a2 = batched.embed_nodes(&query).unwrap();
        assert!(bits_equal(&a, &a2), "coded={coded}: cache changed served bytes");
        // Classes come from the head over the served representations.
        let (logits, classes) = batched.predict_classes(&query[..2]).unwrap();
        assert_eq!(logits.len(), 2 * 3);
        assert_eq!(classes.len(), 2);
        assert!(classes.iter().all(|&c| c < 3));
    }
}

// ---------------------------------------------------------------------------
// Export → save → load → serve (registry model, the CLI path's core)
// ---------------------------------------------------------------------------

#[test]
fn export_roundtrip_serves_registry_model() {
    let manifest = spec::builtin("node_fb_sgc_coded").unwrap();
    let store = ParamStore::init(&manifest, 7);
    let opts = ExportOpts {
        coder: Coder::Hash,
        codes_file: None,
        seed: 7,
        quant: hashgnn::serve::Quant::F32,
        legacy_v1: false,
    };
    let bundle = export_bundle(&manifest, &store, &opts).unwrap();
    assert_eq!(bundle.n_nodes, 1024);
    assert!(bundle.code_bytes() > 0);

    let dir = std::env::temp_dir().join("hashgnn_serve_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sgc.bundle");
    bundle.save(&path).unwrap();
    let loaded = ServingBundle::load(&path).unwrap();

    let mut s1 = session(loaded.clone(), 1, 16);
    let mut s8 = session(loaded, 8, 16);
    let ids = [0u32, 5, 1023];
    let e1 = s1.embed_nodes(&ids).unwrap();
    let e8 = s8.embed_nodes(&ids).unwrap();
    assert!(bits_equal(&e1, &e8), "exported bundle serves thread-invariant bytes");
    assert!(e1.iter().all(|v| v.is_finite()));
    let (logits, classes) = s1.predict_classes(&ids).unwrap();
    assert_eq!(classes.len(), 3);
    assert_eq!(logits.len(), 3 * 8);
    let scores = s1.score_edges(&[(0, 5), (5, 1023)]).unwrap();
    assert!(scores.iter().all(|v| v.is_finite()));
}
