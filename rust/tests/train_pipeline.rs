//! Deterministic parallel training pipeline (PR 9 tier-1 proof).
//!
//! Three invariants, all bitwise and all on the native backend (no
//! artifacts needed):
//!
//! 1. **Pipeline knobs move time, not math** — the loss curve and final
//!    parameters of `train_sage_cfg` / `train_sage_link_cfg` are
//!    bit-identical across `sample_threads` ∈ {1, 2, 8}, `prefetch` ∈
//!    {1, 2, 4}, and pipelined vs serial, for both the §4 classification
//!    head and the link head.
//! 2. **Pooled sampling == single-stream reference** — a batcher with
//!    `sample_threads = t` emits the same tensors as `t = 1`, because
//!    each batch position draws from its own seed stream keyed by
//!    `(step, position)`, never by worker identity.
//! 3. **Scratch reuse == fresh allocation** — training with the
//!    step-scratch arena enabled (default) matches reuse-off runs
//!    bit-for-bit, on the minibatch decoder path and the full-batch GIN
//!    path (the deepest scratch user).

use std::sync::Arc;

use hashgnn::cfg::{CodingCfg, GnnKind, OptimCfg};
use hashgnn::codes::random_codes;
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::spec::{FullBatchBuild, SageMbBuild};
use hashgnn::runtime::{Model, Tensor};
use hashgnn::tasks::linkpred;
use hashgnn::tasks::sage::{self, Features, SageTask};
use hashgnn::train::PipeCfg;

const N: usize = 48;
const C: usize = 4;
const M: usize = 3;

fn sage_build(link: bool) -> SageMbBuild {
    SageMbBuild {
        name: "t_pipe".into(),
        coded: true,
        link,
        n: N,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: C,
        m: M,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn graph_and_codes(seed: u64) -> (Arc<hashgnn::graph::Graph>, Arc<hashgnn::codes::CodeTable>) {
    let g = Arc::new(sbm(SbmCfg::new(N, 3, 8.0, 2.0), seed).unwrap());
    let coding = CodingCfg::new(C, M).unwrap();
    let codes = Arc::new(random_codes(N, coding, seed ^ 0xC0DE));
    (g, codes)
}

fn clf_task(g: &Arc<hashgnn::graph::Graph>, codes: &Arc<hashgnn::codes::CodeTable>) -> SageTask {
    SageTask {
        graph: g.clone(),
        labels: Arc::new(g.labels().unwrap().to_vec()),
        features: Features::Codes(codes.clone()),
        train_nodes: Arc::new((0..N as u32).collect()),
    }
}

/// The full knob grid the acceptance criteria name: threads {1,2,8} ×
/// prefetch {1,2,4}, all pipelined, plus the serial reference.
fn knob_grid() -> Vec<PipeCfg> {
    let mut grid = Vec::new();
    for &t in &[1usize, 2, 8] {
        for &pf in &[1usize, 2, 4] {
            grid.push(PipeCfg { sample_threads: t, prefetch: pf, pipeline: true });
        }
    }
    grid
}

fn assert_bitwise_eq(reference: &[f32], got: &[f32], what: &str) {
    assert_eq!(reference.len(), got.len(), "{what}: length mismatch");
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: step {i} diverged ({a} vs {b})");
    }
}

#[test]
fn clf_loss_curve_is_bit_identical_across_all_pipeline_knobs() {
    let build = sage_build(false);
    let model = Model::native(build.manifest(), 2).unwrap();
    let (g, codes) = graph_and_codes(11);
    let serial = PipeCfg { sample_threads: 1, prefetch: 1, pipeline: false };
    let reference =
        sage::train_sage_cfg(&model, clf_task(&g, &codes), 1, &[], 5, 0, serial).unwrap();
    assert!(!reference.losses.is_empty());
    assert!(reference.losses.iter().all(|l| l.is_finite()));
    for cfg in knob_grid() {
        let run = sage::train_sage_cfg(&model, clf_task(&g, &codes), 1, &[], 5, 0, cfg).unwrap();
        assert_bitwise_eq(&reference.losses, &run.losses, &format!("clf losses {cfg:?}"));
        assert_eq!(reference.store.params, run.store.params, "clf params {cfg:?}");
        assert_eq!(reference.store.step, run.store.step);
    }
}

#[test]
fn link_loss_curve_is_bit_identical_across_all_pipeline_knobs() {
    let build = sage_build(true);
    let model = Model::native(build.manifest(), 2).unwrap();
    let (g, codes) = graph_and_codes(13);
    let edges = Arc::new(g.undirected_edges());
    let serial = PipeCfg { sample_threads: 1, prefetch: 1, pipeline: false };
    let (ref_store, ref_log) = linkpred::train_sage_link_cfg(
        &model,
        g.clone(),
        codes.clone(),
        edges.clone(),
        8,
        7,
        0,
        serial,
    )
    .unwrap();
    assert_eq!(ref_log.losses.len(), 8);
    for cfg in knob_grid() {
        let (store, log) = linkpred::train_sage_link_cfg(
            &model,
            g.clone(),
            codes.clone(),
            edges.clone(),
            8,
            7,
            0,
            cfg,
        )
        .unwrap();
        assert_bitwise_eq(&ref_log.losses, &log.losses, &format!("link losses {cfg:?}"));
        assert_eq!(ref_store.params, store.params, "link params {cfg:?}");
    }
}

#[test]
fn pooled_batcher_emits_the_single_stream_reference_tensors() {
    let build = sage_build(false);
    let model = Model::native(build.manifest(), 1).unwrap();
    let (g, codes) = graph_and_codes(17);
    let targets: Vec<u32> = (0..build.batch as u32).map(|i| i * 3 % N as u32).collect();
    let reference = sage::SageBatcher::new(clf_task(&g, &codes), &model, 3)
        .unwrap()
        .node_tensors(&targets, 0xFEED)
        .unwrap();
    for t in [2usize, 8, 0] {
        let pooled = sage::SageBatcher::new(clf_task(&g, &codes), &model, 3)
            .unwrap()
            .with_sample_threads(t)
            .node_tensors(&targets, 0xFEED)
            .unwrap();
        assert_eq!(reference, pooled, "sample_threads={t} changed the sampled batch");
    }
}

#[test]
fn scratch_reuse_matches_fresh_alloc_on_minibatch_paths() {
    let build = sage_build(false);
    let (g, codes) = graph_and_codes(19);
    let reuse = Model::native(build.manifest(), 2).unwrap();
    let fresh = Model::native(build.manifest(), 2).unwrap();
    fresh.set_scratch_reuse(false).unwrap();
    let cfg = PipeCfg::default();
    let a = sage::train_sage_cfg(&reuse, clf_task(&g, &codes), 1, &[], 9, 0, cfg).unwrap();
    let b = sage::train_sage_cfg(&fresh, clf_task(&g, &codes), 1, &[], 9, 0, cfg).unwrap();
    assert_bitwise_eq(&a.losses, &b.losses, "clf scratch parity");
    assert_eq!(a.store.params, b.store.params);
    assert_eq!(a.store.adam_m, b.store.adam_m);
    assert_eq!(a.store.adam_v, b.store.adam_v);
}

#[test]
fn scratch_reuse_matches_fresh_alloc_on_fullbatch_gin() {
    // GIN is the deepest scratch user (MLP per layer, ε-scaled skip); a
    // take/give imbalance or stale-buffer bug shows up here first.
    let m = FullBatchBuild {
        name: "t_fb_gin".into(),
        gnn: GnnKind::Gin,
        coded: false,
        link: false,
        n: 12,
        n_classes: 2,
        d_e: 3,
        hidden: 4,
        c: 4,
        m: 2,
        d_c: 3,
        d_m: 3,
        l: 2,
        light: false,
        e_train: 4,
        e_pred: 4,
        optim: OptimCfg::adamw_gnn(),
    }
    .manifest();
    let adj = Arc::new(
        hashgnn::sparse::Csr::from_edges(
            12,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (6, 7), (8, 9), (10, 11), (0, 6)],
        )
        .unwrap(),
    );
    let labels = Tensor::i32(vec![12], (0..12).map(|i| i % 2).collect()).unwrap();
    let mask = Tensor::f32(vec![12], vec![1.0; 12]).unwrap();
    let run = |reuse: bool| -> ParamStore {
        let model = Model::native(m.clone(), 3).unwrap();
        model.bind_adjacency(adj.clone()).unwrap();
        model.set_scratch_reuse(reuse).unwrap();
        let mut store = ParamStore::init(&m, 23);
        for _ in 0..4 {
            hashgnn::train::run_step(&model, &mut store, &[labels.clone(), mask.clone()])
                .unwrap();
        }
        store
    };
    let a = run(true);
    let b = run(false);
    assert_eq!(a.params, b.params, "scratch reuse changed full-batch GIN training");
    assert_eq!(a.adam_m, b.adam_m);
    assert_eq!(a.adam_v, b.adam_v);
}

#[test]
fn scratch_reuse_toggle_is_native_only() {
    let build = sage_build(false);
    let model = Model::native(build.manifest(), 1).unwrap();
    assert!(model.set_scratch_reuse(false).is_ok());
    assert!(model.set_scratch_reuse(true).is_ok());
}
