//! Native full-batch GNN grid verification (PR 3): finite-difference
//! gradient checks for GCN / SGC / GIN / full-batch SAGE with both
//! front-ends, bit-determinism across thread counts, registry loading,
//! and per-model end-to-end SBM runs asserting the paper's Table-1 shape
//! (hash codes beat random codes).
//!
//! Everything here runs with zero artifacts and zero dense adjacency: the
//! sparse CSR is bound to the model and propagation goes through
//! `Csr::spmm_row_major`.

use std::sync::Arc;

use hashgnn::cfg::{BackendKind, GnnKind, OptimCfg};
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::graph::Graph;
use hashgnn::params::ParamStore;
use hashgnn::rng::{Rng, Xoshiro256pp};
use hashgnn::runtime::native::spec::FullBatchBuild;
use hashgnn::runtime::native::NativeModel;
use hashgnn::runtime::{Engine, Model, Tensor};
use hashgnn::tasks::linkpred;
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};
use hashgnn::train;

// ---------------------------------------------------------------------------
// Builders
// ---------------------------------------------------------------------------

fn tiny_build(gnn: GnnKind, coded: bool, link: bool) -> FullBatchBuild {
    FullBatchBuild {
        name: format!("t_fb_{}", gnn.as_str()),
        gnn,
        coded,
        link,
        n: 20,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        e_train: 6,
        e_pred: 8,
        optim: OptimCfg::adamw_gnn(),
    }
}

fn tiny_graph(seed: u64) -> Graph {
    sbm(SbmCfg::new(20, 3, 4.0, 2.0), seed).unwrap()
}

fn codes_tensor(rows: usize, m: usize, c: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let data: Vec<i32> = (0..rows * m).map(|_| rng.index(c) as i32).collect();
    Tensor::i32(vec![rows, m], data).unwrap()
}

fn edges_tensor(e: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut data = Vec::with_capacity(e * 2);
    for _ in 0..e {
        let u = rng.index(n);
        let mut v = rng.index(n);
        while v == u {
            v = rng.index(n);
        }
        data.push(u as i32);
        data.push(v as i32);
    }
    Tensor::i32(vec![e, 2], data).unwrap()
}

/// `codes?, labels, mask` for a node-clf build.
fn clf_batch(build: &FullBatchBuild, seed: u64) -> Vec<Tensor> {
    let n = build.n;
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x51);
    let labels: Vec<i32> = (0..n).map(|_| rng.index(build.n_classes) as i32).collect();
    // ~2/3 of nodes masked in, at least one.
    let mut mask: Vec<f32> = (0..n).map(|_| if rng.index(3) < 2 { 1.0 } else { 0.0 }).collect();
    mask[0] = 1.0;
    let mut batch = Vec::new();
    if build.coded {
        batch.push(codes_tensor(n, build.m, build.c, seed));
    }
    batch.push(Tensor::i32(vec![n], labels).unwrap());
    batch.push(Tensor::f32(vec![n], mask).unwrap());
    batch
}

/// `codes?, pos_edges, neg_edges` for a link build.
fn link_batch(build: &FullBatchBuild, seed: u64) -> Vec<Tensor> {
    let mut batch = Vec::new();
    if build.coded {
        batch.push(codes_tensor(build.n, build.m, build.c, seed));
    }
    batch.push(edges_tensor(build.e_train, build.n, seed ^ 0xE1));
    batch.push(edges_tensor(build.e_train, build.n, seed ^ 0xE2));
    batch
}

fn bound_model(build: &FullBatchBuild, graph: &Graph, threads: usize) -> (Model, ParamStore) {
    let manifest = build.manifest();
    let adj = Arc::new(graph.adj().normalized(manifest.hyper_str("adj").unwrap()).unwrap());
    let store = ParamStore::init(&manifest, 11);
    let model = Model::native(manifest, threads).unwrap();
    model.bind_adjacency(adj).unwrap();
    (model, store)
}

// ---------------------------------------------------------------------------
// Finite-difference gradient checks
// ---------------------------------------------------------------------------

/// Same protocol as tests/native_backend.rs: agreement rate over sampled
/// coordinates, loose enough to absorb ReLU-kink noise, tight enough that
/// a wrong transpose / dropped term / missing mask cannot pass.
fn grad_check_fb(build: &FullBatchBuild, graph: &Graph, batch: &[Tensor], seed: u64) {
    let manifest = build.manifest();
    let model = NativeModel::from_manifest(&manifest).unwrap();
    let adj = Arc::new(graph.adj().normalized(manifest.hyper_str("adj").unwrap()).unwrap());
    model.bind_adjacency(adj).unwrap();
    let store = ParamStore::init(&manifest, seed);
    let (loss0, grads) = model.loss_and_grads(&store.params, batch, 1).unwrap();
    assert!(loss0.is_finite());
    let eps = 1e-2f32;
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xF1D0);
    let mut checked = 0usize;
    let mut agreed = 0usize;
    for (i, spec) in manifest.params.iter().enumerate() {
        if !spec.trainable {
            assert!(grads[i].iter().all(|&g| g == 0.0), "{}: frozen grad nonzero", spec.name);
            continue;
        }
        let n = spec.n_elements();
        for _ in 0..6.min(n) {
            let j = rng.index(n);
            let loss_at = |delta: f32| -> f32 {
                let mut params = store.params.clone();
                if let Tensor::F32 { data, .. } = &mut params[i] {
                    data[j] += delta;
                }
                model.loss_and_grads(&params, batch, 1).unwrap().0
            };
            let fd = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps);
            let an = grads[i][j];
            let tol = 3e-3 + 0.08 * an.abs().max(fd.abs());
            checked += 1;
            if (fd - an).abs() <= tol {
                agreed += 1;
            } else {
                eprintln!(
                    "  [{}] mismatch {}[{j}]: fd={fd:.6} analytic={an:.6}",
                    build.name, spec.name
                );
            }
        }
    }
    assert!(checked >= 12, "gradcheck sampled too few coordinates ({checked})");
    let rate = agreed as f64 / checked as f64;
    assert!(rate >= 0.85, "[{}] gradient agreement only {agreed}/{checked}", build.name);
}

#[test]
fn gradcheck_fullbatch_clf_coded_all_models() {
    let graph = tiny_graph(3);
    for (i, gnn) in GnnKind::all().into_iter().enumerate() {
        let build = tiny_build(gnn, true, false);
        grad_check_fb(&build, &graph, &clf_batch(&build, 17 + i as u64), 5 + i as u64);
    }
}

#[test]
fn gradcheck_fullbatch_clf_nc_all_models() {
    let graph = tiny_graph(4);
    for (i, gnn) in GnnKind::all().into_iter().enumerate() {
        let build = tiny_build(gnn, false, false);
        grad_check_fb(&build, &graph, &clf_batch(&build, 29 + i as u64), 9 + i as u64);
    }
}

#[test]
fn gradcheck_fullbatch_link_all_models() {
    let graph = tiny_graph(5);
    for (i, gnn) in GnnKind::all().into_iter().enumerate() {
        let build = tiny_build(gnn, true, true);
        grad_check_fb(&build, &graph, &link_batch(&build, 41 + i as u64), 13 + i as u64);
    }
}

// ---------------------------------------------------------------------------
// Determinism across thread counts
// ---------------------------------------------------------------------------

#[test]
fn fullbatch_training_is_bit_identical_across_thread_counts() {
    let graph = tiny_graph(7);
    for gnn in GnnKind::all() {
        let build = tiny_build(gnn, true, false);
        let run = |threads: usize| -> (Vec<u32>, ParamStore) {
            let (model, mut store) = bound_model(&build, &graph, threads);
            let mut losses = Vec::new();
            for step in 0..3u64 {
                let batch = clf_batch(&build, 100 + step);
                losses.push(train::run_step(&model, &mut store, &batch).unwrap().to_bits());
            }
            (losses, store)
        };
        let (l1, s1) = run(1);
        let (l8, s8) = run(8);
        assert_eq!(l1, l8, "{}: loss bits diverged across thread counts", gnn.as_str());
        assert_eq!(s1.params, s8.params, "{}: params diverged", gnn.as_str());
        assert_eq!(s1.adam_m, s8.adam_m, "{}: adam m diverged", gnn.as_str());
        assert_eq!(s1.adam_v, s8.adam_v, "{}: adam v diverged", gnn.as_str());
    }
}

// ---------------------------------------------------------------------------
// Registry + error paths
// ---------------------------------------------------------------------------

#[test]
fn full_table1_registry_loads_natively_with_no_artifacts() {
    let engine = Engine::with_backend("/nonexistent-artifacts", BackendKind::Native, 1).unwrap();
    for task in ["node_fb", "link_fb"] {
        for gnn in ["gcn", "sgc", "gin", "sage"] {
            for tag in ["coded", "nc"] {
                let name = format!("{task}_{gnn}_{tag}");
                let model = engine.load(&name).unwrap();
                assert_eq!(model.backend_name(), "native", "{name}");
                assert_eq!(model.manifest.name, name);
                // No dense adjacency anywhere in the native contract.
                assert!(
                    model
                        .manifest
                        .train_inputs
                        .iter()
                        .chain(model.manifest.pred_inputs.iter())
                        .all(|t| t.name != "adj"),
                    "{name} must not declare a dense adj input"
                );
            }
        }
    }
}

#[test]
fn fullbatch_train_without_binding_fails_clearly() {
    let build = tiny_build(GnnKind::Gcn, true, false);
    let manifest = build.manifest();
    let model = Model::native(manifest.clone(), 1).unwrap();
    let mut store = ParamStore::init(&manifest, 1);
    let err = train::run_step(&model, &mut store, &clf_batch(&build, 1)).unwrap_err();
    assert!(format!("{err}").contains("bind_adjacency"), "{err}");
}

// ---------------------------------------------------------------------------
// End-to-end Table-1 shape: hash codes beat random codes, per model
// ---------------------------------------------------------------------------

fn e2e_build(gnn: GnnKind) -> FullBatchBuild {
    FullBatchBuild {
        name: format!("e2e_fb_{}", gnn.as_str()),
        gnn,
        coded: true,
        link: false,
        n: 400,
        n_classes: 4,
        d_e: 16,
        hidden: 16,
        c: 8,
        m: 8,
        d_c: 16,
        d_m: 16,
        l: 2,
        light: false,
        e_train: 64,
        e_pred: 128,
        optim: OptimCfg::adamw_gnn(),
    }
}

#[test]
fn native_fullbatch_grid_hash_beats_random() {
    // Strong-community SBM: hash codes carry the community signal, random
    // (ALONE) codes carry none, so test accuracy must separate.
    let graph = sbm(SbmCfg::new(400, 4, 16.0, 2.0), 11).unwrap();
    let opts = RunOpts { epochs: 25, eval_every: 5, seed: 7 };
    for gnn in GnnKind::all() {
        let build = e2e_build(gnn);
        let mut acc = std::collections::HashMap::new();
        for fe in [Frontend::Rand, Frontend::Hash] {
            let model = Model::native(build.manifest(), 0).unwrap();
            let (out, _store) = nodeclf::run_fullbatch_model(&model, fe, &graph, opts).unwrap();
            assert!(out.final_loss.is_finite(), "{}/{}", gnn.as_str(), fe.name());
            acc.insert(fe.name(), out.test);
        }
        // Strict ordering, unless both front-ends saturate the (easy) SBM.
        assert!(
            acc["Hash"] > acc["Rand"] || acc["Hash"] > 0.95,
            "{}: hash {:.3} must beat random {:.3}",
            gnn.as_str(),
            acc["Hash"],
            acc["Rand"]
        );
        assert!(
            acc["Hash"] > 1.5 / 4.0,
            "{}: hash acc {:.3} should clear 1.5× chance on a strong SBM",
            gnn.as_str(),
            acc["Hash"]
        );
    }
}

#[test]
fn native_fullbatch_linkpred_runs_end_to_end() {
    // One link cell natively: finite losses, hits in range, and the
    // trained scorer ranks real edges above the fixed negative pool
    // better than chance would.
    let graph = sbm(SbmCfg::new(300, 4, 12.0, 2.0), 13).unwrap();
    let mut build = e2e_build(GnnKind::Gcn);
    build.link = true;
    build.n = 300;
    build.e_train = 128;
    build.e_pred = 256;
    let model = Model::native(build.manifest(), 0).unwrap();
    let opts = RunOpts { epochs: 20, eval_every: 5, seed: 9 };
    let (out, _store) =
        linkpred::run_fullbatch_model(&model, Frontend::Hash, &graph, 20, opts).unwrap();
    assert!(out.final_loss.is_finite());
    assert!((0.0..=1.0).contains(&out.val_hits));
    assert!((0.0..=1.0).contains(&out.test_hits));
}
