//! Zero-copy bundle format (PR 8) — the load-path parity matrix and the
//! int8 quantization accuracy gate.
//!
//! Every way of getting a bundle into memory must serve bit-identical
//! bytes: the in-memory bundle handed to `ServingBundle::new`, the v2
//! section table read back from disk (borrowed views, zero payload
//! copies), the superseded v1 envelope (owned copies), and — when the
//! crate is built with `--features mmap` — the mapped file. The matrix
//! runs all four model families (plain decoder, minibatch SAGE,
//! full-batch node classification, full-batch link prediction), sharded
//! and unsharded, at threads 1 and 8.
//!
//! The int8 gate trains a real full-batch cell on the Table-1 SBM
//! analog, exports it both ways, and asserts the quantized bundle's
//! serving accuracy lands within the documented tolerance (5 points)
//! of f32.

use std::path::PathBuf;

use hashgnn::cfg::{Coder, CodingCfg, GnnKind, OptimCfg};
use hashgnn::codes::random_codes;
use hashgnn::graph::generate::{sbm, SbmCfg};
use hashgnn::params::ParamStore;
use hashgnn::runtime::native::hashemb::HashKind;
use hashgnn::runtime::native::spec::{FullBatchBuild, HashFrontEnd, ReconBuild, SageMbBuild};
use hashgnn::runtime::Model;
use hashgnn::serve::{Quant, ServeOpts, ServeSession, ServingBundle, ShardRouter};
use hashgnn::tasks::coding::{make_codes, Aux};
use hashgnn::tasks::nodeclf::{self, Frontend, RunOpts};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hashgnn_bundle_v2").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn opts(threads: usize) -> ServeOpts {
    ServeOpts { threads, cache_capacity: 32, seed: 5, ..Default::default() }
}

// ---------------------------------------------------------------------------
// The four families
// ---------------------------------------------------------------------------

fn recon_bundle() -> ServingBundle {
    let m = ReconBuild {
        name: "v2_recon".into(),
        c: 4,
        m: 3,
        d_c: 5,
        d_m: 6,
        d_e: 2,
        l: 2,
        light: false,
        batch: 4,
        optim: OptimCfg::adamw_default(),
    }
    .manifest();
    let store = ParamStore::init(&m, 4);
    let codes = random_codes(12, CodingCfg::new(4, 3).unwrap(), 5);
    ServingBundle::new(m, &store, Some(codes), vec![], 12).unwrap()
}

fn sage_bundle() -> ServingBundle {
    let build = SageMbBuild {
        name: "v2_mb".into(),
        coded: true,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 3).unwrap(), 9).unwrap();
    let store = ParamStore::init(&manifest, 13);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

fn fb_bundle(link: bool) -> ServingBundle {
    let build = FullBatchBuild {
        name: "v2_fb".into(),
        gnn: GnnKind::Gcn,
        coded: true,
        link,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let graph = sbm(SbmCfg::new(60, 4, 8.0, 2.0), 3).unwrap();
    let codes =
        make_codes(&Aux::Graph(&graph), Coder::Hash, CodingCfg::new(4, 5).unwrap(), 3).unwrap();
    let store = ParamStore::init(&manifest, 21);
    ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), 60).unwrap()
}

fn families() -> Vec<(&'static str, ServingBundle, Vec<u32>, Vec<(u32, u32)>)> {
    vec![
        ("recon", recon_bundle(), vec![0, 7, 11, 3, 7], vec![(0, 7), (3, 11)]),
        ("sage_mb", sage_bundle(), vec![0, 7, 59, 13, 7], vec![(7, 0), (59, 59)]),
        ("node_fb", fb_bundle(false), vec![0, 7, 59, 13, 7], vec![(7, 0), (59, 59)]),
        ("link_fb", fb_bundle(true), vec![0, 7, 59, 13, 7], vec![(7, 0), (59, 59)]),
    ]
}

/// Everything a session can serve for this family, as exact bits:
/// embeddings, edge scores, and (where a head exists) logits + classes.
fn fingerprint(
    bundle: ServingBundle,
    threads: usize,
    query: &[u32],
    edges: &[(u32, u32)],
) -> Vec<u32> {
    let mut s = ServeSession::new(bundle, opts(threads)).unwrap();
    let mut bits: Vec<u32> = s.embed_nodes(query).unwrap().iter().map(|v| v.to_bits()).collect();
    bits.extend(s.score_edges(edges).unwrap().iter().map(|v| v.to_bits()));
    if let Ok((logits, classes)) = s.predict_classes(&query[..2]) {
        bits.extend(logits.iter().map(|v| v.to_bits()));
        bits.extend(classes.iter().map(|&c| c as u32));
    }
    bits
}

// ---------------------------------------------------------------------------
// Unsharded matrix: in-memory vs v2 heap vs v1 legacy (vs mmap)
// ---------------------------------------------------------------------------

#[test]
fn all_families_serve_identical_bytes_across_load_paths() {
    let dir = tmp_dir("matrix");
    for (name, bundle, query, edges) in families() {
        let p_v2 = dir.join(format!("{name}.v2.bundle"));
        let p_v1 = dir.join(format!("{name}.v1.bundle"));
        bundle.save(&p_v2).unwrap();
        bundle.save_legacy_v1(&p_v1).unwrap();

        let v2 = ServingBundle::load(&p_v2).unwrap();
        assert!(v2.meta.zero_copy, "{name}: v2 f32 load must be zero-copy");
        assert!(!v2.meta.quantized, "{name}: f32 load must not report quantized");
        assert!(v2.params.borrowed(), "{name}: v2 params must be views");
        assert!(v2.edges.borrowed(), "{name}: v2 edges must be views");
        if let Some(codes) = &v2.codes {
            assert!(codes.bits.words_borrowed(), "{name}: v2 code words must be views");
        }
        assert!(v2.meta.load_us > 0 || v2.meta.file_bytes > 0, "{name}: load meta filled");

        let v1 = ServingBundle::load(&p_v1).unwrap();
        assert!(!v1.meta.zero_copy, "{name}: the v1 envelope copies every section");
        assert!(!v1.params.borrowed() && !v1.edges.borrowed());

        for threads in [1usize, 8] {
            let reference = fingerprint(bundle.clone(), threads, &query, &edges);
            let from_v2 = fingerprint(v2.clone(), threads, &query, &edges);
            let from_v1 = fingerprint(v1.clone(), threads, &query, &edges);
            assert_eq!(
                reference, from_v2,
                "{name} (threads={threads}): v2 section-table load changed served bytes"
            );
            assert_eq!(
                reference, from_v1,
                "{name} (threads={threads}): legacy v1 load changed served bytes"
            );
            #[cfg(feature = "mmap")]
            {
                let mapped = ServingBundle::load_with(&p_v2, true).unwrap();
                assert!(mapped.meta.zero_copy);
                let from_map = fingerprint(mapped, threads, &query, &edges);
                assert_eq!(
                    reference, from_map,
                    "{name} (threads={threads}): mmap load changed served bytes"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sharded matrix: split → files → router, per format
// ---------------------------------------------------------------------------

#[test]
fn sharded_sets_serve_identical_bytes_across_formats() {
    let dir = tmp_dir("shards");
    for (name, bundle, query, edges) in families() {
        let shards = bundle.split_shards(3).unwrap();
        for threads in [1usize, 8] {
            // Unsharded session is the reference for the routed answers.
            let mut whole = ServeSession::new(bundle.clone(), opts(threads)).unwrap();
            let ref_embed: Vec<u32> =
                whole.embed_nodes(&query).unwrap().iter().map(|v| v.to_bits()).collect();
            let ref_scores: Vec<u32> =
                whole.score_edges(&edges).unwrap().iter().map(|v| v.to_bits()).collect();
            for legacy in [false, true] {
                let mut loaded = Vec::new();
                for (i, shard) in shards.iter().enumerate() {
                    let tag = if legacy { "v1" } else { "v2" };
                    let p = dir.join(format!("{name}.{tag}.shard{i}"));
                    if legacy {
                        shard.save_legacy_v1(&p).unwrap();
                    } else {
                        shard.save(&p).unwrap();
                    }
                    loaded.push(ServingBundle::load(&p).unwrap());
                }
                let mut router = ShardRouter::new(loaded, opts(threads)).unwrap();
                let got: Vec<u32> =
                    router.embed_nodes(&query).unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    ref_embed, got,
                    "{name} (threads={threads}, legacy={legacy}): routed embeddings diverged"
                );
                let got_scores: Vec<u32> =
                    router.score_edges(&edges).unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    ref_scores, got_scores,
                    "{name} (threads={threads}, legacy={legacy}): routed scores diverged"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Hash-embedding front-end bundles (multihash / bloom / poshash)
// ---------------------------------------------------------------------------

const HASH_KINDS: [HashKind; 3] = [HashKind::Multi, HashKind::Bloom, HashKind::Pos];

fn hash_fe(kind: HashKind) -> HashFrontEnd {
    HashFrontEnd {
        kind,
        k: 2,
        b: 9,
        bp: if kind == HashKind::Pos { 4 } else { 0 },
        seed: 77,
    }
}

/// Minibatch SAGE bundle on a hash front-end: no codes, ids-input
/// encoder; poshash freezes the training graph's degree-rank map.
fn hash_mb_bundle(kind: HashKind) -> ServingBundle {
    let build = SageMbBuild {
        name: format!("v2_mb_{}", kind.as_str()),
        coded: false,
        link: false,
        n: 60,
        n_classes: 3,
        d_e: 4,
        hidden: 5,
        batch: 4,
        k1: 2,
        k2: 2,
        c: 4,
        m: 3,
        d_c: 4,
        d_m: 6,
        l: 2,
        light: false,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest_hash(&hash_fe(kind));
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let store = ParamStore::init(&manifest, 13);
    let bundle =
        ServingBundle::new(manifest.clone(), &store, None, graph.undirected_edges(), 60).unwrap();
    if kind == HashKind::Pos {
        let map = nodeclf::pos_map_for(&manifest, &graph).unwrap();
        bundle.with_pos_map(map.as_ref().clone()).unwrap()
    } else {
        bundle
    }
}

/// Full-batch GIN bundle on a hash front-end (exercises the empty
/// fb_batch + bound-CSR serving path).
fn hash_fb_bundle(kind: HashKind) -> ServingBundle {
    let build = FullBatchBuild {
        name: format!("v2_fb_{}", kind.as_str()),
        gnn: GnnKind::Gin,
        coded: false,
        link: false,
        n: 60,
        n_classes: 4,
        d_e: 6,
        hidden: 8,
        c: 4,
        m: 5,
        d_c: 6,
        d_m: 7,
        l: 2,
        light: false,
        e_train: 32,
        e_pred: 48,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest_hash(&hash_fe(kind));
    let graph = sbm(SbmCfg::new(60, 4, 8.0, 2.0), 3).unwrap();
    let store = ParamStore::init(&manifest, 21);
    let bundle =
        ServingBundle::new(manifest.clone(), &store, None, graph.undirected_edges(), 60).unwrap();
    if kind == HashKind::Pos {
        let map = nodeclf::pos_map_for(&manifest, &graph).unwrap();
        bundle.with_pos_map(map.as_ref().clone()).unwrap()
    } else {
        bundle
    }
}

#[test]
fn hash_frontend_bundles_serve_identical_bytes_across_load_paths() {
    let dir = tmp_dir("hashemb");
    let query = [0u32, 7, 59, 13, 7];
    let edges = [(7u32, 0u32), (59, 59)];
    for kind in HASH_KINDS {
        for (name, bundle) in [
            (format!("mb_{}", kind.as_str()), hash_mb_bundle(kind)),
            (format!("fb_{}", kind.as_str()), hash_fb_bundle(kind)),
        ] {
            let p = dir.join(format!("{name}.v2.bundle"));
            bundle.save(&p).unwrap();
            let loaded = ServingBundle::load(&p).unwrap();
            assert!(loaded.meta.zero_copy, "{name}: v2 load must be zero-copy");
            assert_eq!(
                loaded.pos_map.is_some(),
                kind == HashKind::Pos,
                "{name}: POSMAP section presence must track the front-end kind"
            );
            for threads in [1usize, 8] {
                let reference = fingerprint(bundle.clone(), threads, &query, &edges);
                let from_disk = fingerprint(loaded.clone(), threads, &query, &edges);
                assert_eq!(
                    reference, from_disk,
                    "{name} (threads={threads}): v2 roundtrip changed served bytes"
                );
            }
        }
    }
}

#[test]
fn sharded_hash_frontend_bundles_route_identically() {
    let dir = tmp_dir("hashemb_shards");
    let query = [0u32, 7, 59, 13, 7];
    let edges = [(7u32, 0u32), (59, 59)];
    for kind in HASH_KINDS {
        for (name, bundle) in [
            (format!("mb_{}", kind.as_str()), hash_mb_bundle(kind)),
            (format!("fb_{}", kind.as_str()), hash_fb_bundle(kind)),
        ] {
            let shards = bundle.split_shards(3).unwrap();
            for s in &shards {
                assert_eq!(
                    s.pos_map, bundle.pos_map,
                    "{name}: shards must replicate the position map verbatim"
                );
            }
            for threads in [1usize, 8] {
                let mut whole = ServeSession::new(bundle.clone(), opts(threads)).unwrap();
                let ref_embed: Vec<u32> =
                    whole.embed_nodes(&query).unwrap().iter().map(|v| v.to_bits()).collect();
                let ref_scores: Vec<u32> =
                    whole.score_edges(&edges).unwrap().iter().map(|v| v.to_bits()).collect();
                let mut loaded = Vec::new();
                for (i, shard) in shards.iter().enumerate() {
                    let p = dir.join(format!("{name}.shard{i}"));
                    shard.save(&p).unwrap();
                    loaded.push(ServingBundle::load(&p).unwrap());
                }
                let mut router = ShardRouter::new(loaded, opts(threads)).unwrap();
                let got: Vec<u32> =
                    router.embed_nodes(&query).unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    ref_embed, got,
                    "{name} (threads={threads}): routed hash-frontend embeddings diverged"
                );
                let got_scores: Vec<u32> =
                    router.score_edges(&edges).unwrap().iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    ref_scores, got_scores,
                    "{name} (threads={threads}): routed hash-frontend scores diverged"
                );
            }
        }
    }
}

#[test]
fn poshash_bundle_without_posmap_is_refused_at_session_open() {
    let manifest = {
        let b = SageMbBuild {
            name: "v2_pos_missing".into(),
            coded: false,
            link: false,
            n: 60,
            n_classes: 3,
            d_e: 4,
            hidden: 5,
            batch: 4,
            k1: 2,
            k2: 2,
            c: 4,
            m: 3,
            d_c: 4,
            d_m: 6,
            l: 2,
            light: false,
            optim: OptimCfg::adamw_gnn(),
        };
        b.manifest_hash(&hash_fe(HashKind::Pos))
    };
    let graph = sbm(SbmCfg::new(60, 3, 8.0, 2.0), 9).unwrap();
    let store = ParamStore::init(&manifest, 13);
    let bundle =
        ServingBundle::new(manifest, &store, None, graph.undirected_edges(), 60).unwrap();
    let err = ServeSession::new(bundle, opts(1)).unwrap_err();
    assert!(format!("{err}").contains("POSMAP"), "{err}");
}

// ---------------------------------------------------------------------------
// int8 accuracy gate on the Table-1 SBM analog
// ---------------------------------------------------------------------------

/// Documented tolerance for the int8 export: serving accuracy on the
/// strong-community SBM may move at most this much against f32
/// (docs/SERVING.md "cold start & memory").
const INT8_ACC_TOLERANCE: f64 = 0.05;

#[test]
fn int8_export_keeps_table1_accuracy_within_tolerance() {
    let n = 300usize;
    let graph = sbm(SbmCfg::new(n, 4, 16.0, 2.0), 11).unwrap();
    let build = FullBatchBuild {
        name: "v2_int8_gate".into(),
        gnn: GnnKind::Sgc,
        coded: true,
        link: false,
        n,
        n_classes: 4,
        d_e: 16,
        hidden: 16,
        c: 8,
        m: 8,
        d_c: 16,
        d_m: 16,
        l: 2,
        light: false,
        e_train: 64,
        e_pred: 128,
        optim: OptimCfg::adamw_gnn(),
    };
    let manifest = build.manifest();
    let run = RunOpts { epochs: 15, eval_every: 5, seed: 7 };
    let model = Model::native(manifest.clone(), 0).unwrap();
    let (out, store) = nodeclf::run_fullbatch_model(&model, Frontend::Hash, &graph, run).unwrap();
    assert!(out.final_loss.is_finite());

    // Same code derivation as the training run, frozen into the bundle.
    let coding = CodingCfg::new(8, 8).unwrap();
    let codes = make_codes(&Aux::Graph(&graph), Coder::Hash, coding, run.seed).unwrap();
    let bundle =
        ServingBundle::new(manifest, &store, Some(codes), graph.undirected_edges(), n).unwrap();

    let dir = tmp_dir("int8_gate");
    let p_f32 = dir.join("gate.f32.bundle");
    let p_i8 = dir.join("gate.i8.bundle");
    bundle.save_with(&p_f32, Quant::F32).unwrap();
    bundle.save_with(&p_i8, Quant::Int8).unwrap();
    assert!(
        std::fs::metadata(&p_i8).unwrap().len() < std::fs::metadata(&p_f32).unwrap().len(),
        "int8 file must be smaller than f32"
    );

    let labels = graph.labels().unwrap();
    let all: Vec<u32> = (0..n as u32).collect();
    let accuracy = |path: &std::path::Path| -> (f64, bool) {
        let loaded = ServingBundle::load(path).unwrap();
        let quantized = loaded.meta.quantized;
        let mut s = ServeSession::new(loaded, opts(1)).unwrap();
        let (_logits, classes) = s.predict_classes(&all).unwrap();
        let hits = classes.iter().zip(labels).filter(|&(&c, &y)| c as u32 == y).count();
        (hits as f64 / n as f64, quantized)
    };
    let (acc_f32, q_f32) = accuracy(&p_f32);
    let (acc_i8, q_i8) = accuracy(&p_i8);
    assert!(!q_f32 && q_i8, "meta.quantized must reflect the written encoding");
    // The trained cell must actually have learned something, or the gate
    // would pass vacuously at chance level.
    assert!(acc_f32 > 0.5, "trained f32 accuracy too low to gate against ({acc_f32:.3})");
    assert!(
        (acc_f32 - acc_i8).abs() <= INT8_ACC_TOLERANCE,
        "int8 accuracy {acc_i8:.3} drifted more than {INT8_ACC_TOLERANCE} from f32 {acc_f32:.3}"
    );
}
