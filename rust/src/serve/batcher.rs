//! Request batchers: the per-call [`Batcher`] that coalesces one query
//! into the fixed-size batches the inference model consumes, and the
//! cross-request [`CrossBatcher`] that accumulates *multiple* requests
//! under a latency budget before handing them to the session at all.
//!
//! # Per-call coalescing ([`Batcher`])
//!
//! The minibatch executables take shape-fixed inputs (`batch` targets per
//! encoder application), so ad-hoc query lists must be deduplicated,
//! chunked to that size, and tail-padded. Padding repeats the group's
//! last id — padded rows are computed and discarded, never returned —
//! and deduplication preserves first-seen order, so the whole coalescing
//! step is deterministic and cannot change any served value (per-row
//! kernels make each output row a function of its own input row only).
//! Edge queries reduce to node queries before reaching the batcher: the
//! session flattens endpoints into one id list, embeds through the cache,
//! and dots the pairs.
//!
//! # Cross-request batching ([`CrossBatcher`])
//!
//! The persistent server ([`super::server`]) does not compute per
//! request: it enqueues requests and flushes the whole pending set as one
//! deduplicated node-id union when **either** bound trips, whichever
//! comes first:
//!
//! - **fill** — the pending set references `max_batch` distinct node ids;
//! - **budget** — `max_delay` has elapsed since the *oldest* pending
//!   request arrived (so the first request in a lull never waits longer
//!   than the budget, no matter how slowly followers trickle in).
//!
//! The `CrossBatcher` is a pure state machine — callers inject
//! [`Instant`]s — so the budget/fill decision logic is unit-testable
//! without real clocks and the server loop owns all actual waiting.
//! Exact counters ([`BatchStats`]) account for every flush, its trigger,
//! and how many node references cross-request deduplication saved.
//!
//! Like everything in the serving layer, batching is result-neutral: the
//! union is computed through the same session path as a lone request,
//! and per-row independence plus per-node sampling seeds make each
//! served row a function of `(bundle, id)` only — never of what else
//! happened to share the flush.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// One pool-sized group: exactly `batch` ids, of which the first
/// `real` are genuine queries and the rest are padding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchGroup {
    pub ids: Vec<u32>,
    pub real: usize,
}

/// A coalesced query: the unique ids in first-seen order plus the padded
/// groups that cover them (`groups` concatenated and truncated to
/// `unique.len()` equals `unique`).
#[derive(Clone, Debug, Default)]
pub struct Coalesced {
    pub unique: Vec<u32>,
    pub groups: Vec<BatchGroup>,
}

/// Fixed-batch request coalescer.
///
/// ```
/// use hashgnn::serve::Batcher;
///
/// let b = Batcher::new(3).unwrap();
/// let c = b.coalesce(&[5, 1, 5, 9, 1]);
/// assert_eq!(c.unique, vec![5, 1, 9]);               // first-seen dedup
/// assert_eq!(c.groups[0].ids, vec![5, 1, 9]);        // one padded group
/// assert_eq!(c.groups[0].real, 3);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Batcher {
    batch: usize,
}

impl Batcher {
    pub fn new(batch: usize) -> Result<Self> {
        if batch == 0 {
            return Err(Error::Config("batcher batch size must be positive".into()));
        }
        Ok(Self { batch })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Dedup (first-seen order) and chunk into padded groups.
    pub fn coalesce(&self, ids: &[u32]) -> Coalesced {
        let mut unique: Vec<u32> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for &id in ids {
            if seen.insert(id) {
                unique.push(id);
            }
        }
        let mut groups = Vec::with_capacity(unique.len().div_ceil(self.batch));
        for chunk in unique.chunks(self.batch) {
            let mut g = chunk.to_vec();
            let last = *g.last().expect("chunks are non-empty");
            g.resize(self.batch, last);
            groups.push(BatchGroup { ids: g, real: chunk.len() });
        }
        Coalesced { unique, groups }
    }

}

/// What made a [`CrossBatcher`] flush.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushTrigger {
    /// The pending set reached `max_batch` distinct node ids.
    Fill,
    /// The latency budget elapsed before the set filled.
    Budget,
    /// The caller drained the queue (EOF, a control request, shutdown).
    Drain,
}

/// Exact cross-request batching counters, cumulative over a server loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests accepted into the pending queue.
    pub batched_requests: u64,
    /// Total flush events (`fill + budget + drain`).
    pub flushes: u64,
    /// Flushes triggered by reaching `max_batch` distinct nodes.
    pub fill_flushes: u64,
    /// Flushes triggered by the latency budget expiring.
    pub budget_expiries: u64,
    /// Flushes triggered by a drain (EOF / control request).
    pub drain_flushes: u64,
    /// Node references removed by cross-request deduplication — the
    /// compute the union saved versus handling each request alone
    /// (Σ per flush of `references − distinct`).
    pub coalesced_nodes: u64,
    /// Distinct node ids actually computed across all flushes.
    pub unique_nodes: u64,
}

impl BatchStats {
    /// Field-wise accumulation (the TCP front sums per-connection
    /// sessions through here, so a new counter cannot be silently
    /// dropped from aggregates).
    pub fn absorb(&mut self, o: &BatchStats) {
        let BatchStats {
            batched_requests,
            flushes,
            fill_flushes,
            budget_expiries,
            drain_flushes,
            coalesced_nodes,
            unique_nodes,
        } = o;
        self.batched_requests += batched_requests;
        self.flushes += flushes;
        self.fill_flushes += fill_flushes;
        self.budget_expiries += budget_expiries;
        self.drain_flushes += drain_flushes;
        self.coalesced_nodes += coalesced_nodes;
        self.unique_nodes += unique_nodes;
    }
}

/// Cross-request accumulator with a fill bound and a latency budget (see
/// the module docs for semantics). Generic over the queued item so the
/// server can carry its response bookkeeping through a flush; `push`
/// takes the node ids the item references separately.
///
/// Time is injected — `push`/`should_flush` take an [`Instant`] — which
/// keeps the decision logic deterministic under test; only the server
/// loop ever sleeps.
pub struct CrossBatcher<T> {
    max_batch: usize,
    max_delay: Duration,
    pending: Vec<T>,
    /// Distinct pending node ids, in first-seen order (`unique` mirrors
    /// `unique_set`; the order makes flush output deterministic).
    unique: Vec<u32>,
    unique_set: HashSet<u32>,
    /// Total node references across pending items (≥ `unique.len()`).
    references: usize,
    /// Arrival time of the oldest pending item — the budget anchor.
    oldest: Option<Instant>,
    stats: BatchStats,
}

impl<T> CrossBatcher<T> {
    pub fn new(max_batch: usize, max_delay: Duration) -> Result<Self> {
        if max_batch == 0 {
            return Err(Error::Config("cross-batcher max_batch must be positive".into()));
        }
        Ok(Self {
            max_batch,
            max_delay,
            pending: Vec::new(),
            unique: Vec::new(),
            unique_set: HashSet::new(),
            references: 0,
            oldest: None,
            stats: BatchStats::default(),
        })
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Pending items (requests, not nodes).
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Distinct node ids currently pending.
    pub fn pending_nodes(&self) -> usize {
        self.unique.len()
    }

    /// Queue one item referencing `ids`; returns `true` when the fill
    /// bound is reached and the caller must flush now.
    pub fn push(&mut self, item: T, ids: &[u32], now: Instant) -> bool {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(item);
        self.stats.batched_requests += 1;
        self.references += ids.len();
        for &id in ids {
            if self.unique_set.insert(id) {
                self.unique.push(id);
            }
        }
        self.unique.len() >= self.max_batch
    }

    /// Deadline after which the pending set must flush (`None` when
    /// nothing is pending).
    pub fn deadline(&self) -> Option<Instant> {
        self.oldest.map(|t| t + self.max_delay)
    }

    /// True when something is pending and its budget has elapsed.
    pub fn should_flush(&self, now: Instant) -> bool {
        self.deadline().map(|d| now >= d).unwrap_or(false)
    }

    /// Take the pending items and their deduplicated node-id union
    /// (first-seen order), recording `trigger` in the counters. Calling
    /// on an empty queue returns empty vecs and counts nothing.
    pub fn take(&mut self, trigger: FlushTrigger) -> (Vec<T>, Vec<u32>) {
        if self.pending.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let items = std::mem::take(&mut self.pending);
        let unique = std::mem::take(&mut self.unique);
        self.unique_set.clear();
        self.oldest = None;
        self.stats.flushes += 1;
        match trigger {
            FlushTrigger::Fill => self.stats.fill_flushes += 1,
            FlushTrigger::Budget => self.stats.budget_expiries += 1,
            FlushTrigger::Drain => self.stats.drain_flushes += 1,
        }
        self.stats.coalesced_nodes += (self.references - unique.len()) as u64;
        self.stats.unique_nodes += unique.len() as u64;
        self.references = 0;
        (items, unique)
    }

    pub fn stats(&self) -> BatchStats {
        self.stats
    }
}

/// Sliding window of the last `cap` flush latencies (microseconds) with
/// exact rank-based percentiles — the `flush_p50_us` / `flush_p99_us`
/// fields of the `stats` control response. A ring buffer, so a
/// long-lived server reports recent behavior, not its lifetime average;
/// exact (sort the window, index by rank), so tests can assert the
/// numbers instead of trusting an approximation.
#[derive(Clone, Debug)]
pub struct LatencyWindow {
    cap: usize,
    buf: Vec<u64>,
    /// Next overwrite position once the buffer is full.
    next: usize,
    /// Total samples ever recorded (≥ `buf.len()`).
    total: u64,
}

impl LatencyWindow {
    /// `cap` = window size in samples (clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), buf: Vec::new(), next: 0, total: 0 }
    }

    pub fn record(&mut self, micros: u64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(micros);
        } else {
            self.buf[self.next] = micros;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Samples currently in the window.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Samples ever recorded (the window forgets, this counter doesn't).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Exact p-th percentile of the window by nearest-rank on the sorted
    /// samples (`index = (len - 1) · p / 100`, integer floor). Returns 0
    /// for an empty window. `p` is clamped to 100.
    pub fn percentile(&self, p: usize) -> u64 {
        if self.buf.is_empty() {
            return 0;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_unstable();
        let idx = (sorted.len() - 1) * p.min(100) / 100;
        sorted[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_in_first_seen_order_and_pads_the_tail() {
        let b = Batcher::new(3).unwrap();
        let c = b.coalesce(&[5, 1, 5, 9, 1, 2, 7]);
        assert_eq!(c.unique, vec![5, 1, 9, 2, 7]);
        assert_eq!(c.groups.len(), 2);
        assert_eq!(c.groups[0], BatchGroup { ids: vec![5, 1, 9], real: 3 });
        assert_eq!(c.groups[1], BatchGroup { ids: vec![2, 7, 7], real: 2 });
        // Concatenated real prefixes reproduce `unique`.
        let flat: Vec<u32> =
            c.groups.iter().flat_map(|g| g.ids[..g.real].iter().copied()).collect();
        assert_eq!(flat, c.unique);
    }

    #[test]
    fn empty_query_yields_no_groups() {
        let b = Batcher::new(4).unwrap();
        let c = b.coalesce(&[]);
        assert!(c.unique.is_empty() && c.groups.is_empty());
        assert!(Batcher::new(0).is_err());
    }

    // ---- CrossBatcher: fill vs budget semantics -------------------------

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn fill_bound_trips_on_distinct_nodes_not_references() {
        let mut cb: CrossBatcher<&str> = CrossBatcher::new(4, ms(1000)).unwrap();
        let t0 = Instant::now();
        assert!(!cb.push("a", &[1, 2], t0), "2 distinct < 4");
        assert!(!cb.push("b", &[2, 1, 3], t0), "duplicates don't fill: 3 distinct");
        assert!(cb.push("c", &[3, 9], t0), "4 distinct trips the fill bound");
        assert_eq!(cb.pending_nodes(), 4);
        let (items, unique) = cb.take(FlushTrigger::Fill);
        assert_eq!(items, vec!["a", "b", "c"]);
        assert_eq!(unique, vec![1, 2, 3, 9], "first-seen union order");
        let s = cb.stats();
        assert_eq!((s.flushes, s.fill_flushes, s.budget_expiries), (1, 1, 0));
        // 7 references, 4 distinct → 3 coalesced away.
        assert_eq!((s.coalesced_nodes, s.unique_nodes, s.batched_requests), (3, 4, 3));
        assert!(cb.is_empty() && cb.deadline().is_none());
    }

    #[test]
    fn budget_anchors_on_the_oldest_request() {
        let mut cb: CrossBatcher<u32> = CrossBatcher::new(100, ms(50)).unwrap();
        let t0 = Instant::now();
        assert!(!cb.should_flush(t0), "empty queue has no deadline");
        cb.push(0, &[5], t0);
        // Followers arriving late do NOT extend the first request's wait.
        cb.push(1, &[6], t0 + ms(30));
        assert_eq!(cb.deadline().unwrap(), t0 + ms(50));
        assert!(!cb.should_flush(t0 + ms(49)));
        assert!(cb.should_flush(t0 + ms(50)), "budget expires exactly at oldest + delay");
        let (items, unique) = cb.take(FlushTrigger::Budget);
        assert_eq!((items.len(), unique.len()), (2, 2));
        assert_eq!(cb.stats().budget_expiries, 1);
        // Next arrival re-anchors the deadline.
        cb.push(2, &[7], t0 + ms(80));
        assert_eq!(cb.deadline().unwrap(), t0 + ms(130));
    }

    #[test]
    fn zero_delay_means_flush_after_every_request() {
        let mut cb: CrossBatcher<u32> = CrossBatcher::new(100, ms(0)).unwrap();
        let t0 = Instant::now();
        cb.push(0, &[1], t0);
        assert!(cb.should_flush(t0), "zero budget expires immediately");
        assert!(CrossBatcher::<u32>::new(0, ms(1)).is_err());
    }

    #[test]
    fn drain_and_empty_take_accounting() {
        let mut cb: CrossBatcher<u32> = CrossBatcher::new(8, ms(10)).unwrap();
        let (items, unique) = cb.take(FlushTrigger::Drain);
        assert!(items.is_empty() && unique.is_empty());
        assert_eq!(cb.stats().flushes, 0, "empty take is not a flush");
        cb.push(0, &[], Instant::now());
        let (items, unique) = cb.take(FlushTrigger::Drain);
        assert_eq!((items.len(), unique.len()), (1, 0), "id-free items still flush");
        let s = cb.stats();
        assert_eq!((s.flushes, s.drain_flushes, s.unique_nodes), (1, 1, 0));
    }

    #[test]
    fn latency_window_exact_percentiles_and_wraparound() {
        let mut w = LatencyWindow::new(4);
        assert_eq!(w.percentile(99), 0, "empty window reports 0");
        for us in [10, 20, 30, 40] {
            w.record(us);
        }
        assert_eq!(w.len(), 4);
        // Sorted [10,20,30,40]: p0 → idx 0, p50 → idx 1, p99 → idx 2, p100 → idx 3.
        assert_eq!(w.percentile(0), 10);
        assert_eq!(w.percentile(50), 20);
        assert_eq!(w.percentile(99), 30);
        assert_eq!(w.percentile(100), 40);
        // Overflow evicts the oldest sample: window becomes [50,20,30,40].
        w.record(50);
        assert_eq!(w.len(), 4);
        assert_eq!(w.total(), 5);
        assert_eq!(w.percentile(100), 50);
        assert_eq!(w.percentile(0), 20, "the 10µs sample was evicted");
        // cap 0 clamps to 1 (a degenerate but valid window).
        let mut one = LatencyWindow::new(0);
        one.record(7);
        one.record(9);
        assert_eq!((one.len(), one.percentile(50)), (1, 9));
    }

    #[test]
    fn oversized_single_request_flushes_at_once() {
        let mut cb: CrossBatcher<u32> = CrossBatcher::new(3, ms(1000)).unwrap();
        assert!(cb.push(0, &[1, 2, 3, 4, 5], Instant::now()), "5 ≥ 3 flushes immediately");
        let (_, unique) = cb.take(FlushTrigger::Fill);
        assert_eq!(unique, vec![1, 2, 3, 4, 5], "never truncated, only flushed");
    }
}
