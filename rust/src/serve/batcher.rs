//! Request batcher: coalesce incoming node/edge queries into the
//! fixed-size batches the inference model consumes.
//!
//! The minibatch executables take shape-fixed inputs (`batch` targets per
//! encoder application), so ad-hoc query lists must be deduplicated,
//! chunked to that size, and tail-padded. Padding repeats the group's
//! last id — padded rows are computed and discarded, never returned —
//! and deduplication preserves first-seen order, so the whole coalescing
//! step is deterministic and cannot change any served value (per-row
//! kernels make each output row a function of its own input row only).
//! Edge queries reduce to node queries before reaching the batcher: the
//! session flattens endpoints into one id list, embeds through the cache,
//! and dots the pairs.

use crate::{Error, Result};

/// One pool-sized group: exactly `batch` ids, of which the first
/// `real` are genuine queries and the rest are padding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchGroup {
    pub ids: Vec<u32>,
    pub real: usize,
}

/// A coalesced query: the unique ids in first-seen order plus the padded
/// groups that cover them (`groups` concatenated and truncated to
/// `unique.len()` equals `unique`).
#[derive(Clone, Debug, Default)]
pub struct Coalesced {
    pub unique: Vec<u32>,
    pub groups: Vec<BatchGroup>,
}

/// Fixed-batch request coalescer.
#[derive(Clone, Copy, Debug)]
pub struct Batcher {
    batch: usize,
}

impl Batcher {
    pub fn new(batch: usize) -> Result<Self> {
        if batch == 0 {
            return Err(Error::Config("batcher batch size must be positive".into()));
        }
        Ok(Self { batch })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Dedup (first-seen order) and chunk into padded groups.
    pub fn coalesce(&self, ids: &[u32]) -> Coalesced {
        let mut unique: Vec<u32> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for &id in ids {
            if seen.insert(id) {
                unique.push(id);
            }
        }
        let mut groups = Vec::with_capacity(unique.len().div_ceil(self.batch));
        for chunk in unique.chunks(self.batch) {
            let mut g = chunk.to_vec();
            let last = *g.last().expect("chunks are non-empty");
            g.resize(self.batch, last);
            groups.push(BatchGroup { ids: g, real: chunk.len() });
        }
        Coalesced { unique, groups }
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_in_first_seen_order_and_pads_the_tail() {
        let b = Batcher::new(3).unwrap();
        let c = b.coalesce(&[5, 1, 5, 9, 1, 2, 7]);
        assert_eq!(c.unique, vec![5, 1, 9, 2, 7]);
        assert_eq!(c.groups.len(), 2);
        assert_eq!(c.groups[0], BatchGroup { ids: vec![5, 1, 9], real: 3 });
        assert_eq!(c.groups[1], BatchGroup { ids: vec![2, 7, 7], real: 2 });
        // Concatenated real prefixes reproduce `unique`.
        let flat: Vec<u32> =
            c.groups.iter().flat_map(|g| g.ids[..g.real].iter().copied()).collect();
        assert_eq!(flat, c.unique);
    }

    #[test]
    fn empty_query_yields_no_groups() {
        let b = Batcher::new(4).unwrap();
        let c = b.coalesce(&[]);
        assert!(c.unique.is_empty() && c.groups.is_empty());
        assert!(Batcher::new(0).is_err());
    }
}
