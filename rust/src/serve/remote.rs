//! Remote shard backend: the [`Serving`] implementation over worker
//! *processes* instead of in-process sessions.
//!
//! `hashgnn serve --shard-worker --listen <addr> --bundle <shard>` turns
//! each `HGNS0001` shard file into its own OS process speaking the
//! existing NDJSON protocol over TCP. [`RemoteShard`] is the client for
//! one such worker — pooled connection, connect/request timeouts,
//! bounded retries with exponential backoff, and a health state machine —
//! and [`RemoteRouter`] composes one per shard into the same [`Serving`]
//! surface as the in-process [`ShardRouter`](super::ShardRouter).
//!
//! # Fault model (what `tests/serve_fault.rs` and CI pin down)
//!
//! - **Transport faults** (refused connect, timeout, torn or unparseable
//!   response) tear down the pooled connection — the next attempt dials
//!   fresh, so framing can never de-sync — and are retried up to
//!   `retries` times with `backoff × 2^attempt` sleeps. Damaged bytes
//!   are **never** served: a response that does not parse is
//!   indistinguishable from no response.
//! - **A worker that stays dead** is marked `Down` after the retry
//!   budget. Service degrades *partially*: ids owned by the dead shard
//!   answer `{"error": "shard_unavailable"}` in position, while every
//!   other shard keeps serving **bit-identical** bytes (shard outputs
//!   are independent by the slicing rules in [`super::bundle`]).
//! - **Recovery** is automatic: a `Down` worker is re-probed with a
//!   `stats` ping at most every `health_every` (zero = every request,
//!   which tests use for determinism); a probe that answers flips it
//!   back to `Up` and normal routing resumes.
//! - **Application errors** (`{"error": ...}` lines — bad id, deadline
//!   shed) are responses, not faults: they propagate to the caller's
//!   position and are never retried.
//!
//! The handshake (`{"op": "stats"}` at connect) carries `n_nodes`,
//! `dim`, `model` and the worker's `shard` range; [`RemoteRouter`]
//! validates that every worker serves the same export and that the
//! owned ranges tile `[0, n)` exactly — a mis-assembled fleet is a loud
//! constructor error, not a silently wrong id space.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::ser::{self, Json};
use crate::{Error, Result};

use super::server::{read_bounded_line, RawLine};
use super::{FanoutReport, Serving};

/// Exact wire string for ids owned by an unreachable worker.
pub const SHARD_UNAVAILABLE: &str = "shard_unavailable";

/// Client-side knobs for one worker connection.
#[derive(Clone, Copy, Debug)]
pub struct RemoteCfg {
    /// TCP dial timeout.
    pub connect_timeout: Duration,
    /// Read/write timeout for one request/response round trip.
    pub request_timeout: Duration,
    /// Retry budget per request (total attempts = `retries + 1`).
    pub retries: u32,
    /// First retry sleep; doubles per attempt (`backoff × 2^attempt`).
    pub backoff: Duration,
    /// Minimum interval between health probes of a `Down` worker; zero
    /// probes on every routing decision (deterministic tests).
    pub health_every: Duration,
    /// Longest response line the client will buffer.
    pub max_line_bytes: usize,
    /// Pipeline one flush across the fleet: write every worker's
    /// sub-request before reading any response (one in-flight request
    /// per pooled socket), so a K-worker flush waits ~max(worker)
    /// instead of the sum. Off, workers are walked sequentially; the
    /// served bytes are identical either way.
    pub fanout: bool,
}

impl Default for RemoteCfg {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_millis(1000),
            request_timeout: Duration::from_millis(5000),
            retries: 2,
            backoff: Duration::from_millis(50),
            health_every: Duration::from_millis(1000),
            max_line_bytes: 1 << 20,
            fanout: true,
        }
    }
}

/// What the worker advertised in its `stats` handshake.
#[derive(Clone, Debug)]
pub struct WorkerMeta {
    pub n_nodes: usize,
    pub dim: usize,
    pub model: String,
    /// Owned `[lo, hi)` plus `(index, count)`; a whole-bundle worker
    /// reports `(0, n, 0, 1)`.
    pub lo: u32,
    pub hi: u32,
    pub index: usize,
    pub count: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Health {
    Up,
    Down,
}

/// One worker process, as seen from the router.
pub struct RemoteShard {
    addr: String,
    cfg: RemoteCfg,
    /// Pooled connection; `None` between failures (every retry dials
    /// fresh, so a torn response can never de-sync framing).
    conn: Option<BufReader<TcpStream>>,
    meta: WorkerMeta,
    health: Health,
    last_probe: Instant,
}

impl RemoteShard {
    /// Dial the worker and handshake via `{"op": "stats"}`; fails loudly
    /// if the worker is unreachable or the response carries no metadata.
    pub fn connect(addr: &str, cfg: RemoteCfg) -> Result<Self> {
        let mut shard = Self {
            addr: addr.to_string(),
            cfg,
            conn: None,
            meta: WorkerMeta {
                n_nodes: 0,
                dim: 0,
                model: String::new(),
                lo: 0,
                hi: 0,
                index: 0,
                count: 1,
            },
            health: Health::Down,
            last_probe: Instant::now(),
        };
        let stats = shard.request_once(r#"{"op": "stats"}"#).map_err(|e| {
            Error::Runtime(format!("worker {addr}: handshake failed: {e}"))
        })?;
        shard.meta = Self::meta_from_stats(addr, &stats)?;
        shard.health = Health::Up;
        Ok(shard)
    }

    fn meta_from_stats(addr: &str, stats: &Json) -> Result<WorkerMeta> {
        let n_nodes = stats
            .get("n_nodes")
            .and_then(|v| v.as_usize())
            .map_err(|e| Error::Runtime(format!("worker {addr}: bad stats handshake: {e}")))?;
        let dim = stats
            .get("dim")
            .and_then(|v| v.as_usize())
            .map_err(|e| Error::Runtime(format!("worker {addr}: bad stats handshake: {e}")))?;
        let model =
            stats.opt("model").and_then(|v| v.as_str().ok()).unwrap_or_default().to_string();
        let (lo, hi, index, count) = match stats.opt("shard") {
            Some(s) => (
                s.get("lo")?.as_usize()? as u32,
                s.get("hi")?.as_usize()? as u32,
                s.get("index")?.as_usize()?,
                s.get("count")?.as_usize()?,
            ),
            None => (0, n_nodes as u32, 0, 1),
        };
        Ok(WorkerMeta { n_nodes, dim, model, lo, hi, index, count })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn meta(&self) -> &WorkerMeta {
        &self.meta
    }

    pub fn is_up(&self) -> bool {
        self.health == Health::Up
    }

    fn dial(&self) -> std::io::Result<TcpStream> {
        let sockaddr = self
            .addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::Other,
                    format!("{}: no address", self.addr),
                )
            })?;
        let stream = TcpStream::connect_timeout(&sockaddr, self.cfg.connect_timeout)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(self.cfg.request_timeout))?;
        stream.set_write_timeout(Some(self.cfg.request_timeout))?;
        Ok(stream)
    }

    /// One request/response round trip on the pooled connection. ANY
    /// failure — dial, write, timed-out/torn read, unparseable response —
    /// drops the connection before returning the error, so the next
    /// attempt starts on a clean stream.
    fn request_once(&mut self, line: &str) -> Result<Json> {
        let r = self.try_round_trip(line);
        if r.is_err() {
            self.conn = None;
        }
        r
    }

    /// Pipelined write: one request goes on the wire now, its response
    /// is collected later by [`Self::finish_request`]. A write failure
    /// tears the connection down (nothing is in flight afterwards).
    /// Exactly ONE request may be in flight per shard — the NDJSON
    /// worker answers strictly in order, so begin/finish pairs on the
    /// same connection can never interleave responses.
    fn begin_request(&mut self, line: &str) -> Result<()> {
        let r = self.write_request(line);
        if r.is_err() {
            self.conn = None;
        }
        r
    }

    /// Collect the response to a successful [`Self::begin_request`].
    /// Any read/parse failure tears the connection down, so a torn
    /// response can never de-sync framing for the next request.
    fn finish_request(&mut self) -> Result<Json> {
        let r = self.read_response();
        if r.is_err() {
            self.conn = None;
        }
        r
    }

    fn try_round_trip(&mut self, line: &str) -> Result<Json> {
        self.write_request(line)?;
        self.read_response()
    }

    /// Write half of one round trip: establish/reuse the pooled
    /// connection and put the request line on the wire. No teardown on
    /// error — callers decide (the retrying paths drop the connection).
    fn write_request(&mut self, line: &str) -> Result<()> {
        if self.conn.is_none() {
            self.conn = Some(BufReader::new(self.dial()?));
        }
        let conn = self.conn.as_mut().expect("established above");
        let stream = conn.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        Ok(())
    }

    /// Read half: one bounded response line, parsed. No teardown here
    /// either (see [`Self::write_request`]).
    fn read_response(&mut self) -> Result<Json> {
        let conn = self.conn.as_mut().ok_or_else(|| {
            Error::Runtime(format!("worker {}: no connection to read from", self.addr))
        })?;
        let mut buf = Vec::new();
        match read_bounded_line(conn, self.cfg.max_line_bytes, &mut buf)? {
            RawLine::Line => {}
            RawLine::Eof => {
                return Err(Error::Runtime(format!(
                    "worker {}: connection closed mid-request",
                    self.addr
                )))
            }
            RawLine::TooLong => {
                return Err(Error::Runtime(format!(
                    "worker {}: response line exceeds {} bytes",
                    self.addr, self.cfg.max_line_bytes
                )))
            }
        }
        let text = std::str::from_utf8(&buf)
            .map_err(|_| Error::Runtime(format!("worker {}: non-UTF-8 response", self.addr)))?;
        ser::parse(text.trim()).map_err(|e| {
            Error::Runtime(format!("worker {}: unparseable response: {e}", self.addr))
        })
    }

    /// Round trip with the retry policy: `retries + 1` attempts,
    /// exponential backoff between them; exhaustion marks the worker
    /// `Down` (the health loop re-admits it later).
    fn request(&mut self, line: &str) -> Result<Json> {
        let mut last = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 && !self.cfg.backoff.is_zero() {
                std::thread::sleep(self.cfg.backoff * (1u32 << (attempt - 1).min(16)));
            }
            match self.request_once(line) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        self.health = Health::Down;
        self.last_probe = Instant::now();
        Err(last.expect("at least one attempt ran"))
    }

    /// Single-attempt `stats` ping; success re-admits the worker.
    pub fn health_check(&mut self) -> bool {
        self.last_probe = Instant::now();
        match self.request_once(r#"{"op": "stats"}"#) {
            Ok(_) => {
                self.health = Health::Up;
                true
            }
            Err(_) => {
                self.health = Health::Down;
                false
            }
        }
    }

    /// Is this worker routable right now? `Up` passes; `Down` triggers a
    /// health probe once `health_every` has elapsed since the last one
    /// (zero re-probes immediately — dead workers re-admit on the first
    /// request after restart).
    fn available(&mut self) -> bool {
        match self.health {
            Health::Up => true,
            Health::Down => {
                if self.last_probe.elapsed() >= self.cfg.health_every {
                    self.health_check()
                } else {
                    false
                }
            }
        }
    }
}

fn ids_json(ids: &[u32]) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::num(i as f64)).collect())
}

/// K worker processes behind one [`Serving`] front.
pub struct RemoteRouter {
    /// Workers sorted by owned range (`shards[i]` owns `ranges[i]`).
    shards: Vec<RemoteShard>,
    ranges: Vec<(u32, u32)>,
    n_nodes: usize,
    d: usize,
    name: String,
    declared: usize,
    /// Pipeline flushes across the fleet (`RemoteCfg::fanout`).
    fanout: bool,
    /// Fan-out telemetry for the most recent flush, drained by
    /// [`Serving::take_fanout_report`].
    last_fanout: Option<FanoutReport>,
}

impl RemoteRouter {
    /// Connect to every worker and validate the fleet: all must be up at
    /// startup, serve the same export (name, node count, dim), and their
    /// owned ranges must tile `[0, n)` with no gap or overlap.
    pub fn connect(addrs: &[String], cfg: RemoteCfg) -> Result<Self> {
        if addrs.is_empty() {
            return Err(Error::Config("remote router needs at least one worker address".into()));
        }
        let mut shards: Vec<RemoteShard> =
            addrs.iter().map(|a| RemoteShard::connect(a, cfg)).collect::<Result<_>>()?;
        let (name, n_nodes, d) = {
            let m = shards[0].meta();
            (m.model.clone(), m.n_nodes, m.dim)
        };
        for s in &shards[1..] {
            let m = s.meta();
            if m.model != name || m.n_nodes != n_nodes || m.dim != d {
                return Err(Error::Config(format!(
                    "mixed worker fleet: {} serves '{}' ({} nodes, dim {}) vs '{name}' \
                     ({n_nodes} nodes, dim {d})",
                    s.addr(),
                    m.model,
                    m.n_nodes,
                    m.dim
                )));
            }
        }
        shards.sort_by_key(|s| s.meta().lo);
        let declared = shards[0].meta().count;
        let mut ranges = Vec::with_capacity(shards.len());
        let mut expect_lo = 0u32;
        for s in &shards {
            let m = s.meta();
            if m.lo != expect_lo {
                return Err(Error::Config(format!(
                    "worker ranges do not tile the node space: {} owns [{}, {}) but the \
                     previous range ends at {expect_lo}",
                    s.addr(),
                    m.lo,
                    m.hi
                )));
            }
            ranges.push((m.lo, m.hi));
            expect_lo = m.hi;
        }
        if expect_lo as usize != n_nodes {
            return Err(Error::Config(format!(
                "worker ranges cover [0, {expect_lo}) but the export has {n_nodes} nodes"
            )));
        }
        Ok(Self {
            shards,
            ranges,
            n_nodes,
            d,
            name,
            declared,
            fanout: cfg.fanout,
            last_fanout: None,
        })
    }

    /// Owning worker of a (validated) node id.
    fn owner(&self, id: u32) -> usize {
        self.ranges.partition_point(|&(lo, _)| lo <= id) - 1
    }

    /// Group `ids` by owning worker, preserving each id's slot in the
    /// request order.
    fn group(&self, ids: &[u32]) -> (Vec<Vec<u32>>, Vec<Vec<usize>>) {
        let k = self.shards.len();
        let mut per_ids: Vec<Vec<u32>> = vec![Vec::new(); k];
        let mut per_slots: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (slot, &id) in ids.iter().enumerate() {
            let s = self.owner(id);
            per_ids[s].push(id);
            per_slots[s].push(slot);
        }
        (per_ids, per_slots)
    }

    fn check_ids(&self, ids: &[u32]) -> Result<()> {
        for &id in ids {
            if id as usize >= self.n_nodes {
                return Err(Error::Shape(format!(
                    "node id {id} out of range [0, {})",
                    self.n_nodes
                )));
            }
        }
        Ok(())
    }
}

impl Serving for RemoteRouter {
    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn embed_dim(&self) -> usize {
        self.d
    }

    fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        let part = self.embed_nodes_partial(ids)?;
        if let Some((id, msg)) = part.failed.iter().next() {
            return Err(Error::Runtime(format!("node {id}: {msg}")));
        }
        Ok(part.rows)
    }

    /// Best-effort embedding across the fleet: each worker serves the
    /// ids it owns; an unavailable or exhausted-retries worker fails
    /// *only its own ids* with [`SHARD_UNAVAILABLE`], and application
    /// errors from a live worker carry through verbatim. Rows that do
    /// arrive are the worker's served f64 text round-tripped back to
    /// f32 — exact, so remote bytes match local bytes.
    ///
    /// With fan-out on and more than one worker involved, the flush is
    /// **pipelined**: every worker's sub-request is written first (one
    /// in flight per pooled socket), then responses are read in
    /// ascending shard order. Any worker whose pipelined attempt faults
    /// falls back to the normal [`RemoteShard::request`] retry/backoff
    /// path, so the fault model above is unchanged — and so are the
    /// merged bytes, since each worker computes the exact sub-request
    /// the sequential walk would send it.
    fn embed_nodes_partial(&mut self, ids: &[u32]) -> Result<super::PartialRows> {
        self.check_ids(ids)?;
        let d = self.d;
        let mut part = super::PartialRows {
            rows: vec![0.0f32; ids.len() * d],
            failed: Default::default(),
        };
        let (per_ids, per_slots) = self.group(ids);
        let k = self.shards.len();
        let fail_all = |part: &mut super::PartialRows, shard_ids: &[u32], msg: &str| {
            for &id in shard_ids {
                part.failed.insert(id, msg.to_string());
            }
        };
        // Availability + request lines, ascending (health probes happen
        // here, exactly where the sequential walk ran them).
        let mut lines: Vec<Option<String>> = (0..k).map(|_| None).collect();
        for (s, shard_ids) in per_ids.iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            if !self.shards[s].available() {
                fail_all(&mut part, shard_ids, SHARD_UNAVAILABLE);
                continue;
            }
            lines[s] = Some(ser::to_string_compact(&Json::obj(vec![
                ("op", Json::str("embed")),
                ("nodes", ids_json(shard_ids)),
            ])));
        }
        let active = lines.iter().filter(|l| l.is_some()).count();
        let pipelined = self.fanout && active > 1;
        // Write phase: put every sub-request on the wire before reading
        // any response. A failed write just means that worker takes the
        // sequential fallback below.
        let mut in_flight = vec![false; k];
        if pipelined {
            for s in 0..k {
                if let Some(line) = &lines[s] {
                    in_flight[s] = self.shards[s].begin_request(line).is_ok();
                }
            }
        }
        // Read/merge phase: ascending shard index, same as sequential.
        let mut waits: Vec<u64> = Vec::with_capacity(active);
        for s in 0..k {
            let Some(line) = lines[s].take() else { continue };
            let shard_ids = &per_ids[s];
            let t0 = Instant::now();
            let resp = if in_flight[s] {
                // One pipelined attempt, then the full retry path — the
                // retrying request dials a fresh connection, so a torn
                // pipelined response can't bleed into it.
                self.shards[s].finish_request().or_else(|_| self.shards[s].request(&line))
            } else {
                self.shards[s].request(&line)
            };
            waits.push(t0.elapsed().as_micros() as u64);
            let resp = match resp {
                Ok(v) => v,
                Err(_) => {
                    fail_all(&mut part, shard_ids, SHARD_UNAVAILABLE);
                    continue;
                }
            };
            if let Some(err) = resp.opt("error").and_then(|e| e.as_str().ok()) {
                fail_all(&mut part, shard_ids, err);
                continue;
            }
            let parsed: Result<()> = (|| {
                let rows = resp.get("embeddings")?.as_arr()?;
                if rows.len() != shard_ids.len() {
                    return Err(Error::Runtime(format!(
                        "worker {}: {} rows for {} ids",
                        self.shards[s].addr(),
                        rows.len(),
                        shard_ids.len()
                    )));
                }
                for (j, row) in rows.iter().enumerate() {
                    let vals = row.as_f64_vec()?;
                    if vals.len() != d {
                        return Err(Error::Runtime(format!(
                            "worker {}: row of {} values, dim is {d}",
                            self.shards[s].addr(),
                            vals.len()
                        )));
                    }
                    let slot = per_slots[s][j];
                    for (c, &v) in vals.iter().enumerate() {
                        part.rows[slot * d + c] = v as f32;
                    }
                }
                Ok(())
            })();
            if parsed.is_err() {
                // A malformed body from a live worker is a fault, not an
                // answer: fail its ids rather than serve damaged rows.
                fail_all(&mut part, shard_ids, SHARD_UNAVAILABLE);
            }
        }
        self.last_fanout = Some(FanoutReport {
            width: if pipelined { active } else { active.min(1) },
            shard_wait_us: waits,
        });
        Ok(part)
    }

    fn classes_from_rows(&self, _h: &[f32], _rows: usize) -> Result<(Vec<f32>, Vec<usize>)> {
        Err(Error::Runtime(
            "remote backend applies the classifier head worker-side (classes_for_ids)".into(),
        ))
    }

    /// Forward `{"op": "classes"}` to each owning worker (the head
    /// parameters live worker-side) and merge the argmax back into
    /// request order. Logits are not transported — the NDJSON response
    /// only carries the argmax.
    fn classes_for_ids(&mut self, ids: &[u32]) -> Result<(Vec<f32>, Vec<usize>)> {
        self.check_ids(ids)?;
        let mut argmax = vec![0usize; ids.len()];
        let (per_ids, per_slots) = self.group(ids);
        for (s, shard_ids) in per_ids.iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            if !self.shards[s].available() {
                return Err(Error::Runtime(SHARD_UNAVAILABLE.into()));
            }
            let line = ser::to_string_compact(&Json::obj(vec![
                ("op", Json::str("classes")),
                ("nodes", ids_json(shard_ids)),
            ]));
            let resp = self.shards[s]
                .request(&line)
                .map_err(|_| Error::Runtime(SHARD_UNAVAILABLE.into()))?;
            if let Some(err) = resp.opt("error").and_then(|e| e.as_str().ok()) {
                return Err(Error::Runtime(err.to_string()));
            }
            let classes = resp.get("classes")?.as_usize_vec()?;
            if classes.len() != shard_ids.len() {
                return Err(Error::Runtime(format!(
                    "worker {}: {} classes for {} ids",
                    self.shards[s].addr(),
                    classes.len(),
                    shard_ids.len()
                )));
            }
            for (j, &c) in classes.iter().enumerate() {
                argmax[per_slots[s][j]] = c;
            }
        }
        Ok((Vec::new(), argmax))
    }

    fn stats_json(&self) -> Json {
        let workers: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("addr", Json::str(s.addr())),
                    ("up", Json::Bool(s.is_up())),
                    ("lo", Json::num(s.meta().lo as f64)),
                    ("hi", Json::num(s.meta().hi as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("shards", Json::num(self.declared as f64)),
            ("workers", Json::Arr(workers)),
        ])
    }

    fn model_name(&self) -> String {
        self.name.clone()
    }

    fn take_fanout_report(&mut self) -> Option<FanoutReport> {
        self.last_fanout.take()
    }
}
