//! Bounded LRU cache of decoded node embeddings, keyed by node id.
//!
//! Serving traffic is heavily skewed (a few hub nodes dominate edge
//! queries), so the session keeps the most recently used embeddings
//! resident and only decodes misses. The cache is **exact**: capacity is
//! a hard bound, eviction is strict least-recently-used (every hit and
//! insert refreshes recency), and the hit/miss/eviction counters account
//! for every lookup — all asserted by the tests. Because the compute path
//! is bit-deterministic, a cached embedding is byte-for-byte the one a
//! cold computation would produce, so caching can never change results.

use std::collections::HashMap;

/// Counter snapshot (exact; one hit or miss per queried id).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
    pub capacity: usize,
}

impl CacheStats {
    /// Field-wise accumulation (the shard router aggregates per-shard
    /// caches through here; the exhaustive destructuring means a new
    /// counter cannot be silently dropped from aggregates).
    pub fn absorb(&mut self, o: &CacheStats) {
        let CacheStats { hits, misses, evictions, len, capacity } = o;
        self.hits += hits;
        self.misses += misses;
        self.evictions += evictions;
        self.len += len;
        self.capacity += capacity;
    }
}

struct Slot {
    emb: Vec<f32>,
    last_used: u64,
}

/// Bounded LRU of `d`-wide embeddings. `capacity == 0` disables caching
/// (every lookup is a miss, nothing is stored) — the "cold" reference
/// configuration the parity tests use.
///
/// ```
/// use hashgnn::serve::EmbedCache;
///
/// let mut c = EmbedCache::new(2, 3); // 2 entries, 3-wide rows
/// c.insert(7, vec![1.0, 2.0, 3.0]);
/// c.insert(8, vec![4.0, 5.0, 6.0]);
/// assert_eq!(c.get(7).unwrap(), &[1.0, 2.0, 3.0]); // refreshes 7's recency
/// c.insert(9, vec![7.0, 8.0, 9.0]); // evicts 8, now the least recently used
/// assert!(c.contains(7) && !c.contains(8));
/// let s = c.stats();
/// assert_eq!((s.hits, s.misses, s.evictions), (1, 0, 1));
/// ```
pub struct EmbedCache {
    capacity: usize,
    d: usize,
    map: HashMap<u32, Slot>,
    /// Monotonic logical clock; each touch gets a unique tick, so the LRU
    /// victim is always unambiguous (deterministic eviction).
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl EmbedCache {
    pub fn new(capacity: usize, d: usize) -> Self {
        Self {
            capacity,
            d,
            map: HashMap::with_capacity(capacity.min(1 << 20)),
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Embedding width this cache stores.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Look up one id, counting exactly one hit or miss and refreshing
    /// recency on hit.
    pub fn get(&mut self, id: u32) -> Option<&[f32]> {
        match self.map.get_mut(&id) {
            Some(slot) => {
                self.clock += 1;
                slot.last_used = self.clock;
                self.hits += 1;
                Some(&slot.emb)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) one embedding, evicting the least recently
    /// used entry if the capacity bound would be exceeded.
    pub fn insert(&mut self, id: u32, emb: Vec<f32>) {
        debug_assert_eq!(emb.len(), self.d, "cache stores {}-wide embeddings", self.d);
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        let tick = self.clock;
        if let Some(slot) = self.map.get_mut(&id) {
            slot.emb = emb;
            slot.last_used = tick;
            return;
        }
        if self.map.len() >= self.capacity {
            // Strict LRU victim: unique ticks make the minimum unambiguous.
            // The victim scan is O(capacity); at the default capacities
            // (thousands) that is noise next to a decode, but a tick-keyed
            // index is the upgrade path if eviction ever shows in profiles.
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(&k, _)| k)
                .expect("cache is non-empty at capacity");
            self.map.remove(&victim);
            self.evictions += 1;
        }
        self.map.insert(id, Slot { emb, last_used: tick });
    }

    pub fn contains(&self, id: u32) -> bool {
        self.map.contains_key(&id)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            len: self.map.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn emb(v: f32) -> Vec<f32> {
        vec![v, v + 1.0]
    }

    #[test]
    fn counters_are_exact_per_lookup() {
        let mut c = EmbedCache::new(4, 2);
        assert!(c.get(1).is_none());
        assert!(c.get(1).is_none());
        c.insert(1, emb(1.0));
        assert_eq!(c.get(1).unwrap(), emb(1.0).as_slice());
        assert!(c.get(2).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.len), (1, 3, 0, 1));
    }

    #[test]
    fn capacity_is_a_hard_bound_and_eviction_is_lru() {
        let mut c = EmbedCache::new(2, 2);
        c.insert(1, emb(1.0));
        c.insert(2, emb(2.0));
        assert_eq!(c.len(), 2);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(c.get(1).is_some());
        c.insert(3, emb(3.0));
        assert_eq!(c.len(), 2, "capacity bound");
        assert!(c.contains(1) && c.contains(3) && !c.contains(2), "2 was LRU");
        assert_eq!(c.stats().evictions, 1);
        // Refreshing an existing key neither grows nor evicts.
        c.insert(1, emb(10.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.get(1).unwrap(), emb(10.0).as_slice());
        // Now 3 is LRU (1 was just touched twice).
        c.insert(4, emb(4.0));
        assert!(c.contains(1) && c.contains(4) && !c.contains(3));
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn zero_capacity_disables_storage() {
        let mut c = EmbedCache::new(0, 2);
        c.insert(1, emb(1.0));
        assert!(c.is_empty());
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().misses, 1);
    }
}
