//! Shard router: serve a `hashgnn export --shards K` set as one id space.
//!
//! A [`ShardRouter`] owns one [`ServeSession`] per node-range shard and
//! presents the same [`Serving`] surface as a single session: each
//! request's node ids are routed to the shard whose `[lo, hi)` range owns
//! them, computed there, and scattered back into request order. Because
//! every shard serves its owned ids bit-identically to the unsharded
//! bundle (see the slicing rules in [`super::bundle`]), the router's
//! merged output is **bit-identical** to an unsharded [`ServeSession`]
//! over the same requests — `tests/serve_persistent.rs` asserts this for
//! embeddings, scores and class predictions at thread counts {1, 8}.
//!
//! Construction validates the set as a whole: every bundle must be a
//! shard of the same export (same manifest name, node count, shard
//! count, identical parameters), each index must appear exactly once,
//! and the owned ranges must tile `[0, n)` with no gap or overlap. A
//! missing or duplicated shard file is a loud constructor error, never a
//! partially-served id space.
//!
//! ```no_run
//! use std::path::PathBuf;
//! use hashgnn::serve::{ServeOpts, ShardRouter};
//!
//! let paths: Vec<PathBuf> =
//!     vec!["b.bin.shard-0-of-2".into(), "b.bin.shard-1-of-2".into()];
//! let mut router = ShardRouter::load(&paths, ServeOpts::default()).unwrap();
//! let emb = router.embed_nodes(&[0, 1, 2]).unwrap(); // routed + merged
//! assert_eq!(emb.len(), 3 * router.embed_dim());
//! ```

use std::path::PathBuf;
use std::time::Instant;

use crate::runtime::native::par;
use crate::ser::Json;
use crate::{Error, Result};

use super::{
    predict_classes_on, score_edges_on, CacheStats, FanoutReport, ServeOpts, ServeSession,
    Serving, ServingBundle,
};

/// K shard sessions behind one [`Serving`] front; see the module docs.
pub struct ShardRouter {
    /// Sessions sorted by owned range (`sessions[i]` owns `ranges[i]`).
    /// For the full-batch family this collapses to ONE session over the
    /// de-sharded bundle (see [`ShardRouter::new`]).
    sessions: Vec<ServeSession>,
    /// Contiguous owned ranges `[lo, hi)` tiling `[0, n)`, ascending.
    ranges: Vec<(u32, u32)>,
    /// Shard count the export declared (what [`ShardRouter::n_shards`]
    /// reports, independent of the session collapse above).
    declared: usize,
    n_nodes: usize,
    d: usize,
    /// Dispatch per-shard sub-requests concurrently (`ServeOpts::fanout`).
    /// Off, shards are walked sequentially; the served bytes are
    /// identical either way — only latency changes.
    fanout: bool,
    /// Fan-out telemetry for the most recent [`ShardRouter::embed_nodes`]
    /// call, drained by [`Serving::take_fanout_report`].
    last_fanout: Option<FanoutReport>,
}

impl ShardRouter {
    /// Build from a complete, validated shard set. `opts` (threads,
    /// cache capacity, sampling seed) apply to every shard session —
    /// the seed in particular must be uniform, since minibatch fan-out
    /// is seeded per `(seed, node id)`.
    pub fn new(bundles: Vec<ServingBundle>, opts: ServeOpts) -> Result<Self> {
        if bundles.is_empty() {
            return Err(Error::Config("shard router needs at least one bundle".into()));
        }
        let count = bundles.len();
        let name = bundles[0].manifest.name.clone();
        let n_nodes = bundles[0].n_nodes;
        let mut slots: Vec<Option<ServingBundle>> = (0..count).map(|_| None).collect();
        for b in bundles {
            let s = b.shard.as_ref().ok_or_else(|| {
                Error::Config(format!(
                    "bundle '{}' is not a shard — route only `export --shards K` outputs",
                    b.manifest.name
                ))
            })?;
            if b.manifest.name != name || b.n_nodes != n_nodes || s.count != count {
                return Err(Error::Config(format!(
                    "mixed shard set: '{}' ({} nodes, {} shards) vs '{name}' ({n_nodes} \
                     nodes, {count} shards)",
                    b.manifest.name, b.n_nodes, s.count
                )));
            }
            let idx = s.index;
            if idx >= count || slots[idx].is_some() {
                return Err(Error::Config(format!(
                    "shard index {idx} duplicated or out of range for {count} shards"
                )));
            }
            slots[idx] = Some(b);
        }
        let bundles: Vec<ServingBundle> =
            slots.into_iter().map(|s| s.expect("every index filled exactly once")).collect();
        // Parameters must be byte-identical across shards: the head demux
        // (classes_from_rows) runs on shard 0 for rows served anywhere.
        for b in &bundles[1..] {
            if b.params != bundles[0].params {
                return Err(Error::Config(
                    "shard set carries differing parameter tensors — shards of one export \
                     always share the trained store"
                        .into(),
                ));
            }
        }
        let mut ranges = Vec::with_capacity(count);
        let mut expect_lo = 0u32;
        for b in &bundles {
            let s = b.shard.as_ref().expect("checked above");
            if s.lo != expect_lo {
                return Err(Error::Config(format!(
                    "shard ranges do not tile the node space: shard {} starts at {} but the \
                     previous range ends at {expect_lo}",
                    s.index, s.lo
                )));
            }
            ranges.push((s.lo, s.hi));
            expect_lo = s.hi;
        }
        if expect_lo as usize != n_nodes {
            return Err(Error::Config(format!(
                "shard ranges cover [0, {expect_lo}) but the export has {n_nodes} nodes"
            )));
        }
        let fullbatch = bundles[0]
            .manifest
            .hyper_str("task")
            .map(|t| t.ends_with("_fullbatch"))
            .unwrap_or(false);
        let (sessions, ranges) = if fullbatch {
            // Full-batch shards replicate the whole graph and codes —
            // ownership is routing-only — so one session over the
            // de-sharded bundle serves every id and memoizes the
            // (n, hidden) H matrix ONCE instead of once per shard.
            let mut whole = bundles.into_iter().next().expect("validated non-empty set");
            whole.shard = None;
            (vec![ServeSession::new(whole, opts)?], vec![(0u32, n_nodes as u32)])
        } else {
            let mut sessions = Vec::with_capacity(count);
            for b in bundles {
                sessions.push(ServeSession::new(b, opts)?);
            }
            (sessions, ranges)
        };
        let d = sessions[0].embed_dim();
        Ok(Self {
            sessions,
            ranges,
            declared: count,
            n_nodes,
            d,
            fanout: opts.fanout,
            last_fanout: None,
        })
    }

    /// Load every shard file of one export and build the router
    /// (`ServeOpts::mmap` selects mapped vs heap-read backing per file).
    pub fn load(paths: &[PathBuf], opts: ServeOpts) -> Result<Self> {
        let bundles: Vec<ServingBundle> = paths
            .iter()
            .map(|p| ServingBundle::load_with(p, opts.mmap))
            .collect::<Result<_>>()?;
        Self::new(bundles, opts)
    }

    /// Shard count of the export (the declared split, even when the
    /// full-batch collapse serves it through fewer sessions).
    pub fn n_shards(&self) -> usize {
        self.declared
    }

    pub fn embed_dim(&self) -> usize {
        self.d
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Owning shard of a (validated) node id: its range index in the
    /// contiguous tiling.
    fn owner(&self, id: u32) -> usize {
        // partition_point returns the first range with lo > id; its
        // predecessor owns id because ranges tile [0, n).
        self.ranges.partition_point(|&(lo, _)| lo <= id) - 1
    }

    /// Serve embeddings for `ids`: route each id to its owning shard,
    /// compute per shard, scatter rows back into request order.
    ///
    /// With fan-out on, non-empty shards run **concurrently** on the
    /// shared worker pool, so a K-shard flush costs roughly the slowest
    /// shard instead of the sum. The merge always walks shards in
    /// ascending index order, and each shard computes exactly the
    /// sub-request the sequential walk would hand it, so the output
    /// bytes — and on failure, which shard's error surfaces — are
    /// identical in both modes. (Per-shard kernels that reach
    /// [`par::join_all`] from a pool worker run inline there, which
    /// keeps every kernel's deterministic chunking intact.)
    pub fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        for &id in ids {
            if id as usize >= self.n_nodes {
                return Err(Error::Shape(format!(
                    "node id {id} out of range [0, {})",
                    self.n_nodes
                )));
            }
        }
        let k = self.sessions.len();
        let mut per_shard_ids: Vec<Vec<u32>> = vec![Vec::new(); k];
        let mut per_shard_slots: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (slot, &id) in ids.iter().enumerate() {
            let s = self.owner(id);
            per_shard_ids[s].push(id);
            per_shard_slots[s].push(slot);
        }
        let active = per_shard_ids.iter().filter(|v| !v.is_empty()).count();
        let mut results: Vec<Option<Result<Vec<f32>>>> = (0..k).map(|_| None).collect();
        let mut waits: Vec<u64> = vec![0; k];
        let parallel = self.fanout && active > 1;
        if parallel {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                .sessions
                .iter_mut()
                .zip(per_shard_ids.iter())
                .zip(results.iter_mut().zip(waits.iter_mut()))
                .filter(|((_, ids), _)| !ids.is_empty())
                .map(|((sess, ids), (res, wait))| {
                    Box::new(move || {
                        let t0 = Instant::now();
                        *res = Some(sess.embed_nodes(ids));
                        *wait = t0.elapsed().as_micros() as u64;
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            par::join_all(jobs);
        } else {
            for s in 0..k {
                if per_shard_ids[s].is_empty() {
                    continue;
                }
                let t0 = Instant::now();
                let res = self.sessions[s].embed_nodes(&per_shard_ids[s]);
                waits[s] = t0.elapsed().as_micros() as u64;
                let failed = res.is_err();
                results[s] = Some(res);
                if failed {
                    break;
                }
            }
        }
        self.last_fanout = Some(FanoutReport {
            width: if parallel { active } else { active.min(1) },
            shard_wait_us: per_shard_ids
                .iter()
                .enumerate()
                .filter(|(_, v)| !v.is_empty())
                .map(|(s, _)| waits[s])
                .collect(),
        });
        // Deterministic merge: ascending shard index, first error wins —
        // exactly what the sequential walk produced.
        let d = self.d;
        let mut out = vec![0.0f32; ids.len() * d];
        for s in 0..k {
            let Some(res) = results[s].take() else { continue };
            let rows = res?;
            for (j, &slot) in per_shard_slots[s].iter().enumerate() {
                out[slot * d..(slot + 1) * d].copy_from_slice(&rows[j * d..(j + 1) * d]);
            }
        }
        Ok(out)
    }

    /// Serve edge scores; endpoints may live on different shards — each
    /// is embedded by its owner, the dot happens here, in the same
    /// ascending-dimension order as every other backend.
    pub fn score_edges(&mut self, edges: &[(u32, u32)]) -> Result<Vec<f32>> {
        score_edges_on(self, edges)
    }

    /// Serve class predictions (logits + argmax) for `ids`.
    pub fn predict_classes(&mut self, ids: &[u32]) -> Result<(Vec<f32>, Vec<usize>)> {
        predict_classes_on(self, ids)
    }

    /// Dispatch one wire request (same format as [`ServeSession::handle`]).
    pub fn handle(&mut self, req: &super::Request) -> Result<Json> {
        super::handle_on(self, req)
    }

    /// Run a request batch and wrap the responses with aggregate cache
    /// statistics.
    pub fn handle_all(&mut self, reqs: &[super::Request]) -> Result<Json> {
        super::handle_all_on(self, reqs)
    }

    /// Cache counters summed over every shard session.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.sessions {
            total.absorb(&s.cache_stats());
        }
        total
    }
}

impl Serving for ShardRouter {
    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn embed_dim(&self) -> usize {
        self.d
    }

    fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        ShardRouter::embed_nodes(self, ids)
    }

    fn classes_from_rows(&self, h: &[f32], rows: usize) -> Result<(Vec<f32>, Vec<usize>)> {
        // The head is row-wise and the trained parameters are replicated
        // (and verified identical) across shards, so any shard can apply
        // it to rows served anywhere.
        self.sessions[0].classes_from_rows(h, rows)
    }

    fn stats_json(&self) -> Json {
        let mut v = super::cache_stats_json(&self.cache_stats());
        if let Json::Obj(o) = &mut v {
            o.insert("shards".to_string(), Json::num(self.declared as f64));
        }
        v
    }

    fn model_name(&self) -> String {
        self.sessions[0].bundle().manifest.name.clone()
    }

    fn take_fanout_report(&mut self) -> Option<FanoutReport> {
        self.last_fanout.take()
    }

    fn bundle_meta(&self) -> Option<(u64, u64, bool)> {
        // Shards load independently (possibly in parallel workers), so
        // cold start is the slowest load; footprint is the summed files.
        let mut agg: Option<(u64, u64, bool)> = None;
        for s in &self.sessions {
            if let Some((us, bytes, q)) = s.bundle_meta() {
                let (aus, abytes, aq) = agg.unwrap_or((0, 0, false));
                agg = Some((aus.max(us), abytes + bytes, aq || q));
            }
        }
        agg
    }
}
