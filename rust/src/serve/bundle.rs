//! The frozen serving artifact: everything inference needs, nothing
//! training needs.
//!
//! A [`ServingBundle`] packs the manifest (the model contract), the
//! trained parameter tensors (no AdamW moments — serving never updates),
//! the bit-packed compositional codes (the paper's compressed node
//! representation, §3.1), and the message-passing edge list (for GNN
//! propagation / fan-out sampling). One file, self-contained: a serving
//! process needs no artifacts directory, no graph generator, and no
//! training code.
//!
//! # On-disk format v2 (`HGNB0002` whole bundle / `HGNS0002` shard)
//!
//! A fixed-offset section table ([`crate::ser::section`]): 64-byte
//! header, a checksummed directory of 64-byte-aligned typed sections,
//! then the payloads. Loading is **zero-copy**: one read (or one `mmap`
//! with the `mmap` cargo feature) of the file, directory + per-section
//! checksum verification, and then the packed code words
//! (`CODEWORD`), the flat edge array (`EDGES`) and the f32 parameters
//! (`PARAMF32`) are handed out as borrowed in-place slices of that one
//! backing buffer — no per-section `Vec` copies, no parse loop. Only the
//! manifest JSON (parsed), the tiny shard header, and the `present` id
//! list (binary-searched per request) are materialized.
//!
//! Sections (presence depends on the bundle):
//!
//! | tag        | contents (little-endian)                                |
//! |------------|---------------------------------------------------------|
//! | `MANIFEST` | manifest JSON text                                      |
//! | `SHARD`    | u64 ×4: lo, hi, index, count (shard files only)         |
//! | `PRESENT`  | ascending u32 global ids (shard files only)             |
//! | `PARAMDIR` | u64 count, then per param: enc (0=f32, 1=int8), rank, dims |
//! | `PARAMF32` | f32 data of every f32-encoded param, in param order     |
//! | `PARAMI8`  | u8 data of every int8-encoded param (quantized exports) |
//! | `QUANT`    | per int8 param, per row: f32 scale, f32 min             |
//! | `CODESMET` | u64 ×4: c, m, n, n_bits (coded models only)             |
//! | `CODEWORD` | packed `BitMatrix` u64 words (coded models only)        |
//! | `POSMAP`   | u32 per node: degree-rank position bucket (poshash only)|
//! | `EDGES`    | flat u32 pairs u₀ v₀ u₁ v₁ …                            |
//! | `META`     | u64: n_nodes                                            |
//!
//! **int8 quantization** (`export --quant int8`): every rank-2 parameter
//! is stored as asymmetric per-row int8 — `q = round((x − min)/scale)`
//! with `scale = (max − min)/255`, so `|x − (min + q·scale)| ≤ scale/2`.
//! Rank-1 params (biases, norms) stay f32: they are tiny and their error
//! is not amortized over a row. A quantized bundle is dequantized ONCE
//! into an owned param buffer at load (codes and edges stay in-place
//! views) and serving is bit-identical *to the quantized model*;
//! `tests/serve_bundle_v2.rs` gates the accuracy delta vs f32 on the
//! Table-1 analogs.
//!
//! **Back-compat:** the v1 envelope formats `HGNB0001`/`HGNS0001`
//! (sequential parse loop, owned copies) still load; the write path
//! emits v2 only ([`ServingBundle::save_legacy_v1`] exists for fixtures
//! and the cold-start before/after benches).
//!
//! # Shard files
//!
//! `hashgnn export --shards K` splits one bundle into K **contiguous
//! node-range shards** so a graph larger than one machine's memory can be
//! served by K processes behind a [`ShardRouter`](crate::serve::ShardRouter).
//! What gets sliced per shard depends on the model family, because
//! **served bytes must stay bit-identical to the unsharded session**:
//!
//! - *plain decoder* (`recon`): a node's embedding is a function of its
//!   own code only, so the shard keeps codes for its owned range and no
//!   edges;
//! - *minibatch SAGE*: fan-out sampling draws uniformly from a node's
//!   full (sorted, deduplicated) CSR neighbor list, and the per-node seed
//!   makes a node's two-hop sample a function of `(seed, id)` alone. The
//!   shard therefore keeps every edge incident to `owned ∪ N(owned)` —
//!   which reproduces the exact neighbor lists of all nodes sampling can
//!   draw *from* — plus codes for the two-hop closure
//!   `owned ∪ N(owned) ∪ N(N(owned))`, the set sampling can draw *to*;
//! - *full-batch GNNs*: every node's representation depends on the whole
//!   graph, so shards replicate edges and codes and the split only
//!   records ownership (the router still fans requests out across
//!   shards; the memory win is for the minibatch/decoder families, the
//!   paper's industrial serving case).
//!
//! Sliced codes are **row-compacted**: the shard's `BitMatrix` has one
//! row per retained node and the header's ascending `present` list maps
//! global node ids to rows. An empty `present` list means codes (when
//! present at all) are dense over all `n_nodes`. Node ids stay global
//! everywhere else — edges, requests, and sampling seeds never change
//! meaning across the split, which is what makes bit-parity provable
//! (`tests/serve_persistent.rs` asserts it).

use std::path::Path;
use std::sync::Arc;

use crate::cfg::CodingCfg;
use crate::codes::{BitMatrix, CodeTable};
use crate::graph::Graph;
use crate::params::ParamStore;
use crate::runtime::{Manifest, Tensor};
use crate::ser;
use crate::ser::section::{SectionBuf, SectionFile, SectionWriter, SharedF32s, SharedU32s};
use crate::{Error, Result};

const MAGIC_V1: &[u8; 8] = b"HGNB0001";
const SHARD_MAGIC_V1: &[u8; 8] = b"HGNS0001";
const MAGIC: &[u8; 8] = b"HGNB0002";
const SHARD_MAGIC: &[u8; 8] = b"HGNS0002";

const SEC_MANIFEST: [u8; 8] = *b"MANIFEST";
const SEC_SHARD: [u8; 8] = *b"SHARD\0\0\0";
const SEC_PRESENT: [u8; 8] = *b"PRESENT\0";
const SEC_PARAMDIR: [u8; 8] = *b"PARAMDIR";
const SEC_PARAMF32: [u8; 8] = *b"PARAMF32";
const SEC_PARAMI8: [u8; 8] = *b"PARAMI8\0";
const SEC_QUANT: [u8; 8] = *b"QUANT\0\0\0";
const SEC_CODESMET: [u8; 8] = *b"CODESMET";
const SEC_CODEWORD: [u8; 8] = *b"CODEWORD";
const SEC_POSMAP: [u8; 8] = *b"POSMAP\0\0";
const SEC_EDGES: [u8; 8] = *b"EDGES\0\0\0";
const SEC_META: [u8; 8] = *b"META\0\0\0\0";

/// Parameter encoding selector for [`ServingBundle::save_with`]
/// (`export --quant {f32,int8}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quant {
    F32,
    Int8,
}

impl Quant {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Quant::F32),
            "int8" => Ok(Quant::Int8),
            other => Err(Error::Config(format!(
                "unknown quantization '{other}' (expected f32 or int8)"
            ))),
        }
    }
}

/// How a loaded bundle came into memory — serving surfaces these in
/// `stats` (`bundle_load_us`, `bundle_bytes`, `quantized`). Never
/// serialized; freshly-assembled (unexported) bundles report zeros.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadMeta {
    /// Wall-clock µs from open to validated bundle (cold-start cost).
    pub load_us: u64,
    /// On-disk artifact size in bytes.
    pub file_bytes: u64,
    /// True when the file carried int8 params (dequantized at load).
    pub quantized: bool,
    /// True when codes/edges/params are in-place views of the file image
    /// (v2, non-quantized) rather than per-section heap copies.
    pub zero_copy: bool,
}

/// Trained parameter storage: owned tensors (assembly, v1 loads,
/// dequantized int8 loads) or one borrowed flat f32 view into the bundle
/// file image sliced by recorded shapes (v2 zero-copy loads). Inference
/// consumes `&[&[f32]]` either way
/// ([`InferModel::embed_nodes_with`](crate::runtime::native::infer::InferModel)).
#[derive(Clone, Debug)]
pub enum BundleParams {
    Owned(Vec<Tensor>),
    View { shapes: Vec<Vec<usize>>, data: SharedF32s },
}

impl BundleParams {
    pub fn len(&self) -> usize {
        match self {
            BundleParams::Owned(ts) => ts.len(),
            BundleParams::View { shapes, .. } => shapes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn shape(&self, i: usize) -> &[usize] {
        match self {
            BundleParams::Owned(ts) => ts[i].shape(),
            BundleParams::View { shapes, .. } => &shapes[i],
        }
    }

    /// Per-param f32 slices in manifest order — the layout inference
    /// kernels consume. For the view variant this is pure pointer
    /// arithmetic over the file image (element counts were validated at
    /// load).
    pub fn slices(&self) -> Result<Vec<&[f32]>> {
        match self {
            BundleParams::Owned(ts) => ts.iter().map(|t| t.as_f32()).collect(),
            BundleParams::View { shapes, data } => {
                let flat = data.as_slice();
                let mut out = Vec::with_capacity(shapes.len());
                let mut pos = 0usize;
                for shape in shapes {
                    let n: usize = shape.iter().product();
                    if pos + n > flat.len() {
                        return Err(Error::Shape(format!(
                            "param view needs {} f32s, backing holds {}",
                            pos + n,
                            flat.len()
                        )));
                    }
                    out.push(&flat[pos..pos + n]);
                    pos += n;
                }
                if pos != flat.len() {
                    return Err(Error::Shape(format!(
                        "param view leaves {} trailing f32s",
                        flat.len() - pos
                    )));
                }
                Ok(out)
            }
        }
    }

    /// Materialize owned tensors (training-side interop; copies the view
    /// variant — not on the serving path).
    pub fn to_tensors(&self) -> Result<Vec<Tensor>> {
        match self {
            BundleParams::Owned(ts) => Ok(ts.clone()),
            BundleParams::View { shapes, .. } => self
                .slices()?
                .into_iter()
                .zip(shapes)
                .map(|(s, shape)| Ok(Tensor::F32 { shape: shape.clone(), data: s.to_vec() }))
                .collect(),
        }
    }

    /// Total f32 element count across params.
    pub fn n_elements(&self) -> usize {
        (0..self.len()).map(|i| self.shape(i).iter().product::<usize>()).sum()
    }

    /// True when params are an in-place view of the bundle file image.
    pub fn borrowed(&self) -> bool {
        matches!(self, BundleParams::View { .. })
    }
}

/// Equality is by content (shapes + f32 bit patterns), regardless of
/// owned-vs-view representation — the shard router uses this to check
/// that every shard carries the same trained weights.
impl PartialEq for BundleParams {
    fn eq(&self, other: &Self) -> bool {
        if self.len() != other.len() {
            return false;
        }
        if (0..self.len()).any(|i| self.shape(i) != other.shape(i)) {
            return false;
        }
        match (self.slices(), other.slices()) {
            (Ok(a), Ok(b)) => a.iter().zip(&b).all(|(x, y)| {
                x.len() == y.len()
                    && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
            }),
            _ => false,
        }
    }
}

/// Message-passing edge storage: an owned pair `Vec` (assembly, v1
/// loads, shard slicing) or a borrowed flat `u₀ v₀ u₁ v₁ …` view into
/// the bundle file image (v2 loads — `(u32, u32)` tuple layout is not
/// guaranteed by Rust, so the flat form is what can be viewed in place).
#[derive(Clone, Debug)]
pub enum EdgeList {
    Owned(Vec<(u32, u32)>),
    View(SharedU32s),
}

impl EdgeList {
    pub fn len(&self) -> usize {
        match self {
            EdgeList::Owned(v) => v.len(),
            EdgeList::View(s) => s.len() / 2,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> (u32, u32) {
        match self {
            EdgeList::Owned(v) => v[i],
            EdgeList::View(s) => {
                let f = s.as_slice();
                (f[2 * i], f[2 * i + 1])
            }
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    pub fn to_vec(&self) -> Vec<(u32, u32)> {
        self.iter().collect()
    }

    /// True when edges are an in-place view of the bundle file image.
    pub fn borrowed(&self) -> bool {
        matches!(self, EdgeList::View(_))
    }
}

impl From<Vec<(u32, u32)>> for EdgeList {
    fn from(v: Vec<(u32, u32)>) -> Self {
        EdgeList::Owned(v)
    }
}

impl PartialEq for EdgeList {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter()).all(|(a, b)| a == b)
    }
}

impl PartialEq<Vec<(u32, u32)>> for EdgeList {
    fn eq(&self, other: &Vec<(u32, u32)>) -> bool {
        self.len() == other.len() && self.iter().zip(other.iter().copied()).all(|(a, b)| a == b)
    }
}

/// Shard header of a node-range bundle slice (`HGNS0002` files): which
/// contiguous global id range this shard **owns** (serves), where it sits
/// in the shard set, and which global ids its row-compacted code table
/// retains.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    /// Owned node range `[lo, hi)` in global ids — the only ids this
    /// shard may be asked to serve.
    pub lo: u32,
    pub hi: u32,
    /// Position of this shard in the set (`0..count`).
    pub index: usize,
    /// Total shards the bundle was split into.
    pub count: usize,
    /// Ascending global ids whose codes this shard retains (row `r` of
    /// the shard's `BitMatrix` is the code of `present[r]`). Empty means
    /// the codes — when the model has any — are dense over all `n_nodes`.
    pub present: Vec<u32>,
}

impl ShardInfo {
    /// True when `id` is in the owned range `[lo, hi)`.
    pub fn owns(&self, id: u32) -> bool {
        self.lo <= id && id < self.hi
    }

    /// Row of `id`'s code in the compacted table (`None` when the shard's
    /// codes are dense, identity-mapped, or `id` was not retained).
    pub fn code_row(&self, id: u32) -> Option<usize> {
        if self.present.is_empty() {
            return None;
        }
        self.present.binary_search(&id).ok()
    }
}

/// A frozen, self-contained serving artifact.
#[derive(Clone)]
pub struct ServingBundle {
    pub manifest: Manifest,
    /// Trained parameters in manifest order (shapes validated at
    /// construction and load); in-place views after a v2 f32 load.
    pub params: BundleParams,
    /// Bit-packed compositional codes for the coded front-ends; `None`
    /// for the NC baseline. Words are in-place views after a v2 load.
    pub codes: Option<CodeTable>,
    /// Undirected message-passing edges (empty for the plain decoder,
    /// whose inference needs no graph).
    pub edges: EdgeList,
    /// Degree-rank position buckets (one u32 per node) for the poshash
    /// hash-embedding front-end — computed from the *training* graph at
    /// export so serving never has to re-rank; `None` otherwise.
    pub pos_map: Option<Vec<u32>>,
    pub n_nodes: usize,
    /// `Some` when this bundle is one node-range shard of a split export
    /// ([`ServingBundle::split_shards`]); `None` for a whole-graph bundle.
    pub shard: Option<ShardInfo>,
    /// How this bundle was loaded (zeros for assembled-in-memory bundles).
    pub meta: LoadMeta,
}

impl ServingBundle {
    /// Assemble from a trained [`ParamStore`] (moments are dropped) plus
    /// the serving-side data. Validates the parameters against the
    /// manifest, the codes format against the hyper-parameters, and every
    /// edge endpoint against `n_nodes`.
    pub fn new(
        manifest: Manifest,
        store: &ParamStore,
        codes: Option<CodeTable>,
        edges: Vec<(u32, u32)>,
        n_nodes: usize,
    ) -> Result<Self> {
        let bundle = Self {
            manifest,
            params: BundleParams::Owned(store.params.clone()),
            codes,
            edges: EdgeList::Owned(edges),
            pos_map: None,
            n_nodes,
            shard: None,
            meta: LoadMeta::default(),
        };
        bundle.validate()?;
        Ok(bundle)
    }

    /// Attach the degree-rank position map a poshash front-end serves
    /// with (one bucket per node, validated against `n_nodes`).
    pub fn with_pos_map(mut self, map: Vec<u32>) -> Result<Self> {
        self.pos_map = Some(map);
        self.validate()?;
        Ok(self)
    }

    fn validate(&self) -> Result<()> {
        if self.params.len() != self.manifest.params.len() {
            return Err(Error::Shape(format!(
                "bundle has {} param tensors, manifest '{}' declares {}",
                self.params.len(),
                self.manifest.name,
                self.manifest.params.len()
            )));
        }
        for (i, spec) in self.manifest.params.iter().enumerate() {
            if self.params.shape(i) != spec.shape.as_slice() {
                return Err(Error::Shape(format!(
                    "bundle param '{}' has shape {:?}, manifest says {:?}",
                    spec.name,
                    self.params.shape(i),
                    spec.shape
                )));
            }
        }
        // Data must be reachable as f32 (rejects non-f32 owned tensors
        // and size-inconsistent views in one pass).
        self.params.slices()?;
        if let Some(s) = &self.shard {
            if s.lo >= s.hi || s.hi as usize > self.n_nodes {
                return Err(Error::Shape(format!(
                    "shard owns [{}, {}) which is not a non-empty range within {} nodes",
                    s.lo, s.hi, self.n_nodes
                )));
            }
            if s.index >= s.count {
                return Err(Error::Shape(format!(
                    "shard index {} out of range for {} shards",
                    s.index, s.count
                )));
            }
            if !s.present.is_empty() {
                if !s.present.windows(2).all(|w| w[0] < w[1]) {
                    return Err(Error::Shape(
                        "shard present-id list must be strictly ascending".into(),
                    ));
                }
                if s.present.last().map(|&v| v as usize >= self.n_nodes).unwrap_or(false) {
                    return Err(Error::Shape(format!(
                        "shard present id {} out of range for {} nodes",
                        s.present.last().unwrap(),
                        self.n_nodes
                    )));
                }
                // Every owned id must have its code retained.
                for id in s.lo..s.hi {
                    if s.present.binary_search(&id).is_err() {
                        return Err(Error::Shape(format!(
                            "shard owns node {id} but its code row is not retained"
                        )));
                    }
                }
            }
        }
        if let Some(codes) = &self.codes {
            // A shard with a non-empty present list carries a row-compacted
            // code table; everything else is dense over all nodes.
            let expect = match &self.shard {
                Some(s) if !s.present.is_empty() => s.present.len(),
                _ => self.n_nodes,
            };
            if codes.n() != expect {
                return Err(Error::Shape(format!(
                    "bundle codes cover {} entities, expected {expect}",
                    codes.n()
                )));
            }
            // When the manifest records a coding format, it must match.
            if let (Ok(c), Ok(m)) =
                (self.manifest.hyper_usize("c"), self.manifest.hyper_usize("m"))
            {
                if codes.coding.c != c || codes.coding.m != m {
                    return Err(Error::Shape(format!(
                        "bundle codes are (c={}, m={}), manifest '{}' wants (c={c}, m={m})",
                        codes.coding.c, codes.coding.m, self.manifest.name
                    )));
                }
            }
        }
        if let Some(pm) = &self.pos_map {
            if pm.len() != self.n_nodes {
                return Err(Error::Shape(format!(
                    "bundle position map covers {} nodes, expected {}",
                    pm.len(),
                    self.n_nodes
                )));
            }
            // When the manifest records the position-table height, every
            // bucket must be addressable.
            if let Ok(bp) = self.manifest.hyper_usize("hemb_bp") {
                if let Some(&bad) = pm.iter().find(|&&b| b as usize >= bp) {
                    return Err(Error::Shape(format!(
                        "bundle position map bucket {bad} out of range for a \
                         {bp}-row position table"
                    )));
                }
            }
        }
        for (u, v) in self.edges.iter() {
            if u as usize >= self.n_nodes || v as usize >= self.n_nodes {
                return Err(Error::Shape(format!(
                    "bundle edge ({u}, {v}) out of range for {} nodes",
                    self.n_nodes
                )));
            }
        }
        Ok(())
    }

    /// Serialized parameter footprint in bytes (f32).
    pub fn param_bytes(&self) -> usize {
        self.params.n_elements() * 4
    }

    /// Packed-code footprint in bytes (the Table-2 accounting unit).
    pub fn code_bytes(&self) -> usize {
        self.codes.as_ref().map(|c| c.bits.storage_bytes()).unwrap_or(0)
    }

    /// Write the v2 section-table format (f32 params). See the module
    /// docs for the layout; [`Self::save_with`] selects int8.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_with(path, Quant::F32)
    }

    /// Write the v2 format with the chosen parameter encoding.
    pub fn save_with(&self, path: &Path, quant: Quant) -> Result<()> {
        let magic = if self.shard.is_some() { SHARD_MAGIC } else { MAGIC };
        let mut w = SectionWriter::new();
        w.section(SEC_MANIFEST)
            .extend_from_slice(ser::to_string_pretty(&self.manifest.to_json()).as_bytes());
        if let Some(sh) = &self.shard {
            let s = w.section(SEC_SHARD);
            for v in [sh.lo as u64, sh.hi as u64, sh.index as u64, sh.count as u64] {
                s.extend_from_slice(&v.to_le_bytes());
            }
            let s = w.section(SEC_PRESENT);
            for &id in &sh.present {
                s.extend_from_slice(&id.to_le_bytes());
            }
        }
        // Params: directory first, then the f32 pool, then (for int8)
        // the quantized pool + per-row scales.
        let slices = self.params.slices()?;
        let quantize = |i: usize| quant == Quant::Int8 && self.params.shape(i).len() == 2;
        {
            let d = w.section(SEC_PARAMDIR);
            d.extend_from_slice(&(slices.len() as u64).to_le_bytes());
            for i in 0..slices.len() {
                let shape = self.params.shape(i);
                d.extend_from_slice(&(quantize(i) as u64).to_le_bytes());
                d.extend_from_slice(&(shape.len() as u64).to_le_bytes());
                for &dim in shape {
                    d.extend_from_slice(&(dim as u64).to_le_bytes());
                }
            }
        }
        {
            let f = w.section(SEC_PARAMF32);
            for (i, s) in slices.iter().enumerate() {
                if !quantize(i) {
                    for &x in *s {
                        f.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        if quant == Quant::Int8 {
            let mut qdata: Vec<u8> = Vec::new();
            let mut qmeta: Vec<u8> = Vec::new();
            for (i, s) in slices.iter().enumerate() {
                if quantize(i) {
                    let cols = self.params.shape(i)[1];
                    let (q, rows_meta) = quantize_rows(s, cols);
                    qdata.extend_from_slice(&q);
                    for &x in &rows_meta {
                        qmeta.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
            w.section(SEC_PARAMI8).extend_from_slice(&qdata);
            w.section(SEC_QUANT).extend_from_slice(&qmeta);
        }
        if let Some(codes) = &self.codes {
            let s = w.section(SEC_CODESMET);
            for v in [
                codes.coding.c as u64,
                codes.coding.m as u64,
                codes.bits.n() as u64,
                codes.bits.n_bits() as u64,
            ] {
                s.extend_from_slice(&v.to_le_bytes());
            }
            let s = w.section(SEC_CODEWORD);
            for &word in codes.bits.words() {
                s.extend_from_slice(&word.to_le_bytes());
            }
        }
        if let Some(pm) = &self.pos_map {
            let s = w.section(SEC_POSMAP);
            for &b in pm {
                s.extend_from_slice(&b.to_le_bytes());
            }
        }
        {
            let s = w.section(SEC_EDGES);
            for (u, v) in self.edges.iter() {
                s.extend_from_slice(&u.to_le_bytes());
                s.extend_from_slice(&v.to_le_bytes());
            }
        }
        w.section(SEC_META).extend_from_slice(&(self.n_nodes as u64).to_le_bytes());
        std::fs::write(path, w.finish(magic)?)?;
        Ok(())
    }

    /// Write the superseded v1 envelope format (sequential parse loop,
    /// per-section copies on load). Kept for back-compat fixtures and
    /// the cold-start before/after benches; the CLI export path emits
    /// v2 only.
    pub fn save_legacy_v1(&self, path: &Path) -> Result<()> {
        if self.pos_map.is_some() {
            return Err(Error::Config(
                "the legacy v1 envelope has no POSMAP section — export poshash \
                 bundles in the default v2 format"
                    .into(),
            ));
        }
        let mut p: Vec<u8> = Vec::new();
        let magic = match &self.shard {
            Some(s) => {
                p.extend_from_slice(&(s.lo as u64).to_le_bytes());
                p.extend_from_slice(&(s.hi as u64).to_le_bytes());
                p.extend_from_slice(&(s.index as u64).to_le_bytes());
                p.extend_from_slice(&(s.count as u64).to_le_bytes());
                p.extend_from_slice(&(s.present.len() as u64).to_le_bytes());
                for &id in &s.present {
                    p.extend_from_slice(&id.to_le_bytes());
                }
                SHARD_MAGIC_V1
            }
            None => MAGIC_V1,
        };
        let manifest_json = ser::to_string_pretty(&self.manifest.to_json());
        p.extend_from_slice(&(manifest_json.len() as u64).to_le_bytes());
        p.extend_from_slice(manifest_json.as_bytes());
        let slices = self.params.slices()?;
        p.extend_from_slice(&(slices.len() as u64).to_le_bytes());
        for (i, data) in slices.iter().enumerate() {
            let shape = self.params.shape(i);
            p.extend_from_slice(&(shape.len() as u64).to_le_bytes());
            for &d in shape {
                p.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in *data {
                p.extend_from_slice(&x.to_le_bytes());
            }
        }
        match &self.codes {
            None => p.push(0u8),
            Some(codes) => {
                p.push(1u8);
                p.extend_from_slice(&(codes.coding.c as u64).to_le_bytes());
                p.extend_from_slice(&(codes.coding.m as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n() as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n_bits() as u64).to_le_bytes());
                for &word in codes.bits.words() {
                    p.extend_from_slice(&word.to_le_bytes());
                }
            }
        }
        p.extend_from_slice(&(self.edges.len() as u64).to_le_bytes());
        for (u, v) in self.edges.iter() {
            p.extend_from_slice(&u.to_le_bytes());
            p.extend_from_slice(&v.to_le_bytes());
        }
        p.extend_from_slice(&(self.n_nodes as u64).to_le_bytes());
        std::fs::write(path, ser::write_envelope(magic, &p))?;
        Ok(())
    }

    /// Load a whole bundle or one shard, any format version, heap-read
    /// backing. [`ServingBundle::shard`] distinguishes bundle vs shard
    /// after the fact; [`ServingBundle::meta`] records how the load went.
    pub fn load(path: &Path) -> Result<Self> {
        Self::load_with(path, false)
    }

    /// [`Self::load`] with an explicit backing choice. `use_mmap` maps
    /// the file instead of heap-reading it (v2 views then point at
    /// shared pages) and requires the `mmap` cargo feature.
    pub fn load_with(path: &Path, use_mmap: bool) -> Result<Self> {
        let t0 = std::time::Instant::now();
        let buf: Arc<SectionBuf> = if use_mmap {
            #[cfg(all(feature = "mmap", unix))]
            {
                SectionBuf::map(path)?
            }
            #[cfg(not(all(feature = "mmap", unix)))]
            {
                return Err(Error::Config(
                    "mmap bundle loading requires building with `--features mmap` \
                     (heap loading serves byte-identically without it)"
                        .into(),
                ));
            }
        } else {
            SectionBuf::read_heap(path)?
        };
        let file_bytes = buf.len() as u64;
        let is_v1 = {
            let bytes = buf.bytes();
            bytes.len() >= 8 && (&bytes[..8] == MAGIC_V1 || &bytes[..8] == SHARD_MAGIC_V1)
        };
        let mut bundle = if is_v1 {
            Self::decode_v1(buf.bytes(), path)?
        } else {
            let sf = SectionFile::parse(buf, &[MAGIC, SHARD_MAGIC], "serving bundle or shard", path)?;
            Self::decode_v2(&sf, sf.magic_index() == 1, path)?
        };
        bundle.validate()?;
        bundle.meta.load_us = t0.elapsed().as_micros() as u64;
        bundle.meta.file_bytes = file_bytes;
        Ok(bundle)
    }

    /// v2 read path: every section is already checksum-verified; codes,
    /// edges and f32 params become in-place views of the file image —
    /// zero payload copies. int8 params are dequantized once into owned
    /// tensors (the only decode work a quantized bundle does).
    fn decode_v2(sf: &SectionFile, sharded: bool, path: &Path) -> Result<Self> {
        let manifest = Manifest::from_json(&ser::parse(sf.text(SEC_MANIFEST)?)?)?;
        let shard = if sharded {
            let h = sf.u64s(SEC_SHARD)?;
            let h = h.as_slice();
            if h.len() != 4 {
                return Err(Error::Config(format!(
                    "{}: SHARD section holds {} u64s, expected 4",
                    path.display(),
                    h.len()
                )));
            }
            let lo = u32::try_from(h[0])
                .map_err(|_| Error::Config("shard lo exceeds u32 range".into()))?;
            let hi = u32::try_from(h[1])
                .map_err(|_| Error::Config("shard hi exceeds u32 range".into()))?;
            // The present list is owned: it is tiny relative to payloads
            // and ShardInfo binary-searches it per request.
            let present = sf.u32s(SEC_PRESENT)?.as_slice().to_vec();
            Some(ShardInfo { lo, hi, index: h[2] as usize, count: h[3] as usize, present })
        } else {
            None
        };

        // Param directory: count, then (enc, rank, dims…) per param.
        let dir = sf.u64s(SEC_PARAMDIR)?;
        let dir = dir.as_slice();
        let mut pos = 0usize;
        let next = |pos: &mut usize| -> Result<u64> {
            let v = dir.get(*pos).copied().ok_or_else(|| {
                Error::Config(format!("{}: PARAMDIR section ends early", path.display()))
            })?;
            *pos += 1;
            Ok(v)
        };
        let n_params = next(&mut pos)? as usize;
        if n_params > dir.len() {
            return Err(Error::Config(format!(
                "{}: PARAMDIR declares {n_params} params, section too small",
                path.display()
            )));
        }
        let mut encs = Vec::with_capacity(n_params);
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            let enc = next(&mut pos)?;
            if enc > 1 {
                return Err(Error::Config(format!(
                    "{}: unknown param encoding {enc} (expected 0=f32, 1=int8)",
                    path.display()
                )));
            }
            let rank = next(&mut pos)? as usize;
            if rank > 8 {
                return Err(Error::Config(format!(
                    "{}: param rank {rank} exceeds the sanity cap",
                    path.display()
                )));
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(next(&mut pos)? as usize);
            }
            encs.push(enc);
            shapes.push(shape);
        }
        let quantized = encs.iter().any(|&e| e == 1);
        let f32_pool = sf.f32s(SEC_PARAMF32)?;
        let params = if !quantized {
            // Pure view: one flat f32 slice of the image, split by shape
            // at access time. Element-count consistency checked here so
            // `slices()` is infallible in practice.
            let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
            if total != f32_pool.len() {
                return Err(Error::Config(format!(
                    "{}: PARAMF32 holds {} f32s, directory shapes need {total}",
                    path.display(),
                    f32_pool.len()
                )));
            }
            BundleParams::View { shapes, data: f32_pool }
        } else {
            // Dequantize once into owned tensors; codes/edges below stay
            // views regardless.
            let f32_pool = f32_pool.as_slice();
            let qdata = sf.bytes(SEC_PARAMI8)?;
            let qdata = qdata.as_slice();
            let qmeta = sf.f32s(SEC_QUANT)?;
            let qmeta = qmeta.as_slice();
            let (mut fpos, mut qpos, mut mpos) = (0usize, 0usize, 0usize);
            let mut tensors = Vec::with_capacity(n_params);
            for (shape, &enc) in shapes.iter().zip(&encs) {
                let n: usize = shape.iter().product();
                let data = if enc == 0 {
                    if fpos + n > f32_pool.len() {
                        return Err(Error::Config(format!(
                            "{}: PARAMF32 section ends early",
                            path.display()
                        )));
                    }
                    let d = f32_pool[fpos..fpos + n].to_vec();
                    fpos += n;
                    d
                } else {
                    let (rows, cols) = (shape[0], shape[1]);
                    if qpos + n > qdata.len() || mpos + rows * 2 > qmeta.len() {
                        return Err(Error::Config(format!(
                            "{}: PARAMI8/QUANT sections end early",
                            path.display()
                        )));
                    }
                    let d = dequantize_rows(
                        &qdata[qpos..qpos + n],
                        &qmeta[mpos..mpos + rows * 2],
                        cols,
                    );
                    qpos += n;
                    mpos += rows * 2;
                    d
                };
                tensors.push(Tensor::F32 { shape: shape.clone(), data });
            }
            if fpos != f32_pool.len() || qpos != qdata.len() || mpos != qmeta.len() {
                return Err(Error::Config(format!(
                    "{}: param sections carry trailing bytes",
                    path.display()
                )));
            }
            BundleParams::Owned(tensors)
        };

        let codes = if sf.has(SEC_CODESMET) {
            let met = sf.u64s(SEC_CODESMET)?;
            let met = met.as_slice();
            if met.len() != 4 {
                return Err(Error::Config(format!(
                    "{}: CODESMET section holds {} u64s, expected 4",
                    path.display(),
                    met.len()
                )));
            }
            let (c, m, n, n_bits) =
                (met[0] as usize, met[1] as usize, met[2] as usize, met[3] as usize);
            let bits = BitMatrix::from_shared_words(n, n_bits, sf.u64s(SEC_CODEWORD)?)?;
            Some(CodeTable::new(bits, CodingCfg::new(c, m)?)?)
        } else {
            None
        };

        let pos_map = if sf.has(SEC_POSMAP) {
            // Owned: tiny (one u32 per node) and consumed as an
            // `Arc<Vec<u32>>` by the model binding anyway.
            Some(sf.u32s(SEC_POSMAP)?.as_slice().to_vec())
        } else {
            None
        };

        let edge_view = sf.u32s(SEC_EDGES)?;
        if edge_view.len() % 2 != 0 {
            return Err(Error::Config(format!(
                "{}: EDGES section holds {} u32s (odd — not u,v pairs)",
                path.display(),
                edge_view.len()
            )));
        }
        let edges = EdgeList::View(edge_view);

        let meta_sec = sf.u64s(SEC_META)?;
        let meta_sec = meta_sec.as_slice();
        if meta_sec.is_empty() {
            return Err(Error::Config(format!("{}: META section is empty", path.display())));
        }
        let n_nodes = meta_sec[0] as usize;

        Ok(Self {
            manifest,
            params,
            codes,
            edges,
            pos_map,
            n_nodes,
            shard,
            meta: LoadMeta {
                load_us: 0,
                file_bytes: 0,
                quantized,
                zero_copy: !quantized,
            },
        })
    }

    /// v1 read path (`HGNB0001`/`HGNS0001`): the original sequential
    /// parse loop — every section heap-copied. Kept verbatim for
    /// back-compat; new exports never produce it.
    fn decode_v1(buf: &[u8], path: &Path) -> Result<Self> {
        let (which, p) = ser::read_envelope(
            buf,
            &[MAGIC_V1, SHARD_MAGIC_V1],
            "serving bundle or shard",
            path,
        )?;
        let sharded = which == 1;

        let mut pos = 0usize;
        let take = |p: &[u8], pos: &mut usize, n: usize| -> Result<()> {
            if *pos + n > p.len() {
                return Err(Error::Config("truncated serving bundle".into()));
            }
            Ok(())
        };
        let read_u64 = |p: &[u8], pos: &mut usize| -> Result<u64> {
            take(p, pos, 8)?;
            let v = u64::from_le_bytes(p[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };

        let shard = if sharded {
            let lo = read_u64(p, &mut pos)?;
            let hi = read_u64(p, &mut pos)?;
            let index = read_u64(p, &mut pos)? as usize;
            let count = read_u64(p, &mut pos)? as usize;
            let n_present = read_u64(p, &mut pos)? as usize;
            take(p, &mut pos, n_present * 4)?;
            let present: Vec<u32> = p[pos..pos + n_present * 4]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n_present * 4;
            let (lo, hi) = (
                u32::try_from(lo)
                    .map_err(|_| Error::Config("shard lo exceeds u32 range".into()))?,
                u32::try_from(hi)
                    .map_err(|_| Error::Config("shard hi exceeds u32 range".into()))?,
            );
            Some(ShardInfo { lo, hi, index, count, present })
        } else {
            None
        };

        let mlen = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, mlen)?;
        let mtext = std::str::from_utf8(&p[pos..pos + mlen])
            .map_err(|_| Error::Config("bundle manifest is not UTF-8".into()))?;
        pos += mlen;
        let manifest = Manifest::from_json(&ser::parse(mtext)?)?;

        let n_params = read_u64(p, &mut pos)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            let rank = read_u64(p, &mut pos)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(p, &mut pos)? as usize);
            }
            let count: usize = shape.iter().product();
            take(p, &mut pos, count * 4)?;
            let data: Vec<f32> = p[pos..pos + count * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += count * 4;
            params.push(Tensor::F32 { shape, data });
        }

        take(p, &mut pos, 1)?;
        let has_codes = p[pos] == 1;
        pos += 1;
        let codes = if has_codes {
            let c = read_u64(p, &mut pos)? as usize;
            let m = read_u64(p, &mut pos)? as usize;
            let n = read_u64(p, &mut pos)? as usize;
            let n_bits = read_u64(p, &mut pos)? as usize;
            let wpr = n_bits.div_ceil(64);
            take(p, &mut pos, n * wpr * 8)?;
            let words: Vec<u64> = p[pos..pos + n * wpr * 8]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n * wpr * 8;
            let bits = BitMatrix::from_words(n, n_bits, words)?;
            Some(CodeTable::new(bits, CodingCfg::new(c, m)?)?)
        } else {
            None
        };

        let n_edges = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, n_edges * 8)?;
        let mut edges = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            let u = u32::from_le_bytes(p[pos..pos + 4].try_into().unwrap());
            let v = u32::from_le_bytes(p[pos + 4..pos + 8].try_into().unwrap());
            pos += 8;
            edges.push((u, v));
        }
        let n_nodes = read_u64(p, &mut pos)? as usize;

        Ok(Self {
            manifest,
            params: BundleParams::Owned(params),
            codes,
            edges: EdgeList::Owned(edges),
            pos_map: None,
            n_nodes,
            shard,
            meta: LoadMeta::default(),
        })
    }

    /// Split a whole-graph bundle into `k` contiguous node-range shards
    /// (shard `i` owns `[i·n/k, (i+1)·n/k)`), slicing edges and codes per
    /// the family rules in the module docs. Every shard serves its owned
    /// ids **bit-identically** to this bundle; a
    /// [`ShardRouter`](crate::serve::ShardRouter) reassembles the full id
    /// space.
    pub fn split_shards(&self, k: usize) -> Result<Vec<ServingBundle>> {
        if self.shard.is_some() {
            return Err(Error::Config("bundle is already a shard — split the original".into()));
        }
        if k < 1 || k > self.n_nodes {
            return Err(Error::Config(format!(
                "cannot split {} nodes into {k} shards (need 1 ≤ k ≤ n)",
                self.n_nodes
            )));
        }
        let task = self.manifest.hyper_str("task")?.to_string();
        let fullbatch = task.ends_with("_fullbatch");
        let minibatch = task.starts_with("sage_minibatch");
        // Neighbor closure for the minibatch family (global neighbor lists
        // come from the same symmetrized CSR the serving session rebuilds).
        let graph = if minibatch {
            Some(Graph::from_edge_iter(self.n_nodes, self.edges.iter())?)
        } else {
            None
        };
        let n = self.n_nodes;
        let mut shards = Vec::with_capacity(k);
        for i in 0..k {
            let lo = (i * n / k) as u32;
            let hi = ((i + 1) * n / k) as u32;
            let (edges, present) = if fullbatch {
                // Whole graph replicated; ownership is routing-only. A
                // view-backed edge list clones by Arc — shards share it.
                (self.edges.clone(), Vec::new())
            } else if let Some(g) = &graph {
                // Edge slice: everything incident to owned ∪ N(owned), so
                // the full neighbor list of every node sampling draws FROM
                // is reproduced exactly. Code closure adds N(N(owned)) —
                // every node sampling can draw TO.
                let mut edge_nodes = vec![false; n];
                for u in lo..hi {
                    edge_nodes[u as usize] = true;
                    for &v in g.neighbors(u as usize) {
                        edge_nodes[v as usize] = true;
                    }
                }
                let mut closure = edge_nodes.clone();
                for v in 0..n {
                    if edge_nodes[v] {
                        for &w in g.neighbors(v) {
                            closure[w as usize] = true;
                        }
                    }
                }
                let edges: Vec<(u32, u32)> = self
                    .edges
                    .iter()
                    .filter(|&(u, v)| edge_nodes[u as usize] || edge_nodes[v as usize])
                    .collect();
                let present: Vec<u32> =
                    (0..n as u32).filter(|&v| closure[v as usize]).collect();
                (EdgeList::Owned(edges), present)
            } else {
                // Plain decoder: no graph; a node needs only its own code.
                (EdgeList::Owned(Vec::new()), (lo..hi).collect())
            };
            let codes = match &self.codes {
                None => None,
                Some(table) if present.is_empty() => Some(table.clone()),
                Some(table) => Some(compact_codes(table, &present)?),
            };
            let shard = ServingBundle {
                manifest: self.manifest.clone(),
                params: self.params.clone(),
                codes,
                edges,
                // Position buckets are a per-node lookup like parameters:
                // replicated so every shard embeds its owned ids
                // bit-identically to the unsharded session.
                pos_map: self.pos_map.clone(),
                n_nodes: n,
                shard: Some(ShardInfo {
                    lo,
                    hi,
                    index: i,
                    count: k,
                    // NC models carry no codes to compact; an empty list
                    // keeps "present" meaning "compacted code rows" only.
                    present: if self.codes.is_some() { present } else { Vec::new() },
                }),
                meta: LoadMeta::default(),
            };
            shard.validate()?;
            shards.push(shard);
        }
        Ok(shards)
    }
}

/// Asymmetric per-row int8 quantization of a row-major `(rows, cols)`
/// f32 matrix: `q = round((x − min)/scale)` with `scale = (max − min)/255`
/// (a constant row stores `scale = 0` and quantizes exactly). Returns the
/// u8 data and the per-row `[scale, min]` pairs, flattened.
pub fn quantize_rows(data: &[f32], cols: usize) -> (Vec<u8>, Vec<f32>) {
    debug_assert!(cols > 0 && data.len() % cols == 0);
    let rows = data.len() / cols;
    let mut q = Vec::with_capacity(data.len());
    let mut meta = Vec::with_capacity(rows * 2);
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &x in row {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let scale = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
        for &x in row {
            let v = if scale > 0.0 { ((x - lo) / scale).round() } else { 0.0 };
            q.push(v.clamp(0.0, 255.0) as u8);
        }
        meta.push(scale);
        meta.push(lo);
    }
    (q, meta)
}

/// Inverse of [`quantize_rows`]: `x̂ = min + q·scale` per row.
/// `meta` is the flattened `[scale, min]` pair list.
pub fn dequantize_rows(q: &[u8], meta: &[f32], cols: usize) -> Vec<f32> {
    debug_assert!(cols > 0 && q.len() % cols == 0);
    debug_assert_eq!(meta.len(), (q.len() / cols) * 2);
    let mut out = Vec::with_capacity(q.len());
    for (r, row) in q.chunks_exact(cols).enumerate() {
        let (scale, lo) = (meta[r * 2], meta[r * 2 + 1]);
        for &v in row {
            out.push(lo + v as f32 * scale);
        }
    }
    out
}

/// Row-compact a code table to `present` (ascending global ids): shard
/// row `r` gets the packed words of global row `present[r]`, verbatim.
fn compact_codes(table: &CodeTable, present: &[u32]) -> Result<CodeTable> {
    let bits = &table.bits;
    let wpr = bits.words_per_row();
    let mut words = Vec::with_capacity(present.len() * wpr);
    for &id in present {
        words.extend_from_slice(bits.row_words(id as usize));
    }
    let compact = BitMatrix::from_words(present.len(), bits.n_bits(), words)?;
    CodeTable::new(compact, table.coding)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::random_codes;
    use crate::runtime::native::spec;

    fn tiny_bundle() -> ServingBundle {
        let m = spec::ReconBuild {
            name: "b_recon".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 4,
            optim: crate::cfg::OptimCfg::adamw_default(),
        }
        .manifest();
        let store = ParamStore::init(&m, 9);
        let codes = random_codes(12, CodingCfg::new(4, 3).unwrap(), 5);
        ServingBundle::new(m, &store, Some(codes), vec![(0, 1), (3, 11)], 12).unwrap()
    }

    #[test]
    fn save_load_roundtrip_is_exact_and_zero_copy() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle.bin");
        b.save(&path).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.manifest.name, "b_recon");
        assert_eq!(back.manifest.to_json(), b.manifest.to_json());
        assert_eq!(back.params, b.params);
        assert_eq!(back.codes.as_ref().unwrap().bits, b.codes.as_ref().unwrap().bits);
        assert_eq!(back.codes.as_ref().unwrap().coding, b.codes.as_ref().unwrap().coding);
        assert_eq!(back.edges, b.edges);
        assert_eq!(back.n_nodes, 12);
        assert_eq!(back.param_bytes(), b.param_bytes());
        assert!(back.code_bytes() > 0);
        // v2 acceptance: codes/edges/params are slices into the file
        // image, not copies.
        assert!(back.meta.zero_copy);
        assert!(!back.meta.quantized);
        assert!(back.params.borrowed(), "params must be an in-place view");
        assert!(back.edges.borrowed(), "edges must be an in-place view");
        assert!(back.codes.as_ref().unwrap().bits.words_borrowed(), "codes must be views");
        assert_eq!(back.meta.file_bytes, std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn legacy_v1_keeps_loading() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle_v1.bin");
        b.save_legacy_v1(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"HGNB0001");
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.params, b.params);
        assert_eq!(back.edges, b.edges);
        assert_eq!(back.codes.as_ref().unwrap().bits, b.codes.as_ref().unwrap().bits);
        assert!(!back.meta.zero_copy, "v1 loads copy every section");
        assert!(!back.params.borrowed());
        // Shard files too.
        let shard_path = dir.join("shard_v1.bin");
        let shards = b.split_shards(2).unwrap();
        shards[1].save_legacy_v1(&shard_path).unwrap();
        let back = ServingBundle::load(&shard_path).unwrap();
        assert_eq!(back.shard, shards[1].shard);
    }

    #[test]
    fn int8_roundtrip_stays_within_scale_bound() {
        use crate::rng::{Rng, Xoshiro256pp};
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let (rows, cols) = (13, 29);
        let data: Vec<f32> =
            (0..rows * cols).map(|_| (rng.f32() - 0.5) * 4.0).collect();
        let (q, meta) = quantize_rows(&data, cols);
        let back = dequantize_rows(&q, &meta, cols);
        assert_eq!(back.len(), data.len());
        for r in 0..rows {
            let scale = meta[r * 2];
            for c in 0..cols {
                let err = (data[r * cols + c] - back[r * cols + c]).abs();
                assert!(err <= scale / 2.0 + 1e-6, "row {r} col {c}: err {err} > {}", scale / 2.0);
            }
        }
        // Constant rows quantize exactly.
        let (q, meta) = quantize_rows(&[3.25; 8], 4);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(dequantize_rows(&q, &meta, 4), vec![3.25; 8]);
    }

    #[test]
    fn quantized_save_load_dequantizes_once_and_bounds_param_error() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle_q.bin");
        b.save_with(&path, Quant::Int8).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert!(back.meta.quantized);
        assert!(!back.meta.zero_copy, "quantized params live in an owned buffer");
        // Codes and edges still load as views even when params dequantize.
        assert!(back.edges.borrowed());
        assert!(back.codes.as_ref().unwrap().bits.words_borrowed());
        // Rank-1 params are carried f32-exact; rank-2 within the per-row
        // scale bound.
        let orig = b.params.slices().unwrap();
        let deq = back.params.slices().unwrap();
        for (i, (o, d)) in orig.iter().zip(&deq).enumerate() {
            let shape = b.params.shape(i);
            if shape.len() != 2 {
                assert_eq!(*o, *d, "param {i} (rank {}) must be exact", shape.len());
                continue;
            }
            let cols = shape[1];
            for (r, (orow, drow)) in o.chunks(cols).zip(d.chunks(cols)).enumerate() {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for &x in orow {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                let bound = (hi - lo) / 255.0 / 2.0 + 1e-6;
                for (x, y) in orow.iter().zip(drow) {
                    assert!((x - y).abs() <= bound, "param {i} row {r}");
                }
            }
        }
        // A quantized file re-saved as f32 roundtrips its own params
        // exactly (serving is deterministic w.r.t. the quantized model).
        let path2 = dir.join("bundle_q2.bin");
        back.save(&path2).unwrap();
        let again = ServingBundle::load(&path2).unwrap();
        assert_eq!(again.params, back.params);
    }

    #[test]
    fn pos_map_roundtrips_validates_and_replicates_to_shards() {
        let b = tiny_bundle();
        assert!(b.clone().with_pos_map(vec![0; 5]).is_err(), "wrong length must be rejected");
        let b = b.with_pos_map((0..12u32).map(|i| i % 3).collect()).unwrap();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle_posmap.bin");
        b.save(&path).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.pos_map, b.pos_map);
        // The v1 envelope has no POSMAP section and must refuse.
        assert!(b.save_legacy_v1(&dir.join("bundle_posmap_v1.bin")).is_err());
        // Shards replicate the map (per-node lookup, like params).
        for s in b.split_shards(3).unwrap() {
            assert_eq!(s.pos_map, b.pos_map);
        }
    }

    #[test]
    fn load_rejects_corruption_by_section_name() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.bin");
        b.save(&path).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // Flip a byte inside the last section's payload: the error names
        // a section and mentions the checksum.
        let mut bytes = clean.clone();
        let mid = bytes.len() - 4;
        bytes[mid] ^= 0x55;
        std::fs::write(&path, &bytes).unwrap();
        let err = ServingBundle::load(&path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
        // Truncation names the section the cut landed in.
        std::fs::write(&path, &clean[..clean.len() - 8]).unwrap();
        let err = ServingBundle::load(&path).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("truncated"), "{msg}");
        std::fs::write(&path, b"nope").unwrap();
        assert!(ServingBundle::load(&path).is_err());
        // v1 corruption still caught by the envelope checksum.
        let path_v1 = dir.join("corrupt_v1.bin");
        b.save_legacy_v1(&path_v1).unwrap();
        let mut bytes = std::fs::read(&path_v1).unwrap();
        let mid = 24 + (bytes.len() - 24) / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&path_v1, &bytes).unwrap();
        let err = ServingBundle::load(&path_v1).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn recon_split_shards_compacts_codes_and_roundtrips() {
        let b = tiny_bundle();
        let shards = b.split_shards(3).unwrap();
        assert_eq!(shards.len(), 3);
        let mut covered = 0usize;
        for (i, s) in shards.iter().enumerate() {
            let info = s.shard.as_ref().unwrap();
            assert_eq!((info.index, info.count), (i, 3));
            assert_eq!(info.present.len(), (info.hi - info.lo) as usize);
            covered += (info.hi - info.lo) as usize;
            assert!(s.edges.is_empty(), "decoder shards carry no edges");
            // Compacted rows are the original rows, verbatim.
            let codes = s.codes.as_ref().unwrap();
            assert_eq!(codes.n(), info.present.len());
            for (r, &id) in info.present.iter().enumerate() {
                assert_eq!(
                    codes.int_code(r),
                    b.codes.as_ref().unwrap().int_code(id as usize),
                    "shard {i} row {r} (global {id})"
                );
            }
            assert_eq!(s.n_nodes, 12, "ids stay global");
        }
        assert_eq!(covered, 12, "ranges tile the node space");
        // Shard save/load roundtrip through the HGNS0002 section table.
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.bin");
        shards[1].save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"HGNS0002");
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.shard, shards[1].shard);
        assert_eq!(back.codes.as_ref().unwrap().bits, shards[1].codes.as_ref().unwrap().bits);
        // Splitting a shard again is rejected; so are degenerate counts.
        assert!(back.split_shards(2).is_err());
        assert!(b.split_shards(0).is_err());
        assert!(b.split_shards(13).is_err());
    }

    #[test]
    fn shard_validation_catches_bad_headers() {
        let b = tiny_bundle();
        let mut s = b.split_shards(2).unwrap().remove(0);
        // Owned id whose code row is missing.
        let info = s.shard.as_mut().unwrap();
        info.present.remove(0);
        // Codes row count now disagrees with present too — both are errors;
        // rebuild a consistent-but-wrong variant to hit the ownership check.
        let present = info.present.clone();
        s.codes = Some(super::compact_codes(b.codes.as_ref().unwrap(), &present).unwrap());
        assert!(s.validate().is_err(), "owned id without a retained code");
        // Inverted range.
        let mut s2 = b.split_shards(2).unwrap().remove(1);
        let info = s2.shard.as_mut().unwrap();
        std::mem::swap(&mut info.lo, &mut info.hi);
        assert!(s2.validate().is_err());
    }

    #[test]
    fn validation_catches_mismatches() {
        let b = tiny_bundle();
        // Codes with the wrong coding format.
        let bad_codes = random_codes(12, CodingCfg::new(2, 6).unwrap(), 1);
        let store = ParamStore {
            params: b.params.to_tensors().unwrap(),
            ..ParamStore::init(&b.manifest, 1)
        };
        assert!(ServingBundle::new(
            b.manifest.clone(),
            &store,
            Some(bad_codes),
            vec![],
            12
        )
        .is_err());
        // Out-of-range edge.
        assert!(
            ServingBundle::new(b.manifest.clone(), &store, b.codes.clone(), vec![(0, 40)], 12)
                .is_err()
        );
    }
}
