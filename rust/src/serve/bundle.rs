//! The frozen serving artifact: everything inference needs, nothing
//! training needs.
//!
//! A [`ServingBundle`] packs the manifest (the model contract), the
//! trained parameter tensors (no AdamW moments — serving never updates),
//! the bit-packed compositional codes (the paper's compressed node
//! representation, §3.1), and the message-passing edge list (for GNN
//! propagation / fan-out sampling). One file, self-contained: a serving
//! process needs no artifacts directory, no graph generator, and no
//! training code.
//!
//! On-disk format `HGNB0001` (all little-endian): 8-byte magic, payload
//! byte count (u64), FNV-1a checksum of the payload (u64), then the
//! payload — manifest JSON (length-prefixed), parameter tensors
//! (rank + dims + f32 data each), optional codes block (`c, m, n, n_bits`
//! + packed words), edge list, node count. Load verifies size and
//! checksum before decoding anything, same policy as the checkpoint and
//! code-file headers.

use std::path::Path;

use crate::cfg::CodingCfg;
use crate::codes::{BitMatrix, CodeTable};
use crate::params::ParamStore;
use crate::runtime::{Manifest, Tensor};
use crate::ser;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"HGNB0001";

/// A frozen, self-contained serving artifact.
#[derive(Clone)]
pub struct ServingBundle {
    pub manifest: Manifest,
    /// Trained parameters in manifest order (shapes validated at
    /// construction and load).
    pub params: Vec<Tensor>,
    /// Bit-packed compositional codes for the coded front-ends; `None`
    /// for the NC baseline.
    pub codes: Option<CodeTable>,
    /// Undirected message-passing edges (empty for the plain decoder,
    /// whose inference needs no graph).
    pub edges: Vec<(u32, u32)>,
    pub n_nodes: usize,
}

impl ServingBundle {
    /// Assemble from a trained [`ParamStore`] (moments are dropped) plus
    /// the serving-side data. Validates the parameters against the
    /// manifest, the codes format against the hyper-parameters, and every
    /// edge endpoint against `n_nodes`.
    pub fn new(
        manifest: Manifest,
        store: &ParamStore,
        codes: Option<CodeTable>,
        edges: Vec<(u32, u32)>,
        n_nodes: usize,
    ) -> Result<Self> {
        let bundle = Self { manifest, params: store.params.clone(), codes, edges, n_nodes };
        bundle.validate()?;
        Ok(bundle)
    }

    fn validate(&self) -> Result<()> {
        if self.params.len() != self.manifest.params.len() {
            return Err(Error::Shape(format!(
                "bundle has {} param tensors, manifest '{}' declares {}",
                self.params.len(),
                self.manifest.name,
                self.manifest.params.len()
            )));
        }
        for (t, spec) in self.params.iter().zip(&self.manifest.params) {
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::Shape(format!(
                    "bundle param '{}' has shape {:?}, manifest says {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                )));
            }
            t.as_f32()?;
        }
        if let Some(codes) = &self.codes {
            if codes.n() != self.n_nodes {
                return Err(Error::Shape(format!(
                    "bundle codes cover {} entities, bundle declares {} nodes",
                    codes.n(),
                    self.n_nodes
                )));
            }
            // When the manifest records a coding format, it must match.
            if let (Ok(c), Ok(m)) =
                (self.manifest.hyper_usize("c"), self.manifest.hyper_usize("m"))
            {
                if codes.coding.c != c || codes.coding.m != m {
                    return Err(Error::Shape(format!(
                        "bundle codes are (c={}, m={}), manifest '{}' wants (c={c}, m={m})",
                        codes.coding.c, codes.coding.m, self.manifest.name
                    )));
                }
            }
        }
        for &(u, v) in &self.edges {
            if u as usize >= self.n_nodes || v as usize >= self.n_nodes {
                return Err(Error::Shape(format!(
                    "bundle edge ({u}, {v}) out of range for {} nodes",
                    self.n_nodes
                )));
            }
        }
        Ok(())
    }

    /// Serialized parameter footprint in bytes (f32).
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|t| t.len() * 4).sum()
    }

    /// Packed-code footprint in bytes (the Table-2 accounting unit).
    pub fn code_bytes(&self) -> usize {
        self.codes.as_ref().map(|c| c.bits.storage_bytes()).unwrap_or(0)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut p: Vec<u8> = Vec::new();
        let manifest_json = ser::to_string_pretty(&self.manifest.to_json());
        p.extend_from_slice(&(manifest_json.len() as u64).to_le_bytes());
        p.extend_from_slice(manifest_json.as_bytes());
        p.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for t in &self.params {
            let data = t.as_f32()?;
            let shape = t.shape();
            p.extend_from_slice(&(shape.len() as u64).to_le_bytes());
            for &d in shape {
                p.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in data {
                p.extend_from_slice(&x.to_le_bytes());
            }
        }
        match &self.codes {
            None => p.push(0u8),
            Some(codes) => {
                p.push(1u8);
                p.extend_from_slice(&(codes.coding.c as u64).to_le_bytes());
                p.extend_from_slice(&(codes.coding.m as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n() as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n_bits() as u64).to_le_bytes());
                for &w in codes.bits.words() {
                    p.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
        p.extend_from_slice(&(self.edges.len() as u64).to_le_bytes());
        for &(u, v) in &self.edges {
            p.extend_from_slice(&u.to_le_bytes());
            p.extend_from_slice(&v.to_le_bytes());
        }
        p.extend_from_slice(&(self.n_nodes as u64).to_le_bytes());

        let mut buf = Vec::with_capacity(24 + p.len());
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(p.len() as u64).to_le_bytes());
        buf.extend_from_slice(&ser::fnv1a64(&p).to_le_bytes());
        buf.extend_from_slice(&p);
        std::fs::write(path, buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)?;
        if buf.len() < 24 || &buf[..8] != MAGIC {
            return Err(Error::Config(format!(
                "{}: not a serving bundle (bad magic or shorter than the header)",
                path.display()
            )));
        }
        let expect_len = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
        let expect_sum = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let p = &buf[24..];
        if p.len() != expect_len {
            return Err(Error::Config(format!(
                "{}: bundle payload is {} bytes, header says {expect_len} (truncated?)",
                path.display(),
                p.len()
            )));
        }
        if ser::fnv1a64(p) != expect_sum {
            return Err(Error::Config(format!(
                "{}: bundle checksum mismatch — file is corrupt",
                path.display()
            )));
        }

        let mut pos = 0usize;
        let take = |p: &[u8], pos: &mut usize, n: usize| -> Result<()> {
            if *pos + n > p.len() {
                return Err(Error::Config("truncated serving bundle".into()));
            }
            Ok(())
        };
        let read_u64 = |p: &[u8], pos: &mut usize| -> Result<u64> {
            take(p, pos, 8)?;
            let v = u64::from_le_bytes(p[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };

        let mlen = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, mlen)?;
        let mtext = std::str::from_utf8(&p[pos..pos + mlen])
            .map_err(|_| Error::Config("bundle manifest is not UTF-8".into()))?;
        pos += mlen;
        let manifest = Manifest::from_json(&ser::parse(mtext)?)?;

        let n_params = read_u64(p, &mut pos)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            let rank = read_u64(p, &mut pos)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(p, &mut pos)? as usize);
            }
            let count: usize = shape.iter().product();
            take(p, &mut pos, count * 4)?;
            let data: Vec<f32> = p[pos..pos + count * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += count * 4;
            params.push(Tensor::F32 { shape, data });
        }

        take(p, &mut pos, 1)?;
        let has_codes = p[pos] == 1;
        pos += 1;
        let codes = if has_codes {
            let c = read_u64(p, &mut pos)? as usize;
            let m = read_u64(p, &mut pos)? as usize;
            let n = read_u64(p, &mut pos)? as usize;
            let n_bits = read_u64(p, &mut pos)? as usize;
            let wpr = n_bits.div_ceil(64);
            take(p, &mut pos, n * wpr * 8)?;
            let words: Vec<u64> = p[pos..pos + n * wpr * 8]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n * wpr * 8;
            let bits = BitMatrix::from_words(n, n_bits, words)?;
            Some(CodeTable::new(bits, CodingCfg::new(c, m)?)?)
        } else {
            None
        };

        let n_edges = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, n_edges * 8)?;
        let mut edges = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            let u = u32::from_le_bytes(p[pos..pos + 4].try_into().unwrap());
            let v = u32::from_le_bytes(p[pos + 4..pos + 8].try_into().unwrap());
            pos += 8;
            edges.push((u, v));
        }
        let n_nodes = read_u64(p, &mut pos)? as usize;

        let bundle = Self { manifest, params, codes, edges, n_nodes };
        bundle.validate()?;
        Ok(bundle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::random_codes;
    use crate::runtime::native::spec;

    fn tiny_bundle() -> ServingBundle {
        let m = spec::ReconBuild {
            name: "b_recon".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 4,
            optim: crate::cfg::OptimCfg::adamw_default(),
        }
        .manifest();
        let store = ParamStore::init(&m, 9);
        let codes = random_codes(12, CodingCfg::new(4, 3).unwrap(), 5);
        ServingBundle::new(m, &store, Some(codes), vec![(0, 1), (3, 11)], 12).unwrap()
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle.bin");
        b.save(&path).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.manifest.name, "b_recon");
        assert_eq!(back.manifest.to_json(), b.manifest.to_json());
        assert_eq!(back.params, b.params);
        assert_eq!(back.codes.as_ref().unwrap().bits, b.codes.as_ref().unwrap().bits);
        assert_eq!(back.codes.as_ref().unwrap().coding, b.codes.as_ref().unwrap().coding);
        assert_eq!(back.edges, b.edges);
        assert_eq!(back.n_nodes, 12);
        assert_eq!(back.param_bytes(), b.param_bytes());
        assert!(back.code_bytes() > 0);
    }

    #[test]
    fn load_rejects_corruption() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.bin");
        b.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = 24 + (bytes.len() - 24) / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&path, &bytes).unwrap();
        let err = ServingBundle::load(&path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
        std::fs::write(&path, b"nope").unwrap();
        assert!(ServingBundle::load(&path).is_err());
    }

    #[test]
    fn validation_catches_mismatches() {
        let b = tiny_bundle();
        // Codes with the wrong coding format.
        let bad_codes = random_codes(12, CodingCfg::new(2, 6).unwrap(), 1);
        let store = ParamStore { params: b.params.clone(), ..ParamStore::init(&b.manifest, 1) };
        assert!(ServingBundle::new(
            b.manifest.clone(),
            &store,
            Some(bad_codes),
            vec![],
            12
        )
        .is_err());
        // Out-of-range edge.
        assert!(
            ServingBundle::new(b.manifest.clone(), &store, b.codes.clone(), vec![(0, 40)], 12)
                .is_err()
        );
    }
}
