//! The frozen serving artifact: everything inference needs, nothing
//! training needs.
//!
//! A [`ServingBundle`] packs the manifest (the model contract), the
//! trained parameter tensors (no AdamW moments — serving never updates),
//! the bit-packed compositional codes (the paper's compressed node
//! representation, §3.1), and the message-passing edge list (for GNN
//! propagation / fan-out sampling). One file, self-contained: a serving
//! process needs no artifacts directory, no graph generator, and no
//! training code.
//!
//! On-disk format `HGNB0001` (all little-endian): 8-byte magic, payload
//! byte count (u64), FNV-1a checksum of the payload (u64), then the
//! payload — manifest JSON (length-prefixed), parameter tensors
//! (rank + dims + f32 data each), optional codes block (`c, m, n, n_bits`
//! + packed words), edge list, node count. Load verifies size and
//! checksum before decoding anything, same policy as the checkpoint and
//! code-file headers.
//!
//! # Shard files (`HGNS0001`)
//!
//! `hashgnn export --shards K` splits one bundle into K **contiguous
//! node-range shards** so a graph larger than one machine's memory can be
//! served by K processes behind a [`ShardRouter`](crate::serve::ShardRouter).
//! A shard file carries the same 24-byte `magic + payload-size + FNV-1a`
//! envelope (each shard is checksummed independently), then a shard
//! header — owned range `[lo, hi)`, shard index, shard count, and the
//! `present` id list described below — followed by the ordinary bundle
//! payload.
//!
//! What gets sliced per shard depends on the model family, because
//! **served bytes must stay bit-identical to the unsharded session**:
//!
//! - *plain decoder* (`recon`): a node's embedding is a function of its
//!   own code only, so the shard keeps codes for its owned range and no
//!   edges;
//! - *minibatch SAGE*: fan-out sampling draws uniformly from a node's
//!   full (sorted, deduplicated) CSR neighbor list, and the per-node seed
//!   makes a node's two-hop sample a function of `(seed, id)` alone. The
//!   shard therefore keeps every edge incident to `owned ∪ N(owned)` —
//!   which reproduces the exact neighbor lists of all nodes sampling can
//!   draw *from* — plus codes for the two-hop closure
//!   `owned ∪ N(owned) ∪ N(N(owned))`, the set sampling can draw *to*;
//! - *full-batch GNNs*: every node's representation depends on the whole
//!   graph, so shards replicate edges and codes and the split only
//!   records ownership (the router still fans requests out across
//!   shards; the memory win is for the minibatch/decoder families, the
//!   paper's industrial serving case).
//!
//! Sliced codes are **row-compacted**: the shard's `BitMatrix` has one
//! row per retained node and the header's ascending `present` list maps
//! global node ids to rows. An empty `present` list means codes (when
//! present at all) are dense over all `n_nodes`. Node ids stay global
//! everywhere else — edges, requests, and sampling seeds never change
//! meaning across the split, which is what makes bit-parity provable
//! (`tests/serve_persistent.rs` asserts it).

use std::path::Path;

use crate::cfg::CodingCfg;
use crate::codes::{BitMatrix, CodeTable};
use crate::graph::Graph;
use crate::params::ParamStore;
use crate::runtime::{Manifest, Tensor};
use crate::ser;
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"HGNB0001";
const SHARD_MAGIC: &[u8; 8] = b"HGNS0001";

/// Shard header of a node-range bundle slice (`HGNS0001` files): which
/// contiguous global id range this shard **owns** (serves), where it sits
/// in the shard set, and which global ids its row-compacted code table
/// retains.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardInfo {
    /// Owned node range `[lo, hi)` in global ids — the only ids this
    /// shard may be asked to serve.
    pub lo: u32,
    pub hi: u32,
    /// Position of this shard in the set (`0..count`).
    pub index: usize,
    /// Total shards the bundle was split into.
    pub count: usize,
    /// Ascending global ids whose codes this shard retains (row `r` of
    /// the shard's `BitMatrix` is the code of `present[r]`). Empty means
    /// the codes — when the model has any — are dense over all `n_nodes`.
    pub present: Vec<u32>,
}

impl ShardInfo {
    /// True when `id` is in the owned range `[lo, hi)`.
    pub fn owns(&self, id: u32) -> bool {
        self.lo <= id && id < self.hi
    }

    /// Row of `id`'s code in the compacted table (`None` when the shard's
    /// codes are dense, identity-mapped, or `id` was not retained).
    pub fn code_row(&self, id: u32) -> Option<usize> {
        if self.present.is_empty() {
            return None;
        }
        self.present.binary_search(&id).ok()
    }
}

/// A frozen, self-contained serving artifact.
#[derive(Clone)]
pub struct ServingBundle {
    pub manifest: Manifest,
    /// Trained parameters in manifest order (shapes validated at
    /// construction and load).
    pub params: Vec<Tensor>,
    /// Bit-packed compositional codes for the coded front-ends; `None`
    /// for the NC baseline.
    pub codes: Option<CodeTable>,
    /// Undirected message-passing edges (empty for the plain decoder,
    /// whose inference needs no graph).
    pub edges: Vec<(u32, u32)>,
    pub n_nodes: usize,
    /// `Some` when this bundle is one node-range shard of a split export
    /// ([`ServingBundle::split_shards`]); `None` for a whole-graph bundle.
    pub shard: Option<ShardInfo>,
}

impl ServingBundle {
    /// Assemble from a trained [`ParamStore`] (moments are dropped) plus
    /// the serving-side data. Validates the parameters against the
    /// manifest, the codes format against the hyper-parameters, and every
    /// edge endpoint against `n_nodes`.
    pub fn new(
        manifest: Manifest,
        store: &ParamStore,
        codes: Option<CodeTable>,
        edges: Vec<(u32, u32)>,
        n_nodes: usize,
    ) -> Result<Self> {
        let bundle =
            Self { manifest, params: store.params.clone(), codes, edges, n_nodes, shard: None };
        bundle.validate()?;
        Ok(bundle)
    }

    fn validate(&self) -> Result<()> {
        if self.params.len() != self.manifest.params.len() {
            return Err(Error::Shape(format!(
                "bundle has {} param tensors, manifest '{}' declares {}",
                self.params.len(),
                self.manifest.name,
                self.manifest.params.len()
            )));
        }
        for (t, spec) in self.params.iter().zip(&self.manifest.params) {
            if t.shape() != spec.shape.as_slice() {
                return Err(Error::Shape(format!(
                    "bundle param '{}' has shape {:?}, manifest says {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                )));
            }
            t.as_f32()?;
        }
        if let Some(s) = &self.shard {
            if s.lo >= s.hi || s.hi as usize > self.n_nodes {
                return Err(Error::Shape(format!(
                    "shard owns [{}, {}) which is not a non-empty range within {} nodes",
                    s.lo, s.hi, self.n_nodes
                )));
            }
            if s.index >= s.count {
                return Err(Error::Shape(format!(
                    "shard index {} out of range for {} shards",
                    s.index, s.count
                )));
            }
            if !s.present.is_empty() {
                if !s.present.windows(2).all(|w| w[0] < w[1]) {
                    return Err(Error::Shape(
                        "shard present-id list must be strictly ascending".into(),
                    ));
                }
                if s.present.last().map(|&v| v as usize >= self.n_nodes).unwrap_or(false) {
                    return Err(Error::Shape(format!(
                        "shard present id {} out of range for {} nodes",
                        s.present.last().unwrap(),
                        self.n_nodes
                    )));
                }
                // Every owned id must have its code retained.
                for id in s.lo..s.hi {
                    if s.present.binary_search(&id).is_err() {
                        return Err(Error::Shape(format!(
                            "shard owns node {id} but its code row is not retained"
                        )));
                    }
                }
            }
        }
        if let Some(codes) = &self.codes {
            // A shard with a non-empty present list carries a row-compacted
            // code table; everything else is dense over all nodes.
            let expect = match &self.shard {
                Some(s) if !s.present.is_empty() => s.present.len(),
                _ => self.n_nodes,
            };
            if codes.n() != expect {
                return Err(Error::Shape(format!(
                    "bundle codes cover {} entities, expected {expect}",
                    codes.n()
                )));
            }
            // When the manifest records a coding format, it must match.
            if let (Ok(c), Ok(m)) =
                (self.manifest.hyper_usize("c"), self.manifest.hyper_usize("m"))
            {
                if codes.coding.c != c || codes.coding.m != m {
                    return Err(Error::Shape(format!(
                        "bundle codes are (c={}, m={}), manifest '{}' wants (c={c}, m={m})",
                        codes.coding.c, codes.coding.m, self.manifest.name
                    )));
                }
            }
        }
        for &(u, v) in &self.edges {
            if u as usize >= self.n_nodes || v as usize >= self.n_nodes {
                return Err(Error::Shape(format!(
                    "bundle edge ({u}, {v}) out of range for {} nodes",
                    self.n_nodes
                )));
            }
        }
        Ok(())
    }

    /// Serialized parameter footprint in bytes (f32).
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|t| t.len() * 4).sum()
    }

    /// Packed-code footprint in bytes (the Table-2 accounting unit).
    pub fn code_bytes(&self) -> usize {
        self.codes.as_ref().map(|c| c.bits.storage_bytes()).unwrap_or(0)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut p: Vec<u8> = Vec::new();
        let magic = match &self.shard {
            Some(s) => {
                p.extend_from_slice(&(s.lo as u64).to_le_bytes());
                p.extend_from_slice(&(s.hi as u64).to_le_bytes());
                p.extend_from_slice(&(s.index as u64).to_le_bytes());
                p.extend_from_slice(&(s.count as u64).to_le_bytes());
                p.extend_from_slice(&(s.present.len() as u64).to_le_bytes());
                for &id in &s.present {
                    p.extend_from_slice(&id.to_le_bytes());
                }
                SHARD_MAGIC
            }
            None => MAGIC,
        };
        self.encode_core(&mut p)?;
        std::fs::write(path, ser::write_envelope(magic, &p))?;
        Ok(())
    }

    /// Encode manifest + params + codes + edges + node count (the part of
    /// the payload shared by whole bundles and shards) onto `p`.
    fn encode_core(&self, p: &mut Vec<u8>) -> Result<()> {
        let manifest_json = ser::to_string_pretty(&self.manifest.to_json());
        p.extend_from_slice(&(manifest_json.len() as u64).to_le_bytes());
        p.extend_from_slice(manifest_json.as_bytes());
        p.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for t in &self.params {
            let data = t.as_f32()?;
            let shape = t.shape();
            p.extend_from_slice(&(shape.len() as u64).to_le_bytes());
            for &d in shape {
                p.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in data {
                p.extend_from_slice(&x.to_le_bytes());
            }
        }
        match &self.codes {
            None => p.push(0u8),
            Some(codes) => {
                p.push(1u8);
                p.extend_from_slice(&(codes.coding.c as u64).to_le_bytes());
                p.extend_from_slice(&(codes.coding.m as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n() as u64).to_le_bytes());
                p.extend_from_slice(&(codes.bits.n_bits() as u64).to_le_bytes());
                for &w in codes.bits.words() {
                    p.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
        p.extend_from_slice(&(self.edges.len() as u64).to_le_bytes());
        for &(u, v) in &self.edges {
            p.extend_from_slice(&u.to_le_bytes());
            p.extend_from_slice(&v.to_le_bytes());
        }
        p.extend_from_slice(&(self.n_nodes as u64).to_le_bytes());
        Ok(())
    }

    /// Load either a whole bundle (`HGNB0001`) or one shard (`HGNS0001`);
    /// [`ServingBundle::shard`] distinguishes them after the fact.
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)?;
        let (which, p) =
            ser::read_envelope(&buf, &[MAGIC, SHARD_MAGIC], "serving bundle or shard", path)?;
        let sharded = which == 1;

        let mut pos = 0usize;
        let take = |p: &[u8], pos: &mut usize, n: usize| -> Result<()> {
            if *pos + n > p.len() {
                return Err(Error::Config("truncated serving bundle".into()));
            }
            Ok(())
        };
        let read_u64 = |p: &[u8], pos: &mut usize| -> Result<u64> {
            take(p, pos, 8)?;
            let v = u64::from_le_bytes(p[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };

        let shard = if sharded {
            let lo = read_u64(p, &mut pos)?;
            let hi = read_u64(p, &mut pos)?;
            let index = read_u64(p, &mut pos)? as usize;
            let count = read_u64(p, &mut pos)? as usize;
            let n_present = read_u64(p, &mut pos)? as usize;
            take(p, &mut pos, n_present * 4)?;
            let present: Vec<u32> = p[pos..pos + n_present * 4]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n_present * 4;
            let (lo, hi) = (
                u32::try_from(lo)
                    .map_err(|_| Error::Config("shard lo exceeds u32 range".into()))?,
                u32::try_from(hi)
                    .map_err(|_| Error::Config("shard hi exceeds u32 range".into()))?,
            );
            Some(ShardInfo { lo, hi, index, count, present })
        } else {
            None
        };

        let mlen = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, mlen)?;
        let mtext = std::str::from_utf8(&p[pos..pos + mlen])
            .map_err(|_| Error::Config("bundle manifest is not UTF-8".into()))?;
        pos += mlen;
        let manifest = Manifest::from_json(&ser::parse(mtext)?)?;

        let n_params = read_u64(p, &mut pos)? as usize;
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            let rank = read_u64(p, &mut pos)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(p, &mut pos)? as usize);
            }
            let count: usize = shape.iter().product();
            take(p, &mut pos, count * 4)?;
            let data: Vec<f32> = p[pos..pos + count * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += count * 4;
            params.push(Tensor::F32 { shape, data });
        }

        take(p, &mut pos, 1)?;
        let has_codes = p[pos] == 1;
        pos += 1;
        let codes = if has_codes {
            let c = read_u64(p, &mut pos)? as usize;
            let m = read_u64(p, &mut pos)? as usize;
            let n = read_u64(p, &mut pos)? as usize;
            let n_bits = read_u64(p, &mut pos)? as usize;
            let wpr = n_bits.div_ceil(64);
            take(p, &mut pos, n * wpr * 8)?;
            let words: Vec<u64> = p[pos..pos + n * wpr * 8]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += n * wpr * 8;
            let bits = BitMatrix::from_words(n, n_bits, words)?;
            Some(CodeTable::new(bits, CodingCfg::new(c, m)?)?)
        } else {
            None
        };

        let n_edges = read_u64(p, &mut pos)? as usize;
        take(p, &mut pos, n_edges * 8)?;
        let mut edges = Vec::with_capacity(n_edges);
        for _ in 0..n_edges {
            let u = u32::from_le_bytes(p[pos..pos + 4].try_into().unwrap());
            let v = u32::from_le_bytes(p[pos + 4..pos + 8].try_into().unwrap());
            pos += 8;
            edges.push((u, v));
        }
        let n_nodes = read_u64(p, &mut pos)? as usize;

        let bundle = Self { manifest, params, codes, edges, n_nodes, shard };
        bundle.validate()?;
        Ok(bundle)
    }

    /// Split a whole-graph bundle into `k` contiguous node-range shards
    /// (shard `i` owns `[i·n/k, (i+1)·n/k)`), slicing edges and codes per
    /// the family rules in the module docs. Every shard serves its owned
    /// ids **bit-identically** to this bundle; a
    /// [`ShardRouter`](crate::serve::ShardRouter) reassembles the full id
    /// space.
    pub fn split_shards(&self, k: usize) -> Result<Vec<ServingBundle>> {
        if self.shard.is_some() {
            return Err(Error::Config("bundle is already a shard — split the original".into()));
        }
        if k < 1 || k > self.n_nodes {
            return Err(Error::Config(format!(
                "cannot split {} nodes into {k} shards (need 1 ≤ k ≤ n)",
                self.n_nodes
            )));
        }
        let task = self.manifest.hyper_str("task")?.to_string();
        let fullbatch = task.ends_with("_fullbatch");
        let minibatch = task.starts_with("sage_minibatch");
        // Neighbor closure for the minibatch family (global neighbor lists
        // come from the same symmetrized CSR the serving session rebuilds).
        let graph = if minibatch {
            Some(Graph::from_edges(self.n_nodes, &self.edges)?)
        } else {
            None
        };
        let n = self.n_nodes;
        let mut shards = Vec::with_capacity(k);
        for i in 0..k {
            let lo = (i * n / k) as u32;
            let hi = ((i + 1) * n / k) as u32;
            let (edges, present) = if fullbatch {
                // Whole graph replicated; ownership is routing-only.
                (self.edges.clone(), Vec::new())
            } else if let Some(g) = &graph {
                // Edge slice: everything incident to owned ∪ N(owned), so
                // the full neighbor list of every node sampling draws FROM
                // is reproduced exactly. Code closure adds N(N(owned)) —
                // every node sampling can draw TO.
                let mut edge_nodes = vec![false; n];
                for u in lo..hi {
                    edge_nodes[u as usize] = true;
                    for &v in g.neighbors(u as usize) {
                        edge_nodes[v as usize] = true;
                    }
                }
                let mut closure = edge_nodes.clone();
                for v in 0..n {
                    if edge_nodes[v] {
                        for &w in g.neighbors(v) {
                            closure[w as usize] = true;
                        }
                    }
                }
                let edges: Vec<(u32, u32)> = self
                    .edges
                    .iter()
                    .filter(|&&(u, v)| edge_nodes[u as usize] || edge_nodes[v as usize])
                    .copied()
                    .collect();
                let present: Vec<u32> =
                    (0..n as u32).filter(|&v| closure[v as usize]).collect();
                (edges, present)
            } else {
                // Plain decoder: no graph; a node needs only its own code.
                (Vec::new(), (lo..hi).collect())
            };
            let codes = match &self.codes {
                None => None,
                Some(table) if present.is_empty() => Some(table.clone()),
                Some(table) => Some(compact_codes(table, &present)?),
            };
            let shard = ServingBundle {
                manifest: self.manifest.clone(),
                params: self.params.clone(),
                codes,
                edges,
                n_nodes: n,
                shard: Some(ShardInfo {
                    lo,
                    hi,
                    index: i,
                    count: k,
                    // NC models carry no codes to compact; an empty list
                    // keeps "present" meaning "compacted code rows" only.
                    present: if self.codes.is_some() { present } else { Vec::new() },
                }),
            };
            shard.validate()?;
            shards.push(shard);
        }
        Ok(shards)
    }
}

/// Row-compact a code table to `present` (ascending global ids): shard
/// row `r` gets the packed words of global row `present[r]`, verbatim.
fn compact_codes(table: &CodeTable, present: &[u32]) -> Result<CodeTable> {
    let bits = &table.bits;
    let wpr = bits.words_per_row();
    let mut words = Vec::with_capacity(present.len() * wpr);
    for &id in present {
        words.extend_from_slice(bits.row_words(id as usize));
    }
    let compact = BitMatrix::from_words(present.len(), bits.n_bits(), words)?;
    CodeTable::new(compact, table.coding)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::random_codes;
    use crate::runtime::native::spec;

    fn tiny_bundle() -> ServingBundle {
        let m = spec::ReconBuild {
            name: "b_recon".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 4,
            optim: crate::cfg::OptimCfg::adamw_default(),
        }
        .manifest();
        let store = ParamStore::init(&m, 9);
        let codes = random_codes(12, CodingCfg::new(4, 3).unwrap(), 5);
        ServingBundle::new(m, &store, Some(codes), vec![(0, 1), (3, 11)], 12).unwrap()
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bundle.bin");
        b.save(&path).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.manifest.name, "b_recon");
        assert_eq!(back.manifest.to_json(), b.manifest.to_json());
        assert_eq!(back.params, b.params);
        assert_eq!(back.codes.as_ref().unwrap().bits, b.codes.as_ref().unwrap().bits);
        assert_eq!(back.codes.as_ref().unwrap().coding, b.codes.as_ref().unwrap().coding);
        assert_eq!(back.edges, b.edges);
        assert_eq!(back.n_nodes, 12);
        assert_eq!(back.param_bytes(), b.param_bytes());
        assert!(back.code_bytes() > 0);
    }

    #[test]
    fn load_rejects_corruption() {
        let b = tiny_bundle();
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.bin");
        b.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = 24 + (bytes.len() - 24) / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&path, &bytes).unwrap();
        let err = ServingBundle::load(&path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
        std::fs::write(&path, b"nope").unwrap();
        assert!(ServingBundle::load(&path).is_err());
    }

    #[test]
    fn recon_split_shards_compacts_codes_and_roundtrips() {
        let b = tiny_bundle();
        let shards = b.split_shards(3).unwrap();
        assert_eq!(shards.len(), 3);
        let mut covered = 0usize;
        for (i, s) in shards.iter().enumerate() {
            let info = s.shard.as_ref().unwrap();
            assert_eq!((info.index, info.count), (i, 3));
            assert_eq!(info.present.len(), (info.hi - info.lo) as usize);
            covered += (info.hi - info.lo) as usize;
            assert!(s.edges.is_empty(), "decoder shards carry no edges");
            // Compacted rows are the original rows, verbatim.
            let codes = s.codes.as_ref().unwrap();
            assert_eq!(codes.n(), info.present.len());
            for (r, &id) in info.present.iter().enumerate() {
                assert_eq!(
                    codes.int_code(r),
                    b.codes.as_ref().unwrap().int_code(id as usize),
                    "shard {i} row {r} (global {id})"
                );
            }
            assert_eq!(s.n_nodes, 12, "ids stay global");
        }
        assert_eq!(covered, 12, "ranges tile the node space");
        // Shard save/load roundtrip through the HGNS0001 header.
        let dir = std::env::temp_dir().join("hashgnn_test_bundle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.bin");
        shards[1].save(&path).unwrap();
        let back = ServingBundle::load(&path).unwrap();
        assert_eq!(back.shard, shards[1].shard);
        assert_eq!(back.codes.as_ref().unwrap().bits, shards[1].codes.as_ref().unwrap().bits);
        // Splitting a shard again is rejected; so are degenerate counts.
        assert!(back.split_shards(2).is_err());
        assert!(b.split_shards(0).is_err());
        assert!(b.split_shards(13).is_err());
    }

    #[test]
    fn shard_validation_catches_bad_headers() {
        let b = tiny_bundle();
        let mut s = b.split_shards(2).unwrap().remove(0);
        // Owned id whose code row is missing.
        let info = s.shard.as_mut().unwrap();
        info.present.remove(0);
        // Codes row count now disagrees with present too — both are errors;
        // rebuild a consistent-but-wrong variant to hit the ownership check.
        let present = info.present.clone();
        s.codes = Some(super::compact_codes(b.codes.as_ref().unwrap(), &present).unwrap());
        assert!(s.validate().is_err(), "owned id without a retained code");
        // Inverted range.
        let mut s2 = b.split_shards(2).unwrap().remove(1);
        let info = s2.shard.as_mut().unwrap();
        std::mem::swap(&mut info.lo, &mut info.hi);
        assert!(s2.validate().is_err());
    }

    #[test]
    fn validation_catches_mismatches() {
        let b = tiny_bundle();
        // Codes with the wrong coding format.
        let bad_codes = random_codes(12, CodingCfg::new(2, 6).unwrap(), 1);
        let store = ParamStore { params: b.params.clone(), ..ParamStore::init(&b.manifest, 1) };
        assert!(ServingBundle::new(
            b.manifest.clone(),
            &store,
            Some(bad_codes),
            vec![],
            12
        )
        .is_err());
        // Out-of-range edge.
        assert!(
            ServingBundle::new(b.manifest.clone(), &store, b.codes.clone(), vec![(0, 40)], 12)
                .is_err()
        );
    }
}
