//! Batched inference/serving subsystem — the deployment payoff of the
//! paper (§1, §4): once the codebook decoder + GNN are trained, every
//! node is a compact bit vector, and embeddings / edge scores / class
//! predictions are answered from that compressed representation alone.
//!
//! Pieces:
//!
//! - [`ServingBundle`] ([`bundle`]): the frozen artifact — manifest +
//!   trained parameters + packed codes + message-passing edges — written
//!   by `hashgnn export` (optionally as K node-range **shards**, see
//!   [`ServingBundle::split_shards`]) and loaded by `hashgnn infer` /
//!   `hashgnn serve`;
//! - [`Batcher`] / [`CrossBatcher`] ([`batcher`]): per-call coalescing
//!   into fixed, pool-sized batches, and cross-request accumulation
//!   under a fill bound + latency budget for the persistent server;
//! - [`EmbedCache`] ([`cache`]): bounded, exact-LRU cache of decoded
//!   embeddings keyed by node id with precise hit/miss/eviction counters;
//! - [`ServeSession`]: wires the above around an
//!   [`InferModel`](crate::runtime::native::infer::InferModel) — the
//!   forward-only model surface, so **no backward or optimizer code is
//!   reachable from this module**;
//! - [`ShardRouter`] ([`router`]): serves a sharded export as one id
//!   space — routes each request's node ids to the owning shard's
//!   session and merges responses;
//! - [`server`]: the persistent loop — newline-delimited JSON over
//!   stdin/stdout or TCP, cross-request batching, exact counters.
//!
//! The [`Serving`] trait is the request-side seam: [`ServeSession`]
//! (one bundle) and [`ShardRouter`] (K bundles) both implement it, and
//! every front-end — `serve --oneshot`, the persistent NDJSON/TCP loop,
//! `hashgnn infer` — is written against `&mut dyn Serving`, so a future
//! remote backend is one more implementation, not a new protocol.
//! Response construction lives in [`handle_on`] / [`handle_all_on`] so
//! the wire format cannot drift between front-ends.
//!
//! Every served value is bit-identical to the training-time forward on
//! the same inputs: the inference forwards run the training kernels in
//! the same order, the batchers only regroup row-independent work, the
//! cache only replays previously computed bytes, and minibatch fan-out
//! sampling is seeded **per node id**, so a node's neighborhood — and
//! therefore its embedding — does not depend on which request batch it
//! arrived in, nor on which shard served it. `tests/serve_e2e.rs` and
//! `tests/serve_persistent.rs` assert all of this at thread counts
//! {1, 8}.
//!
//! See `docs/SERVING.md` for the wire protocol and an end-to-end
//! transcript, and `docs/ARCHITECTURE.md` for where this subsystem sits
//! in the repo.

pub mod batcher;
pub mod bundle;
pub mod cache;
pub mod fault;
pub mod remote;
pub mod router;
pub mod server;

pub use batcher::{
    BatchGroup, BatchStats, Batcher, Coalesced, CrossBatcher, FlushTrigger, LatencyWindow,
};
pub use bundle::{BundleParams, EdgeList, LoadMeta, Quant, ServingBundle, ShardInfo};
pub use cache::{CacheStats, EmbedCache};
pub use fault::{FaultAction, FaultPlan, FaultState};
pub use remote::{RemoteCfg, RemoteRouter, RemoteShard};
pub use router::ShardRouter;
pub use server::{LoopStats, ServerCfg};

use std::sync::Arc;

use crate::codes::CodeTable;
use crate::graph::{Graph, NeighborSampler};
use crate::rng::mix64;
use crate::runtime::native::infer::{row_index_into, InferModel};
use crate::runtime::Tensor;
use crate::ser::Json;
use crate::{Error, Result};

/// Session knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Compute threads (0 = all cores; never changes any served bit).
    pub threads: usize,
    /// Embedding-cache capacity in entries (0 disables caching).
    pub cache_capacity: usize,
    /// Seed for the per-node fan-out sampling of minibatch models.
    pub seed: u64,
    /// Dispatch per-shard sub-requests concurrently inside one flush
    /// ([`ShardRouter`] via the worker pool, [`RemoteRouter`] via one
    /// in-flight request per worker socket). Merge order is always by
    /// ascending shard index, so response bytes are identical with the
    /// fan-out on or off (`--no-fanout`); only the latency changes.
    pub fanout: bool,
    /// `mmap` the bundle file(s) instead of heap-reading them (requires
    /// the `mmap` cargo feature; served bytes are identical either way —
    /// only residency changes: mapped pages are shared across worker
    /// processes and reclaimable under pressure).
    pub mmap: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self { threads: 0, cache_capacity: 4096, seed: 7, fanout: true, mmap: false }
    }
}

/// What a router's most recent shard fan-out looked like — drained by the
/// persistent loop after each flush ([`Serving::take_fanout_report`]) to
/// feed the `fanout_width` / `shard_wait_us` stats counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FanoutReport {
    /// Shard sub-requests dispatched concurrently (1 = sequential walk).
    pub width: usize,
    /// Wall time of each dispatched shard's sub-request in microseconds,
    /// ascending shard index.
    pub shard_wait_us: Vec<u64>,
}

/// One parsed serving request (the `hashgnn serve --oneshot` wire form).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Embed these node ids.
    Embed(Vec<u32>),
    /// Score these (u, v) edges.
    Score(Vec<(u32, u32)>),
    /// Predict classes for these node ids.
    Classes(Vec<u32>),
}

fn id_from(v: &Json) -> Result<u32> {
    let u = v.as_usize()?;
    u32::try_from(u).map_err(|_| Error::Json(format!("node id {u} exceeds u32 range")))
}

fn ids_from(v: &Json) -> Result<Vec<u32>> {
    v.as_arr()?.iter().map(id_from).collect()
}

impl Request {
    /// Parse `{"op": "embed"|"score"|"classes", "nodes": [...]}` /
    /// `{"op": "score", "edges": [[u, v], ...]}`.
    pub fn from_json(v: &Json) -> Result<Request> {
        match v.get("op")?.as_str()? {
            "embed" => Ok(Request::Embed(ids_from(v.get("nodes")?)?)),
            "classes" => Ok(Request::Classes(ids_from(v.get("nodes")?)?)),
            "score" => {
                let mut edges = Vec::new();
                for pair in v.get("edges")?.as_arr()? {
                    let p = pair.as_arr()?;
                    if p.len() != 2 {
                        return Err(Error::Json("edge must be a [u, v] pair".into()));
                    }
                    edges.push((id_from(&p[0])?, id_from(&p[1])?));
                }
                Ok(Request::Score(edges))
            }
            other => Err(Error::Json(format!(
                "unknown serve op '{other}' (expected embed | score | classes)"
            ))),
        }
    }
}

impl Request {
    /// Every node id the request references (edge endpoints flattened) —
    /// what the cross-request batcher accumulates and the flush embeds.
    pub fn node_ids(&self) -> Vec<u32> {
        match self {
            Request::Embed(ids) | Request::Classes(ids) => ids.clone(),
            Request::Score(edges) => {
                let mut ids = Vec::with_capacity(edges.len() * 2);
                for &(u, v) in edges {
                    ids.push(u);
                    ids.push(v);
                }
                ids
            }
        }
    }
}

/// Parse a `{"requests": [...]}` envelope.
pub fn parse_requests(v: &Json) -> Result<Vec<Request>> {
    v.get("requests")?.as_arr()?.iter().map(Request::from_json).collect()
}

// ---------------------------------------------------------------------------
// The request-side seam: one trait, many backends, one wire format.
// ---------------------------------------------------------------------------

/// Result of a best-effort embedding call ([`Serving::embed_nodes_partial`]):
/// row-major rows for every requested id (failed ids are **zero-filled**
/// so demux indexing stays uniform) plus the per-id failure reasons. An
/// empty `failed` map means every row is genuine.
#[derive(Debug, Default)]
pub struct PartialRows {
    /// `ids.len() × embed_dim` row-major f32s; rows of failed ids are
    /// zeros and must not be served.
    pub rows: Vec<f32>,
    /// Ids that could not be served, with the reason (e.g.
    /// `"shard_unavailable"` from a dead remote worker).
    pub failed: std::collections::BTreeMap<u32, String>,
}

/// What a serving backend must provide for the shared front-ends
/// (`oneshot`, the persistent NDJSON/TCP loop, `hashgnn infer`).
///
/// Implementors: [`ServeSession`] (one bundle, local [`InferModel`]),
/// [`ShardRouter`] (K in-process node-range shards) and
/// [`RemoteRouter`] (K shard-worker *processes* over TCP). The contract
/// every implementor must keep: `embed_nodes` returns
/// `ids.len() × embed_dim` row-major f32s that are **bit-identical** for
/// any request grouping, cache state, thread count, or sharding of the
/// same bundle — local or remote.
pub trait Serving {
    /// Size of the served id space (requests are validated against it).
    fn n_nodes(&self) -> usize;
    /// Width of the rows [`Serving::embed_nodes`] returns.
    fn embed_dim(&self) -> usize;
    /// Serve embeddings for `ids` (duplicates allowed, any order).
    fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>>;
    /// Classification head over already-served rows `h (rows, embed_dim)`
    /// → `(logits, argmax)`; errors when the model has no head. Row-wise,
    /// so results never depend on how rows were grouped.
    fn classes_from_rows(&self, h: &[f32], rows: usize) -> Result<(Vec<f32>, Vec<usize>)>;
    /// Cache/backend counters as a JSON object (the `"cache"` field of
    /// batch responses).
    fn stats_json(&self) -> Json;

    /// Best-effort embedding: serve every id that can be served and name
    /// the ones that can't, instead of failing the whole union. The
    /// default is all-or-nothing (local backends have no partial failure
    /// mode); [`RemoteRouter`] overrides it so one dead shard worker
    /// degrades only the ids it owns.
    fn embed_nodes_partial(&mut self, ids: &[u32]) -> Result<PartialRows> {
        Ok(PartialRows { rows: self.embed_nodes(ids)?, failed: Default::default() })
    }

    /// Class predictions `(logits, argmax)` for `ids`. The default
    /// embeds locally and applies the row-wise head; [`RemoteRouter`]
    /// overrides it to forward `{"op": "classes"}` to the owning worker
    /// (the head parameters live worker-side). `logits` may be empty for
    /// backends that only transport the argmax — the NDJSON `classes`
    /// response carries only the argmax.
    fn classes_for_ids(&mut self, ids: &[u32]) -> Result<(Vec<f32>, Vec<usize>)> {
        let emb = self.embed_nodes(ids)?;
        self.classes_from_rows(&emb, ids.len())
    }

    /// The contiguous `[lo, hi)` global-id range this backend may be
    /// asked to serve — `[0, n)` for everything except a lone shard
    /// session behind `serve --shard-worker`, whose loop rejects
    /// non-owned ids per line instead of poisoning a flush.
    fn owned_range(&self) -> (u32, u32) {
        (0, self.n_nodes() as u32)
    }

    /// `(lo, hi, index, count)` when this backend serves exactly one
    /// shard of a split export — what a shard worker advertises in its
    /// `stats` handshake so [`RemoteRouter`] can validate the set.
    fn shard_info(&self) -> Option<(u32, u32, usize, usize)> {
        None
    }

    /// Manifest name of the served model ("" when unknown) — handshake
    /// field guarding against routing to a worker serving a different
    /// export.
    fn model_name(&self) -> String {
        String::new()
    }

    /// Drain the fan-out record of the most recent embed call. Routers
    /// report how wide they dispatched and how long each shard took; a
    /// single-session backend has no fan-out and returns `None` (the
    /// default). Draining resets the record so one flush is never
    /// counted twice.
    fn take_fanout_report(&mut self) -> Option<FanoutReport> {
        None
    }

    /// `(load_us, file_bytes, quantized)` of the served bundle(s) — the
    /// cold-start cost, on-disk footprint, and whether int8 params were
    /// dequantized at load. Routers aggregate over their shard set (max
    /// load, summed bytes, any-quantized); backends without a local
    /// bundle ([`RemoteRouter`]) return `None` (the default). Surfaced
    /// by the persistent loop's `stats` op as `bundle_load_us` /
    /// `bundle_bytes` / `quantized`.
    fn bundle_meta(&self) -> Option<(u64, u64, bool)> {
        None
    }
}

/// Score `(u, v)` edges on any backend: embed both endpoints, then a
/// fixed ascending-dimension dot per pair — the exact reduction the
/// training link heads use, so scores are bit-identical to the training
/// forward.
pub fn score_edges_on(backend: &mut dyn Serving, edges: &[(u32, u32)]) -> Result<Vec<f32>> {
    let mut ids = Vec::with_capacity(edges.len() * 2);
    for &(u, v) in edges {
        ids.push(u);
        ids.push(v);
    }
    let emb = backend.embed_nodes(&ids)?;
    let d = backend.embed_dim();
    Ok(dot_pairs(&emb, edges.len(), d))
}

/// Ascending-dimension dots of `2·pairs` consecutive row pairs.
pub(crate) fn dot_pairs(emb: &[f32], pairs: usize, d: usize) -> Vec<f32> {
    let mut scores = vec![0.0f32; pairs];
    for (e, s) in scores.iter_mut().enumerate() {
        let hu = &emb[(2 * e) * d..(2 * e + 1) * d];
        let hv = &emb[(2 * e + 1) * d..(2 * e + 2) * d];
        let mut acc = 0.0f32;
        for (&a, &b) in hu.iter().zip(hv) {
            acc += a * b;
        }
        *s = acc;
    }
    scores
}

/// Class predictions (logits + argmax) for `ids` on any backend.
pub fn predict_classes_on(
    backend: &mut dyn Serving,
    ids: &[u32],
) -> Result<(Vec<f32>, Vec<usize>)> {
    let emb = backend.embed_nodes(ids)?;
    backend.classes_from_rows(&emb, ids.len())
}

/// Row-major argmax of `(rows, k)` logits.
pub(crate) fn argmax_rows(logits: &[f32], k: usize) -> Vec<usize> {
    logits
        .chunks(k)
        .map(|row| {
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// The wire form of [`CacheStats`] — one place, so the session's and the
/// router's `"cache"` objects cannot drift apart field-by-field.
pub(crate) fn cache_stats_json(s: &CacheStats) -> Json {
    Json::obj(vec![
        ("hits", Json::num(s.hits as f64)),
        ("misses", Json::num(s.misses as f64)),
        ("evictions", Json::num(s.evictions as f64)),
        ("len", Json::num(s.len as f64)),
        ("capacity", Json::num(s.capacity as f64)),
    ])
}

// Response builders — the single source of truth for the wire format,
// shared by the oneshot path and the persistent server's flush demux.

pub(crate) fn embed_response(ids: &[u32], emb: &[f32], d: usize) -> Json {
    let rows: Vec<Json> = (0..ids.len())
        .map(|i| Json::arr_num(emb[i * d..(i + 1) * d].iter().map(|&x| x as f64)))
        .collect();
    Json::obj(vec![
        ("op", Json::str("embed")),
        ("nodes", Json::Arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ("dim", Json::num(d as f64)),
        ("embeddings", Json::Arr(rows)),
    ])
}

pub(crate) fn score_response(edges: &[(u32, u32)], scores: &[f32]) -> Json {
    Json::obj(vec![
        ("op", Json::str("score")),
        (
            "edges",
            Json::Arr(edges.iter().map(|&(u, v)| Json::arr_num([u as f64, v as f64])).collect()),
        ),
        ("scores", Json::arr_num(scores.iter().map(|&s| s as f64))),
    ])
}

pub(crate) fn classes_response(ids: &[u32], argmax: &[usize]) -> Json {
    Json::obj(vec![
        ("op", Json::str("classes")),
        ("nodes", Json::Arr(ids.iter().map(|&i| Json::num(i as f64)).collect())),
        ("classes", Json::Arr(argmax.iter().map(|&c| Json::num(c as f64)).collect())),
    ])
}

/// Dispatch one wire request on any backend; the response is a JSON
/// object in the same format for every front-end.
pub fn handle_on(backend: &mut dyn Serving, req: &Request) -> Result<Json> {
    match req {
        Request::Embed(ids) => {
            let emb = backend.embed_nodes(ids)?;
            Ok(embed_response(ids, &emb, backend.embed_dim()))
        }
        Request::Score(edges) => {
            let scores = score_edges_on(backend, edges)?;
            Ok(score_response(edges, &scores))
        }
        Request::Classes(ids) => {
            let (_logits, argmax) = predict_classes_on(backend, ids)?;
            Ok(classes_response(ids, &argmax))
        }
    }
}

/// Run a request batch (the `--oneshot` envelope) and wrap the responses
/// with the backend's counters.
pub fn handle_all_on(backend: &mut dyn Serving, reqs: &[Request]) -> Result<Json> {
    let responses: Vec<Json> =
        reqs.iter().map(|r| handle_on(backend, r)).collect::<Result<_>>()?;
    Ok(Json::obj(vec![
        ("responses", Json::Arr(responses)),
        ("cache", backend.stats_json()),
    ]))
}

/// Load one or more bundle/shard files into the right backend: one
/// whole-graph bundle → [`ServeSession`]; a complete shard set →
/// [`ShardRouter`]. A lone shard file is rejected with the list it
/// belongs to, so a misconfigured server cannot silently serve a
/// fraction of the id space.
pub fn load_backend(paths: &[std::path::PathBuf], opts: ServeOpts) -> Result<Box<dyn Serving>> {
    if paths.is_empty() {
        return Err(Error::Config("no bundle paths given".into()));
    }
    if paths.len() == 1 {
        let bundle = ServingBundle::load_with(&paths[0], opts.mmap)?;
        if let Some(s) = &bundle.shard {
            if s.count > 1 {
                return Err(Error::Config(format!(
                    "{} is shard {} of {} — pass all {} shard files (comma-separated) so the \
                     router can cover the whole node range",
                    paths[0].display(),
                    s.index,
                    s.count,
                    s.count
                )));
            }
        }
        return Ok(Box::new(ServeSession::new(bundle, opts)?));
    }
    Ok(Box::new(ShardRouter::load(paths, opts)?))
}

/// Load the backend for `serve --shard-worker`: exactly like
/// [`load_backend`], except that a **lone shard file is allowed** — the
/// whole point of a worker process is to serve one shard's owned range
/// and let the [`RemoteRouter`] cover the rest of the id space. Multiple
/// paths still build a router (a worker may serve a sub-set as one unit).
pub fn load_worker_backend(
    paths: &[std::path::PathBuf],
    opts: ServeOpts,
) -> Result<Box<dyn Serving>> {
    if paths.len() == 1 {
        let bundle = ServingBundle::load_with(&paths[0], opts.mmap)?;
        return Ok(Box::new(ServeSession::new(bundle, opts)?));
    }
    load_backend(paths, opts)
}

/// A live serving session over one frozen bundle: forward-only model,
/// request batcher, embedding LRU.
pub struct ServeSession {
    bundle: ServingBundle,
    model: InferModel,
    /// Rebuilt message-passing graph (fan-out sampling for the minibatch
    /// encoder; adjacency source for full batch). `None` for the plain
    /// decoder, which needs no graph at all.
    graph: Option<Graph>,
    /// Pre-gathered all-node codes batch for full-batch models.
    fb_batch: Vec<Tensor>,
    /// Memoized full-graph representation matrix `(n, hidden)` for the
    /// full-batch models: the bundle is frozen, so H never changes —
    /// computed once on the first miss, row-copied ever after.
    fb_h: Option<Vec<f32>>,
    batcher: Batcher,
    cache: EmbedCache,
    threads: usize,
    seed: u64,
    d: usize,
    /// Per-session scratch reused across [`ServeSession::embed_nodes`]
    /// calls so the flush hot path stops allocating per request (§perf:
    /// the persistent server calls this once per flush, forever).
    scratch: SessionScratch,
}

/// Reusable buffers for the embed hot path. Taken (`std::mem::take`) at
/// the top of a call and put back cleared-by-`clear()` capacity intact;
/// an error path may drop one, which only costs a warm-up re-allocation.
#[derive(Default)]
struct SessionScratch {
    /// Request slots whose id missed the cache.
    miss_slots: Vec<usize>,
    /// Deduplicated missing ids in first-seen order.
    missing: Vec<u32>,
    /// Dedup set for `missing`.
    missing_set: std::collections::HashSet<u32>,
    /// id → row map over `missing` (the per-flush `row_index`).
    index: std::collections::HashMap<u32, usize>,
    /// Gathered integer codes for one coalesced group.
    codes: Vec<i32>,
}

impl ServeSession {
    pub fn new(bundle: ServingBundle, opts: ServeOpts) -> Result<Self> {
        let model = InferModel::from_manifest(&bundle.manifest)?;
        if model.coded() && bundle.codes.is_none() {
            return Err(Error::Config(format!(
                "bundle for coded model '{}' carries no packed codes",
                bundle.manifest.name
            )));
        }
        if model.is_fullbatch() {
            if let Some(s) = &bundle.shard {
                if !s.present.is_empty() {
                    return Err(Error::Config(format!(
                        "full-batch shard for '{}' carries row-compacted codes — full-batch \
                         propagation needs every node's code (split_shards keeps them dense)",
                        bundle.manifest.name
                    )));
                }
            }
        }
        let graph = if model.is_fullbatch() || model.is_minibatch_sage() {
            // The edge list may be an in-place view of the bundle file;
            // the CSR is built straight off its iterator — no pair Vec.
            Some(Graph::from_edge_iter(bundle.n_nodes, bundle.edges.iter())?)
        } else {
            None
        };
        if model.is_fullbatch() {
            let g = graph.as_ref().expect("full-batch session has a graph");
            let adj = Arc::new(g.adj().normalized(bundle.manifest.hyper_str("adj")?)?);
            model.bind_adjacency(adj)?;
        }
        if model.needs_pos_map() {
            // The poshash front-end serves with the exported degree-rank
            // buckets — never recomputed from the serving edge list, which
            // may be a shard slice with different degrees.
            let pm = bundle.pos_map.clone().ok_or_else(|| {
                Error::Config(format!(
                    "bundle for poshash model '{}' carries no POSMAP section — re-export it",
                    bundle.manifest.name
                ))
            })?;
            model.bind_pos_map(Arc::new(pm))?;
        }
        let fb_batch = if model.is_fullbatch() && model.coded() {
            let codes = bundle.codes.as_ref().expect("checked above");
            let ids: Vec<u32> = (0..bundle.n_nodes as u32).collect();
            let mut buf = Vec::new();
            codes.gather_int_codes(&ids, &mut buf);
            vec![Tensor::i32(vec![bundle.n_nodes, codes.coding.m], buf)?]
        } else {
            Vec::new()
        };
        let d = model.embed_dim();
        let batcher = Batcher::new(model.serve_batch())?;
        Ok(Self {
            model,
            graph,
            fb_batch,
            fb_h: None,
            batcher,
            cache: EmbedCache::new(opts.cache_capacity, d),
            threads: opts.threads,
            seed: opts.seed,
            d,
            bundle,
            scratch: SessionScratch::default(),
        })
    }

    /// Width of the served embeddings.
    pub fn embed_dim(&self) -> usize {
        self.d
    }

    pub fn n_nodes(&self) -> usize {
        self.bundle.n_nodes
    }

    pub fn model(&self) -> &InferModel {
        &self.model
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The node range this session may be asked to serve: the shard's
    /// owned `[lo, hi)` for a shard bundle, `[0, n)` otherwise.
    pub fn owned_range(&self) -> (u32, u32) {
        match &self.bundle.shard {
            Some(s) => (s.lo, s.hi),
            None => (0, self.bundle.n_nodes as u32),
        }
    }

    /// The bundle this session serves (the router validates shard sets
    /// through it).
    pub fn bundle(&self) -> &ServingBundle {
        &self.bundle
    }

    fn check_ids(&self, ids: &[u32]) -> Result<()> {
        let (lo, hi) = self.owned_range();
        for &id in ids {
            if id < lo || id >= hi {
                return Err(Error::Shape(if self.bundle.shard.is_some() {
                    format!("node id {id} outside this shard's owned range [{lo}, {hi})")
                } else {
                    format!("node id {id} out of range [0, {hi})")
                }));
            }
        }
        Ok(())
    }

    /// Gather integer codes for global `ids`, translating through the
    /// shard's row compaction when present.
    fn gather_codes(&self, codes: &CodeTable, ids: &[u32], buf: &mut Vec<i32>) -> Result<()> {
        match self.bundle.shard.as_ref().filter(|s| !s.present.is_empty()) {
            None => {
                codes.gather_int_codes(ids, buf);
                Ok(())
            }
            Some(s) => {
                let mut rows = Vec::with_capacity(ids.len());
                for &id in ids {
                    let r = s.code_row(id).ok_or_else(|| {
                        Error::Shape(format!(
                            "node id {id} has no code row in shard {}/{} — outside the \
                             two-hop closure split_shards retained",
                            s.index, s.count
                        ))
                    })?;
                    rows.push(r as u32);
                }
                codes.gather_int_codes(&rows, buf);
                Ok(())
            }
        }
    }

    /// Serve embeddings for `ids` (row-major, [`Self::embed_dim`] wide).
    /// Cache hits are replayed; misses are deduplicated, coalesced into
    /// pool-sized batches, computed, and inserted. Results are
    /// bit-identical to a cold computation for any cache state, request
    /// grouping, or thread count.
    pub fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        self.check_ids(ids)?;
        let d = self.d;
        let mut out = vec![0.0f32; ids.len() * d];
        // Session scratch, not per-call allocations: the persistent
        // server runs this once per flush, so the miss bookkeeping and
        // the id→row map keep their capacity across the session.
        let mut miss_slots = std::mem::take(&mut self.scratch.miss_slots);
        let mut missing = std::mem::take(&mut self.scratch.missing);
        let mut missing_set = std::mem::take(&mut self.scratch.missing_set);
        miss_slots.clear();
        missing.clear();
        missing_set.clear();
        for (i, &id) in ids.iter().enumerate() {
            if let Some(e) = self.cache.get(id) {
                out[i * d..(i + 1) * d].copy_from_slice(e);
            } else {
                miss_slots.push(i);
                if missing_set.insert(id) {
                    missing.push(id);
                }
            }
        }
        let result = if missing.is_empty() { Ok(()) } else { self.fill_misses(ids, &missing, &miss_slots, &mut out) };
        self.scratch.miss_slots = miss_slots;
        self.scratch.missing = missing;
        self.scratch.missing_set = missing_set;
        result?;
        Ok(out)
    }

    /// Compute the deduplicated cache misses and scatter them into the
    /// response (plus the cache). Split out of [`Self::embed_nodes`] so
    /// the scratch vectors above can be restored on every return path.
    fn fill_misses(
        &mut self,
        ids: &[u32],
        missing: &[u32],
        miss_slots: &[usize],
        out: &mut [f32],
    ) -> Result<()> {
        let d = self.d;
        let fresh = self.compute_unique(missing)?;
        debug_assert_eq!(fresh.len(), missing.len() * d);
        let mut index = std::mem::take(&mut self.scratch.index);
        row_index_into(missing, &mut index);
        for &slot in miss_slots {
            let k = index[&ids[slot]];
            out[slot * d..(slot + 1) * d].copy_from_slice(&fresh[k * d..(k + 1) * d]);
        }
        self.scratch.index = index;
        for (k, &id) in missing.iter().enumerate() {
            self.cache.insert(id, fresh[k * d..(k + 1) * d].to_vec());
        }
        Ok(())
    }

    /// Serve dot-product scores for `(u, v)` edges, through the embedding
    /// cache. The per-edge accumulation runs in ascending dimension order
    /// — the same reduction the training link heads use — so scores are
    /// bit-identical to the training-time forward.
    pub fn score_edges(&mut self, edges: &[(u32, u32)]) -> Result<Vec<f32>> {
        score_edges_on(self, edges)
    }

    /// Serve class predictions (logits + argmax) for `ids`; errors for
    /// models without a classification head.
    pub fn predict_classes(&mut self, ids: &[u32]) -> Result<(Vec<f32>, Vec<usize>)> {
        predict_classes_on(self, ids)
    }

    /// Compute embeddings for a deduplicated id list (cache-free inner
    /// path shared by hits-and-misses assembly above).
    fn compute_unique(&mut self, unique: &[u32]) -> Result<Vec<f32>> {
        if self.model.is_fullbatch() {
            self.compute_fullbatch(unique)
        } else if self.model.is_minibatch_sage() {
            self.compute_sage(unique)
        } else {
            self.compute_decoder(unique)
        }
    }

    fn compute_decoder(&mut self, unique: &[u32]) -> Result<Vec<f32>> {
        let codes = self.bundle.codes.as_ref().expect("coded session has codes");
        let m = codes.coding.m;
        let d = self.d;
        let co = self.batcher.coalesce(unique);
        let mut out = Vec::with_capacity(unique.len() * d);
        // Session code-gather scratch: the buffer moves into the batch
        // tensor (no copy) and is recovered from it after the forward,
        // so the per-group gather allocates nothing in steady state.
        // Params go straight to the kernels as borrowed slices — for a
        // v2 bundle these point into the load-time file image.
        let pslices = self.bundle.params.slices()?;
        let mut buf = std::mem::take(&mut self.scratch.codes);
        for g in &co.groups {
            self.gather_codes(codes, &g.ids, &mut buf)?;
            let t = Tensor::i32(vec![g.ids.len(), m], std::mem::take(&mut buf))?;
            let emb =
                self.model.embed_nodes_with(&pslices, std::slice::from_ref(&t), self.threads)?;
            if let Tensor::I32 { data, .. } = t {
                buf = data;
            }
            out.extend_from_slice(&emb.as_f32()?[..g.real * d]);
        }
        self.scratch.codes = buf;
        Ok(out)
    }

    fn compute_sage(&mut self, unique: &[u32]) -> Result<Vec<f32>> {
        let graph = self.graph.as_ref().expect("sage session has a graph");
        let (k1, k2) = self.model.fanout().expect("sage model has fan-out dims");
        let sampler = NeighborSampler::new(graph, k1, k2);
        let d = self.d;
        let co = self.batcher.coalesce(unique);
        let mut out = Vec::with_capacity(unique.len() * d);
        let pslices = self.bundle.params.slices()?;
        let mut buf = std::mem::take(&mut self.scratch.codes);
        for g in &co.groups {
            // Per-node seeded fan-out: node u's neighborhood (and hence
            // its embedding) never depends on the batch it rides in.
            let mut hop1: Vec<u32> = Vec::with_capacity(g.ids.len() * k1);
            let mut hop2: Vec<u32> = Vec::with_capacity(g.ids.len() * k1 * k2);
            for &id in &g.ids {
                let s = sampler.sample_seeded(&[id], mix64(self.seed ^ (id as u64 + 1)));
                hop1.extend_from_slice(&s.hop1);
                hop2.extend_from_slice(&s.hop2);
            }
            let tensors = self.node_set_tensors(&g.ids, &hop1, &hop2, &mut buf)?;
            let emb = self.model.embed_nodes_with(&pslices, &tensors, self.threads)?;
            out.extend_from_slice(&emb.as_f32()?[..g.real * d]);
        }
        self.scratch.codes = buf;
        Ok(out)
    }

    /// The three node-set tensors one encoder application consumes:
    /// gathered codes for the coded front-end, raw ids for NC. `buf` is
    /// caller-provided gather scratch (reused across groups and calls).
    fn node_set_tensors(
        &self,
        targets: &[u32],
        hop1: &[u32],
        hop2: &[u32],
        buf: &mut Vec<i32>,
    ) -> Result<Vec<Tensor>> {
        match (&self.bundle.codes, self.model.code_m()) {
            (Some(codes), Some(m)) => {
                let gather = |ids: &[u32], buf: &mut Vec<i32>| -> Result<Tensor> {
                    self.gather_codes(codes, ids, buf)?;
                    Tensor::i32(vec![ids.len(), m], buf.clone())
                };
                Ok(vec![
                    gather(targets, buf)?,
                    gather(hop1, buf)?,
                    gather(hop2, buf)?,
                ])
            }
            _ => {
                let ids =
                    |v: &[u32]| Tensor::i32(vec![v.len()], v.iter().map(|&x| x as i32).collect());
                Ok(vec![ids(targets)?, ids(hop1)?, ids(hop2)?])
            }
        }
    }

    fn compute_fullbatch(&mut self, unique: &[u32]) -> Result<Vec<f32>> {
        if self.fb_h.is_none() {
            let emb = self
                .model
                .embed_nodes_with(&self.bundle.params.slices()?, &self.fb_batch, self.threads)?;
            let data = match emb {
                Tensor::F32 { data, .. } => data,
                Tensor::I32 { .. } => {
                    return Err(Error::Runtime("embed_nodes produced a non-f32 tensor".into()))
                }
            };
            self.fb_h = Some(data);
        }
        let vals = self.fb_h.as_deref().expect("filled above");
        let d = self.d;
        let mut out = Vec::with_capacity(unique.len() * d);
        for &id in unique {
            let r = id as usize;
            out.extend_from_slice(&vals[r * d..(r + 1) * d]);
        }
        Ok(out)
    }

    /// Dispatch one wire request; the response is a JSON object (same
    /// format on every backend — see [`handle_on`]).
    pub fn handle(&mut self, req: &Request) -> Result<Json> {
        handle_on(self, req)
    }

    /// Run a request batch and wrap the responses with cache statistics.
    pub fn handle_all(&mut self, reqs: &[Request]) -> Result<Json> {
        handle_all_on(self, reqs)
    }
}

impl Serving for ServeSession {
    fn n_nodes(&self) -> usize {
        self.bundle.n_nodes
    }

    fn embed_dim(&self) -> usize {
        self.d
    }

    fn embed_nodes(&mut self, ids: &[u32]) -> Result<Vec<f32>> {
        ServeSession::embed_nodes(self, ids)
    }

    fn classes_from_rows(&self, h: &[f32], rows: usize) -> Result<(Vec<f32>, Vec<usize>)> {
        let k = self.model.n_classes().ok_or_else(|| {
            Error::Runtime(format!(
                "model '{}' has no classification head",
                self.bundle.manifest.name
            ))
        })?;
        let logits =
            self.model.head_logits_with(&self.bundle.params.slices()?, h, rows, self.threads)?;
        let argmax = argmax_rows(&logits, k);
        Ok((logits, argmax))
    }

    fn stats_json(&self) -> Json {
        cache_stats_json(&self.cache_stats())
    }

    fn owned_range(&self) -> (u32, u32) {
        ServeSession::owned_range(self)
    }

    fn shard_info(&self) -> Option<(u32, u32, usize, usize)> {
        self.bundle.shard.as_ref().map(|s| (s.lo, s.hi, s.index, s.count))
    }

    fn model_name(&self) -> String {
        self.bundle.manifest.name.clone()
    }

    fn bundle_meta(&self) -> Option<(u64, u64, bool)> {
        let m = &self.bundle.meta;
        Some((m.load_us, m.file_bytes, m.quantized))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::CodingCfg;
    use crate::codes::random_codes;
    use crate::params::ParamStore;
    use crate::runtime::native::spec;
    use crate::ser;

    fn recon_session(cache: usize) -> ServeSession {
        let m = spec::ReconBuild {
            name: "s_recon".into(),
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            d_e: 2,
            l: 2,
            light: false,
            batch: 3,
            optim: crate::cfg::OptimCfg::adamw_default(),
        }
        .manifest();
        let store = ParamStore::init(&m, 4);
        let codes = random_codes(10, CodingCfg::new(4, 3).unwrap(), 5);
        let bundle = ServingBundle::new(m, &store, Some(codes), vec![], 10).unwrap();
        let opts = ServeOpts { threads: 1, cache_capacity: cache, seed: 3, ..Default::default() };
        ServeSession::new(bundle, opts).unwrap()
    }

    #[test]
    fn decoder_session_serves_and_caches() {
        let mut cold = recon_session(0);
        let mut warm = recon_session(8);
        let ids = [0u32, 7, 3, 7, 9];
        let a = cold.embed_nodes(&ids).unwrap();
        let b = warm.embed_nodes(&ids).unwrap();
        assert_eq!(a.len(), ids.len() * 2);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        // Second pass: all hits, identical bytes.
        let c = warm.embed_nodes(&ids).unwrap();
        assert!(b.iter().zip(&c).all(|(x, y)| x.to_bits() == y.to_bits()));
        let s = warm.cache_stats();
        // First pass: 5 lookups, all misses (dup id 7 counted per lookup);
        // 4 unique entries inserted. Second pass: 5 hits.
        assert_eq!((s.hits, s.misses, s.len), (5, 5, 4));
        // Scores equal manual dots of the embeddings.
        let scores = warm.score_edges(&[(0, 7)]).unwrap();
        let manual = b[0] * b[2] + b[1] * b[3]; // rows 0 and 1 of first pass
        assert_eq!(scores[0].to_bits(), manual.to_bits());
        // No head on the plain decoder.
        assert!(warm.predict_classes(&[0]).is_err());
        // Out-of-range ids rejected.
        assert!(warm.embed_nodes(&[10]).is_err());
    }

    #[test]
    fn oneshot_request_wire_roundtrip() {
        let mut session = recon_session(8);
        let v = ser::parse(
            r#"{"requests": [
                {"op": "embed", "nodes": [1, 2]},
                {"op": "score", "edges": [[1, 2], [0, 3]]}
            ]}"#,
        )
        .unwrap();
        let reqs = parse_requests(&v).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0], Request::Embed(vec![1, 2]));
        let out = session.handle_all(&reqs).unwrap();
        let responses = out.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses.len(), 2);
        assert_eq!(
            responses[1].get("scores").unwrap().as_arr().unwrap().len(),
            2
        );
        assert!(out.get("cache").unwrap().get("hits").is_ok());
        // Unknown op rejected.
        let bad = ser::parse(r#"{"op": "train", "nodes": []}"#).unwrap();
        assert!(Request::from_json(&bad).is_err());
        // Ids beyond u32 must error, not silently wrap onto a valid node.
        let too_big = ser::parse(r#"{"op": "embed", "nodes": [4294967296]}"#).unwrap();
        assert!(Request::from_json(&too_big).is_err());
        let bad_edge = ser::parse(r#"{"op": "score", "edges": [[0, 4294967296]]}"#).unwrap();
        assert!(Request::from_json(&bad_edge).is_err());
    }
}
