//! Deterministic fault injection for the serving loop.
//!
//! A [`FaultPlan`] describes response-stream damage in terms of *ordinal
//! positions* — "drop the 3rd response", "kill the process after 5" — so
//! degradation tests are exactly reproducible: no randomness, no timing
//! races, the Nth response always breaks the same way. The plan is
//! gated: it only activates when the operator passes `serve --fault
//! <spec>` or sets `HASHGNN_FAULT=<spec>`; production servers with
//! neither run the untouched write path.
//!
//! # Spec grammar
//!
//! A comma-separated list of actions (1-based response counting):
//!
//! | token          | effect on the Nth response line                     |
//! |----------------|-----------------------------------------------------|
//! | `drop:N`       | never written (client sees a missing/late response) |
//! | `delay:N:MS`   | written after an extra `MS` milliseconds            |
//! | `truncate:N`   | first half of the line, **no newline** (torn write) |
//! | `corrupt:N`    | first byte replaced with `#` (unparseable JSON)     |
//! | `kill:K`       | process exits(9) right after the Kth response       |
//!
//! e.g. `HASHGNN_FAULT=corrupt:2,kill:5`. The [`RemoteShard`] client
//! (see [`super::remote`]) must survive every one of these: drops and
//! delays hit its request timeout, truncation and corruption fail the
//! response parse — all of which tear down the pooled connection,
//! retry with backoff, and eventually mark the worker down rather than
//! serving damaged bytes. `tests/serve_fault.rs` drives each row.

use crate::{Error, Result};

/// One scripted fault, positioned by 1-based response ordinal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Swallow the Nth response entirely.
    Drop { nth: u64 },
    /// Sleep `ms` milliseconds before writing the Nth response.
    Delay { nth: u64, ms: u64 },
    /// Write only the first half of the Nth response, without its
    /// trailing newline — a torn write mid-line.
    Truncate { nth: u64 },
    /// Replace the Nth response's first byte with `#` so it cannot parse
    /// as JSON (framing survives, content doesn't).
    Corrupt { nth: u64 },
    /// `exit(9)` immediately after writing the Nth response — the
    /// crashed-worker scenario (`kill -9` without the signal).
    KillAfter { n: u64 },
}

/// A parsed, ordered fault script. Empty plans are inert.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub actions: Vec<FaultAction>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    /// Parse the spec grammar above; loud errors for anything else.
    pub fn parse(spec: &str) -> Result<Self> {
        let bad = |tok: &str, why: &str| {
            Error::Config(format!("fault spec token '{tok}': {why}"))
        };
        let num = |tok: &str, field: &str| -> Result<u64> {
            let n: u64 = field
                .parse()
                .map_err(|_| bad(tok, &format!("'{field}' is not a non-negative integer")))?;
            if n == 0 {
                return Err(bad(tok, "response ordinals are 1-based (got 0)"));
            }
            Ok(n)
        };
        let mut actions = Vec::new();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let mut parts = tok.split(':');
            let kind = parts.next().unwrap_or("");
            let rest: Vec<&str> = parts.collect();
            let action = match (kind, rest.as_slice()) {
                ("drop", [n]) => FaultAction::Drop { nth: num(tok, n)? },
                ("delay", [n, ms]) => FaultAction::Delay {
                    nth: num(tok, n)?,
                    ms: ms.parse().map_err(|_| {
                        bad(tok, &format!("'{ms}' is not a millisecond count"))
                    })?,
                },
                ("truncate", [n]) => FaultAction::Truncate { nth: num(tok, n)? },
                ("corrupt", [n]) => FaultAction::Corrupt { nth: num(tok, n)? },
                ("kill", [k]) => FaultAction::KillAfter { n: num(tok, k)? },
                _ => {
                    return Err(bad(
                        tok,
                        "expected drop:N | delay:N:MS | truncate:N | corrupt:N | kill:K",
                    ))
                }
            };
            actions.push(action);
        }
        Ok(Self { actions })
    }

    /// The env-gated plan: `HASHGNN_FAULT=<spec>` (`None` when unset or
    /// empty — the common case costs one getenv).
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("HASHGNN_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => Ok(Some(Self::parse(&spec)?)),
            _ => Ok(None),
        }
    }
}

/// What the writer should do with one response line.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultDecision {
    /// Extra sleep before writing, in milliseconds.
    pub delay_ms: u64,
    /// Bytes to put on the wire (`None` = drop the response). The
    /// healthy path is the line plus `\n`.
    pub bytes: Option<Vec<u8>>,
    /// `exit(9)` after the write.
    pub kill: bool,
}

/// Plan + response counter: one per serving process, shared by every
/// connection writer (the ordinal counts *process-wide* responses, which
/// is what "kill the worker after K requests" means).
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    sent: u64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan, sent: 0 }
    }

    /// Responses counted so far (1-based after the first `decide`).
    pub fn sent(&self) -> u64 {
        self.sent
    }

    /// Count this response and apply every action scripted for its
    /// ordinal. Later tokens win where they overlap (e.g. `drop:1` after
    /// `corrupt:1` drops).
    pub fn decide(&mut self, line: &str) -> FaultDecision {
        self.sent += 1;
        let n = self.sent;
        let mut d = FaultDecision {
            delay_ms: 0,
            bytes: Some(format!("{line}\n").into_bytes()),
            kill: false,
        };
        for a in &self.plan.actions {
            match *a {
                FaultAction::Drop { nth } if nth == n => d.bytes = None,
                FaultAction::Delay { nth, ms } if nth == n => d.delay_ms = ms,
                FaultAction::Truncate { nth } if nth == n => {
                    d.bytes = Some(line.as_bytes()[..line.len() / 2].to_vec());
                }
                FaultAction::Corrupt { nth } if nth == n => {
                    let mut b = line.as_bytes().to_vec();
                    if !b.is_empty() {
                        b[0] = b'#';
                    }
                    b.push(b'\n');
                    d.bytes = Some(b);
                }
                FaultAction::KillAfter { n: k } if n >= k => d.kill = true,
                _ => {}
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_token_kind_and_rejects_garbage() {
        let p = FaultPlan::parse("drop:1, delay:2:250,truncate:3,corrupt:4,kill:5").unwrap();
        assert_eq!(
            p.actions,
            vec![
                FaultAction::Drop { nth: 1 },
                FaultAction::Delay { nth: 2, ms: 250 },
                FaultAction::Truncate { nth: 3 },
                FaultAction::Corrupt { nth: 4 },
                FaultAction::KillAfter { n: 5 },
            ]
        );
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("explode:1").is_err());
        assert!(FaultPlan::parse("drop:0").is_err(), "ordinals are 1-based");
        assert!(FaultPlan::parse("delay:1").is_err(), "delay needs a millisecond field");
        assert!(FaultPlan::parse("drop:x").is_err());
    }

    #[test]
    fn decide_applies_faults_at_exact_ordinals() {
        let plan = FaultPlan::parse("drop:2,corrupt:3,truncate:4,kill:5").unwrap();
        let mut st = FaultState::new(plan);
        let line = r#"{"embeddings": [[1, 2]]}"#;

        // #1: untouched — line plus newline, no kill.
        let d = st.decide(line);
        assert_eq!(d.bytes.as_deref(), Some(format!("{line}\n").as_bytes()));
        assert!(!d.kill && d.delay_ms == 0);

        // #2: dropped.
        assert_eq!(st.decide(line).bytes, None);

        // #3: corrupted — same length + newline, starts with '#', unparseable.
        let d = st.decide(line);
        let b = d.bytes.unwrap();
        assert_eq!(b.len(), line.len() + 1);
        assert_eq!(b[0], b'#');
        assert!(crate::ser::parse(std::str::from_utf8(&b).unwrap().trim()).is_err());

        // #4: truncated — half the line, and crucially NO newline.
        let d = st.decide(line);
        let b = d.bytes.unwrap();
        assert_eq!(b, &line.as_bytes()[..line.len() / 2]);
        assert!(!b.ends_with(b"\n"));

        // #5: written intact, then kill.
        let d = st.decide(line);
        assert_eq!(d.bytes.as_deref(), Some(format!("{line}\n").as_bytes()));
        assert!(d.kill);
    }

    #[test]
    fn kill_fires_on_every_response_at_or_past_k() {
        let mut st = FaultState::new(FaultPlan::parse("kill:2").unwrap());
        assert!(!st.decide("a").kill);
        assert!(st.decide("b").kill);
        assert!(st.decide("c").kill, "a process that somehow survived still dies next write");
        assert_eq!(st.sent(), 3);
    }
}
