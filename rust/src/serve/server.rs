//! Persistent server loop: newline-delimited JSON over stdin/stdout
//! (`hashgnn serve --stdin`) or TCP (`--listen <addr>`), with
//! cross-request batching under a latency budget.
//!
//! # Protocol (see `docs/SERVING.md` for the full spec)
//!
//! One JSON object per input line — the same request objects the oneshot
//! envelope carries (`{"op": "embed", "nodes": [...]}` etc.), plus two
//! control ops: `{"op": "stats"}` (flush, then report counters) and
//! `{"op": "shutdown"}` (flush, acknowledge, end the session). An
//! optional `"id"` field is echoed verbatim on the matching response
//! line. One JSON object per output line, **in request order**; a
//! request that fails — malformed JSON, unknown op, out-of-range node id,
//! model without the requested head — produces an `{"error": ...}` line
//! in its position and never tears down the session.
//!
//! # Batching semantics
//!
//! Requests do not compute as they arrive. They queue in a
//! [`CrossBatcher`] until **either** `max_batch` distinct node ids are
//! pending **or** `max_delay` has elapsed since the oldest queued request
//! (whichever comes first; EOF and control ops drain immediately). A
//! flush embeds the union of pending node ids in one deduplicated
//! session call — the padded, pool-sized `InferModel` batches — and
//! demuxes rows back per request
//! ([`demux_rows`](crate::runtime::native::infer::demux_rows)). Exact
//! counters ([`LoopStats`]) report flushes by trigger, nodes saved by
//! cross-request coalescing, and distinct nodes computed.
//!
//! Batching never changes served bytes: the union goes through the same
//! grouping-invariant session path as a lone request, and the classifier
//! head is applied row-wise to the flushed rows. The NDJSON responses
//! are therefore identical whether requests arrive one per flush or all
//! in one — and identical between a [`ServeSession`](super::ServeSession)
//! and a [`ShardRouter`](super::ShardRouter) over the same export.
//!
//! # Blocking model
//!
//! A detached reader thread feeds raw lines into a channel; the loop
//! waits with `recv_timeout` against the batcher's deadline, so the
//! latency budget holds whether input is idle, trickling, or flooding.
//! TCP mode accepts connections sequentially (one NDJSON session at a
//! time over a shared backend, so the embedding cache stays warm across
//! connections); concurrent connections belong to a fleet of processes
//! behind the shard router, not to one loop.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use crate::runtime::native::infer::{demux_rows_with, row_index};
use crate::ser::{self, Json};
use crate::Result;

use super::batcher::{BatchStats, CrossBatcher, FlushTrigger};
use super::{classes_response, dot_pairs, embed_response, score_response, Request, Serving};

/// Persistent-loop knobs (`--max-batch`, `--max-delay-ms`).
#[derive(Clone, Copy, Debug)]
pub struct ServerCfg {
    /// Flush when this many distinct node ids are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long.
    pub max_delay: Duration,
}

impl Default for ServerCfg {
    fn default() -> Self {
        Self { max_batch: 256, max_delay: Duration::from_millis(5) }
    }
}

/// Exact per-session counters: request/response accounting on top of the
/// batcher's flush statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoopStats {
    /// Non-empty input lines consumed (requests + control ops).
    pub requests: u64,
    /// Successful response lines written.
    pub responses: u64,
    /// Error lines written.
    pub errors: u64,
    /// Cross-request batching counters.
    pub batch: BatchStats,
}

impl LoopStats {
    /// Accumulate another session's counters (TCP mode sums sessions).
    pub fn absorb(&mut self, o: &LoopStats) {
        self.requests += o.requests;
        self.responses += o.responses;
        self.errors += o.errors;
        self.batch.absorb(&o.batch);
    }

    /// One-line human summary (the CLI prints it to stderr).
    pub fn summary(&self) -> String {
        format!(
            "{} request(s), {} response(s), {} error(s) | {} flush(es): {} fill / {} budget / \
             {} drain | {} node(s) coalesced away, {} unique computed",
            self.requests,
            self.responses,
            self.errors,
            self.batch.flushes,
            self.batch.fill_flushes,
            self.batch.budget_expiries,
            self.batch.drain_flushes,
            self.batch.coalesced_nodes,
            self.batch.unique_nodes
        )
    }
}

/// One queued input line: a validated request or a deferred error that
/// must answer in its arrival position.
enum Pending {
    Req { req: Request, echo: Option<Json> },
    Fail { msg: String, echo: Option<Json> },
}

/// One parsed input line.
enum Line {
    Item(Pending),
    Stats(Option<Json>),
    Shutdown(Option<Json>),
}

fn parse_line(line: &str, n_nodes: usize) -> Line {
    let v = match ser::parse(line) {
        Ok(v) => v,
        Err(e) => return Line::Item(Pending::Fail { msg: format!("{e}"), echo: None }),
    };
    let echo = v.opt("id").cloned();
    match v.opt("op").and_then(|op| op.as_str().ok()) {
        Some("stats") => return Line::Stats(echo),
        Some("shutdown") => return Line::Shutdown(echo),
        _ => {}
    }
    match Request::from_json(&v) {
        Ok(req) => {
            // Validate ids at enqueue time so one bad id fails its own
            // line instead of poisoning a whole flush.
            if let Some(&bad) = req.node_ids().iter().find(|&&id| id as usize >= n_nodes) {
                return Line::Item(Pending::Fail {
                    msg: format!("node id {bad} out of range [0, {n_nodes})"),
                    echo,
                });
            }
            Line::Item(Pending::Req { req, echo })
        }
        Err(e) => Line::Item(Pending::Fail { msg: format!("{e}"), echo }),
    }
}

fn with_echo(v: Json, echo: Option<Json>) -> Json {
    match (v, echo) {
        (Json::Obj(mut o), Some(e)) => {
            o.insert("id".to_string(), e);
            Json::Obj(o)
        }
        (v, _) => v,
    }
}

fn error_json(msg: &str, echo: Option<Json>) -> Json {
    with_echo(Json::obj(vec![("error", Json::str(msg))]), echo)
}

/// Build one response from the flush's precomputed rows. Embeds and
/// scores demux through the flush's shared id→row index; classes push
/// the demuxed rows through the row-wise head.
fn respond(
    backend: &dyn Serving,
    req: &Request,
    index: &HashMap<u32, usize>,
    rows: &[f32],
    d: usize,
) -> Result<Json> {
    match req {
        Request::Embed(ids) => {
            let mut emb = vec![0.0f32; ids.len() * d];
            demux_rows_with(index, rows, d, ids, &mut emb)?;
            Ok(embed_response(ids, &emb, d))
        }
        Request::Score(edges) => {
            let ids = req.node_ids();
            let mut emb = vec![0.0f32; ids.len() * d];
            demux_rows_with(index, rows, d, &ids, &mut emb)?;
            Ok(score_response(edges, &dot_pairs(&emb, edges.len(), d)))
        }
        Request::Classes(ids) => {
            let mut emb = vec![0.0f32; ids.len() * d];
            demux_rows_with(index, rows, d, ids, &mut emb)?;
            let (_logits, argmax) = backend.classes_from_rows(&emb, ids.len())?;
            Ok(classes_response(ids, &argmax))
        }
    }
}

fn flush(
    backend: &mut dyn Serving,
    batcher: &mut CrossBatcher<Pending>,
    trigger: FlushTrigger,
    out: &mut dyn Write,
    stats: &mut LoopStats,
) -> Result<()> {
    if batcher.is_empty() {
        return Ok(());
    }
    let (items, unique) = batcher.take(trigger);
    let computed =
        if unique.is_empty() { Ok(Vec::new()) } else { backend.embed_nodes(&unique) };
    let d = backend.embed_dim();
    match computed {
        Ok(rows) => {
            // One id→row index per flush, shared by every request's demux.
            let index = row_index(&unique);
            for item in items {
                let line = match item {
                    Pending::Fail { msg, echo } => {
                        stats.errors += 1;
                        error_json(&msg, echo)
                    }
                    Pending::Req { req, echo } => match respond(backend, &req, &index, &rows, d)
                    {
                        Ok(resp) => {
                            stats.responses += 1;
                            with_echo(resp, echo)
                        }
                        Err(e) => {
                            stats.errors += 1;
                            error_json(&format!("{e}"), echo)
                        }
                    },
                };
                writeln!(out, "{}", ser::to_string_compact(&line))?;
            }
        }
        Err(e) => {
            // The whole union failed (ids were pre-validated, so this is a
            // model/bundle-level fault): every queued line gets the error.
            let msg = format!("{e}");
            for item in items {
                stats.errors += 1;
                let echo = match item {
                    Pending::Req { echo, .. } | Pending::Fail { echo, .. } => echo,
                };
                writeln!(out, "{}", ser::to_string_compact(&error_json(&msg, echo)))?;
            }
        }
    }
    out.flush()?;
    Ok(())
}

fn stats_response(backend: &dyn Serving, stats: &LoopStats, batch: BatchStats) -> Json {
    Json::obj(vec![
        ("op", Json::str("stats")),
        ("requests", Json::num(stats.requests as f64)),
        ("responses", Json::num(stats.responses as f64)),
        ("errors", Json::num(stats.errors as f64)),
        ("flushes", Json::num(batch.flushes as f64)),
        ("fill_flushes", Json::num(batch.fill_flushes as f64)),
        ("budget_expiries", Json::num(batch.budget_expiries as f64)),
        ("drain_flushes", Json::num(batch.drain_flushes as f64)),
        ("coalesced_nodes", Json::num(batch.coalesced_nodes as f64)),
        ("unique_nodes", Json::num(batch.unique_nodes as f64)),
        ("cache", backend.stats_json()),
    ])
}

/// Lines the reader thread may buffer ahead of the serve loop. Bounded
/// so a client that floods requests (or never drains responses, wedging
/// the loop on socket backpressure) blocks its own reader instead of
/// growing server memory without limit.
const READER_BACKLOG: usize = 1024;

/// Spawn a detached thread reading raw lines into a bounded channel —
/// the select-able form of a blocking reader the budget wait needs. The
/// channel closes at EOF or on the first read error.
pub fn spawn_line_reader<R: BufRead + Send + 'static>(
    mut r: R,
) -> Receiver<std::io::Result<String>> {
    let (tx, rx) = sync_channel(READER_BACKLOG);
    std::thread::spawn(move || loop {
        let mut line = String::new();
        match r.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                if tx.send(Ok(line)).is_err() {
                    break;
                }
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                break;
            }
        }
    });
    rx
}

/// Drive one NDJSON session to completion (EOF or `shutdown`); the core
/// the stdin, TCP and test front-ends share.
pub fn run_loop(
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    rx: &Receiver<std::io::Result<String>>,
    out: &mut dyn Write,
) -> Result<LoopStats> {
    let mut batcher: CrossBatcher<Pending> = CrossBatcher::new(cfg.max_batch, cfg.max_delay)?;
    let mut stats = LoopStats::default();
    loop {
        let msg = if batcher.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => None, // channel closed: EOF
            }
        } else {
            let deadline = batcher.deadline().expect("non-empty queue has a deadline");
            let wait = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(wait) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => {
                    flush(backend, &mut batcher, FlushTrigger::Budget, out, &mut stats)?;
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => None,
            }
        };
        let line = match msg {
            None => {
                flush(backend, &mut batcher, FlushTrigger::Drain, out, &mut stats)?;
                break;
            }
            Some(Err(e)) => {
                flush(backend, &mut batcher, FlushTrigger::Drain, out, &mut stats)?;
                return Err(e.into());
            }
            Some(Ok(line)) => line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        stats.requests += 1;
        match parse_line(line, backend.n_nodes()) {
            Line::Item(item) => {
                let ids = match &item {
                    Pending::Req { req, .. } => req.node_ids(),
                    Pending::Fail { .. } => Vec::new(),
                };
                let full = batcher.push(item, &ids, Instant::now());
                if full {
                    flush(backend, &mut batcher, FlushTrigger::Fill, out, &mut stats)?;
                } else if batcher.should_flush(Instant::now()) {
                    // Continuous traffic must still honor the budget even
                    // though recv_timeout never got to expire.
                    flush(backend, &mut batcher, FlushTrigger::Budget, out, &mut stats)?;
                }
            }
            Line::Stats(echo) => {
                flush(backend, &mut batcher, FlushTrigger::Drain, out, &mut stats)?;
                stats.responses += 1;
                let resp =
                    with_echo(stats_response(backend, &stats, batcher.stats()), echo);
                writeln!(out, "{}", ser::to_string_compact(&resp))?;
                out.flush()?;
            }
            Line::Shutdown(echo) => {
                flush(backend, &mut batcher, FlushTrigger::Drain, out, &mut stats)?;
                stats.responses += 1;
                let resp = with_echo(
                    Json::obj(vec![("op", Json::str("shutdown")), ("ok", Json::Bool(true))]),
                    echo,
                );
                writeln!(out, "{}", ser::to_string_compact(&resp))?;
                out.flush()?;
                break;
            }
        }
    }
    stats.batch = batcher.stats();
    Ok(stats)
}

/// Run one NDJSON session over an arbitrary reader/writer pair (the
/// piped-session entry point the e2e tests drive).
pub fn run_ndjson<R: BufRead + Send + 'static>(
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    input: R,
    out: &mut dyn Write,
) -> Result<LoopStats> {
    let rx = spawn_line_reader(input);
    run_loop(backend, cfg, &rx, out)
}

/// `hashgnn serve --stdin`: one NDJSON session over stdin/stdout.
pub fn serve_stdin(backend: &mut dyn Serving, cfg: &ServerCfg) -> Result<LoopStats> {
    let rx = spawn_line_reader(std::io::BufReader::new(std::io::stdin()));
    let mut out = std::io::BufWriter::new(std::io::stdout());
    run_loop(backend, cfg, &rx, &mut out)
}

/// `hashgnn serve --listen`: accept NDJSON sessions sequentially over a
/// bound listener, sharing one backend (and so one warm cache) across
/// connections. `max_conns = 0` accepts forever; a positive bound makes
/// the call return aggregate stats after that many connections (the CI
/// smoke and tests use 1).
pub fn serve_listener(
    listener: std::net::TcpListener,
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    max_conns: usize,
) -> Result<LoopStats> {
    let mut total = LoopStats::default();
    let mut served = 0usize;
    while max_conns == 0 || served < max_conns {
        let (stream, peer) = listener.accept()?;
        eprintln!("[serve] connection from {peer}");
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let closer = stream.try_clone()?;
        let rx = spawn_line_reader(reader);
        let mut out = std::io::BufWriter::new(stream);
        match run_loop(backend, cfg, &rx, &mut out) {
            Ok(s) => {
                eprintln!("[serve] connection closed: {}", s.summary());
                total.absorb(&s);
            }
            Err(e) => eprintln!("[serve] connection error: {e}"),
        }
        // The reader thread still holds a clone of the socket blocked in
        // read_line; shut the connection down so the client sees EOF and
        // the thread unblocks instead of leaking per connection.
        let _ = closer.shutdown(std::net::Shutdown::Both);
        served += 1;
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_classifies_requests_controls_and_errors() {
        match parse_line(r#"{"op": "embed", "nodes": [1, 2], "id": 7}"#, 10) {
            Line::Item(Pending::Req { req, echo }) => {
                assert_eq!(req, Request::Embed(vec![1, 2]));
                assert_eq!(echo, Some(Json::num(7.0)));
            }
            _ => panic!("expected a request"),
        }
        assert!(matches!(parse_line(r#"{"op": "stats"}"#, 10), Line::Stats(None)));
        assert!(matches!(parse_line(r#"{"op": "shutdown"}"#, 10), Line::Shutdown(None)));
        // Out-of-range id fails its own line at parse time.
        match parse_line(r#"{"op": "embed", "nodes": [99]}"#, 10) {
            Line::Item(Pending::Fail { msg, .. }) => assert!(msg.contains("out of range")),
            _ => panic!("expected a deferred failure"),
        }
        // Malformed JSON and unknown ops likewise.
        assert!(matches!(
            parse_line("not json", 10),
            Line::Item(Pending::Fail { .. })
        ));
        assert!(matches!(
            parse_line(r#"{"op": "train"}"#, 10),
            Line::Item(Pending::Fail { .. })
        ));
    }

    #[test]
    fn echo_attaches_to_objects_only() {
        let v = with_echo(Json::obj(vec![("a", Json::num(1.0))]), Some(Json::str("x")));
        assert_eq!(v.get("id").unwrap(), &Json::str("x"));
        let e = error_json("boom", None);
        assert!(e.get("error").is_ok() && e.opt("id").is_none());
    }
}
