//! Persistent server loop: newline-delimited JSON over stdin/stdout
//! (`hashgnn serve --stdin`) or TCP (`--listen <addr>`), with
//! cross-request batching under a latency budget, bounded admission,
//! per-request deadlines, and load-shed responses.
//!
//! # Protocol (see `docs/SERVING.md` for the full spec)
//!
//! One JSON object per input line — the same request objects the oneshot
//! envelope carries (`{"op": "embed", "nodes": [...]}` etc.), plus two
//! control ops: `{"op": "stats"}` (flush, then report counters) and
//! `{"op": "shutdown"}` (flush, acknowledge, end the session — in
//! concurrent TCP mode, the whole server). An optional `"id"` field is
//! echoed verbatim on the matching response line. One JSON object per
//! output line, **in request order**; a request that fails — malformed
//! JSON, unknown op, out-of-range node id, model without the requested
//! head — produces an `{"error": ...}` line in its position and never
//! tears down the session. Load shedding speaks the same form:
//! `{"error": "overloaded"}` when the bounded queue is full,
//! `{"error": "deadline"}` when a request waited past `--deadline-ms`,
//! `{"error": "line_too_long"}` for a line beyond `--max-line-bytes`,
//! and `{"error": "shard_unavailable"}` for ids owned by a dead remote
//! shard worker — always in the request's position.
//!
//! # Batching semantics
//!
//! Requests do not compute as they arrive. They queue in a
//! [`CrossBatcher`] until **either** `max_batch` distinct node ids are
//! pending **or** `max_delay` has elapsed since the oldest queued request
//! (whichever comes first; EOF and control ops drain immediately). A
//! flush embeds the union of pending node ids in one deduplicated
//! session call — the padded, pool-sized `InferModel` batches — and
//! demuxes rows back per request
//! ([`demux_rows`](crate::runtime::native::infer::demux_rows)). Exact
//! counters ([`LoopStats`]) report flushes by trigger, nodes saved by
//! cross-request coalescing, distinct nodes computed, shed counts, and
//! requests drained at shutdown; a [`LatencyWindow`] tracks exact
//! p50/p99 flush latency for the `stats` response.
//!
//! Batching never changes served bytes: the union goes through the same
//! grouping-invariant session path as a lone request, and the classifier
//! head is applied row-wise per request. The NDJSON responses are
//! therefore identical whether requests arrive one per flush or all in
//! one — and identical between a [`ServeSession`](super::ServeSession),
//! a [`ShardRouter`](super::ShardRouter) and a
//! [`RemoteRouter`](super::RemoteRouter) over the same export.
//!
//! # Blocking model
//!
//! Single-session fronts (`--stdin`, tests) run [`run_loop`]: a detached
//! reader thread feeds bounded lines into a channel; the loop waits with
//! `recv_timeout` against the batcher's deadline, so the latency budget
//! holds whether input is idle, trickling, or flooding.
//!
//! The TCP front ([`serve_concurrent`]) accepts up to `--max-conns`
//! connections **concurrently**: per connection, a reader thread parses
//! bounded lines and a writer thread reorders responses by arrival slot;
//! every line funnels through one bounded engine queue into the ONE
//! shared [`CrossBatcher`], so deduplication finally coalesces across
//! *connections*, not just across requests. The engine — and therefore
//! the backend — stays on the calling thread: `Serving` needs no `Send`
//! bound, and every flush is a plain `&mut` call. Admission is bounded
//! end to end (engine queue, pending set, per-connection writer buffer);
//! overflow sheds with explicit error lines instead of growing memory.
//! [`serve_listener`] remains the sequential variant (one session at a
//! time over the shared backend).

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::runtime::native::infer::{demux_rows_with, row_index};
use crate::ser::{self, Json};
use crate::Result;

use super::batcher::{BatchStats, CrossBatcher, FlushTrigger, LatencyWindow};
use super::fault::{FaultPlan, FaultState};
use super::{
    classes_response, dot_pairs, embed_response, score_response, PartialRows, Request, Serving,
};

/// Persistent-loop knobs (`--max-batch`, `--max-delay-ms`,
/// `--deadline-ms`, `--queue-cap`, `--max-line-bytes`).
#[derive(Clone, Copy, Debug)]
pub struct ServerCfg {
    /// Flush when this many distinct node ids are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long.
    pub max_delay: Duration,
    /// Per-request deadline: a data request still unanswered this long
    /// after arrival is shed with `{"error": "deadline"}` in its
    /// position at the next flush. `None` disables deadlines.
    pub deadline: Option<Duration>,
    /// Admission bound: data requests arriving while this many items are
    /// already pending are shed with `{"error": "overloaded"}` in
    /// position (clamped to ≥ 1). Also bounds the concurrent engine's
    /// event queue.
    pub queue_cap: usize,
    /// Longest accepted input line in bytes; longer lines answer
    /// `{"error": "line_too_long"}` in position and are discarded
    /// without buffering (OOM hardening for the public socket).
    pub max_line_bytes: usize,
}

impl Default for ServerCfg {
    fn default() -> Self {
        Self {
            max_batch: 256,
            max_delay: Duration::from_millis(5),
            deadline: None,
            queue_cap: 1024,
            max_line_bytes: 1 << 20,
        }
    }
}

/// Exact per-session counters: request/response accounting on top of the
/// batcher's flush statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoopStats {
    /// Non-empty input lines consumed (requests + control ops).
    pub requests: u64,
    /// Successful response lines written.
    pub responses: u64,
    /// Error lines written (including shed responses).
    pub errors: u64,
    /// Requests shed with `{"error": "overloaded"}` (admission queue or
    /// engine queue full, or the connection cap reached).
    pub shed_overload: u64,
    /// Requests shed with `{"error": "deadline"}` (waited past the
    /// per-request deadline before their flush).
    pub shed_deadline: u64,
    /// Items answered by drain flushes (control barriers, EOF, shutdown)
    /// — the graceful-shutdown guarantee made countable.
    pub drained: u64,
    /// Connections dropped because their writer buffer overflowed (a
    /// client that stopped draining responses).
    pub dropped_conns: u64,
    /// Widest shard fan-out any flush dispatched (1 = sequential or a
    /// single shard; 0 = no sharded flush has run yet).
    pub fanout_width: u64,
    /// Cross-request batching counters.
    pub batch: BatchStats,
}

impl LoopStats {
    /// Accumulate another session's counters (TCP mode sums sessions).
    /// Exhaustive destructuring: adding a field without deciding how it
    /// aggregates is a compile error, not a silently dropped counter.
    pub fn absorb(&mut self, o: &LoopStats) {
        let LoopStats {
            requests,
            responses,
            errors,
            shed_overload,
            shed_deadline,
            drained,
            dropped_conns,
            fanout_width,
            batch,
        } = o;
        self.requests += requests;
        self.responses += responses;
        self.errors += errors;
        self.shed_overload += shed_overload;
        self.shed_deadline += shed_deadline;
        self.drained += drained;
        self.dropped_conns += dropped_conns;
        self.fanout_width = self.fanout_width.max(*fanout_width);
        self.batch.absorb(batch);
    }

    /// One-line human summary (the CLI prints it to stderr).
    pub fn summary(&self) -> String {
        format!(
            "{} request(s), {} response(s), {} error(s) | {} flush(es): {} fill / {} budget / \
             {} drain | {} node(s) coalesced away, {} unique computed | shed {} overload / \
             {} deadline, {} drained",
            self.requests,
            self.responses,
            self.errors,
            self.batch.flushes,
            self.batch.fill_flushes,
            self.batch.budget_expiries,
            self.batch.drain_flushes,
            self.batch.coalesced_nodes,
            self.batch.unique_nodes,
            self.shed_overload,
            self.shed_deadline,
            self.drained
        )
    }
}

/// One queued input line: a validated request or a deferred error that
/// must answer in its arrival position.
enum Pending {
    Req { req: Request, echo: Option<Json> },
    Fail { msg: String, echo: Option<Json> },
}

/// A [`Pending`] item with its response routing (connection + arrival
/// slot for the per-connection reorder buffer) and arrival time (for the
/// per-request deadline). The single-session loop uses `conn = 0` and a
/// running slot.
struct Queued {
    conn: u64,
    slot: u64,
    at: Instant,
    item: Pending,
}

/// One parsed input line.
enum Line {
    Item(Pending),
    Stats(Option<Json>),
    Shutdown(Option<Json>),
}

fn parse_line(line: &str, n_nodes: usize, owned: (u32, u32)) -> Line {
    let v = match ser::parse(line) {
        Ok(v) => v,
        Err(e) => return Line::Item(Pending::Fail { msg: format!("{e}"), echo: None }),
    };
    let echo = v.opt("id").cloned();
    match v.opt("op").and_then(|op| op.as_str().ok()) {
        Some("stats") => return Line::Stats(echo),
        Some("shutdown") => return Line::Shutdown(echo),
        _ => {}
    }
    match Request::from_json(&v) {
        Ok(req) => {
            // Validate ids at enqueue time so one bad id fails its own
            // line instead of poisoning a whole flush.
            if let Some(&bad) = req.node_ids().iter().find(|&&id| id as usize >= n_nodes) {
                return Line::Item(Pending::Fail {
                    msg: format!("node id {bad} out of range [0, {n_nodes})"),
                    echo,
                });
            }
            // A shard worker only owns [lo, hi): misrouted ids fail per
            // line, the same policy as out-of-range ids.
            let (lo, hi) = owned;
            if let Some(&bad) =
                req.node_ids().iter().find(|&&id| id < lo || id >= hi)
            {
                return Line::Item(Pending::Fail {
                    msg: format!("node id {bad} outside this shard's owned range [{lo}, {hi})"),
                    echo,
                });
            }
            Line::Item(Pending::Req { req, echo })
        }
        Err(e) => Line::Item(Pending::Fail { msg: format!("{e}"), echo }),
    }
}

fn with_echo(v: Json, echo: Option<Json>) -> Json {
    match (v, echo) {
        (Json::Obj(mut o), Some(e)) => {
            o.insert("id".to_string(), e);
            Json::Obj(o)
        }
        (v, _) => v,
    }
}

fn error_json(msg: &str, echo: Option<Json>) -> Json {
    with_echo(Json::obj(vec![("error", Json::str(msg))]), echo)
}

/// Node ids a pending item references (what the batcher accumulates).
fn item_ids(item: &Pending) -> Vec<u32> {
    match item {
        Pending::Req { req, .. } => req.node_ids(),
        Pending::Fail { .. } => Vec::new(),
    }
}

/// Admission bound: convert a data request into an in-position
/// `{"error": "overloaded"}` when the pending set is at capacity.
/// Deferred failures pass through (they carry no node ids and answer an
/// error either way).
fn admit(item: Pending, pending: usize, queue_cap: usize, stats: &mut LoopStats) -> Pending {
    match item {
        Pending::Req { echo, .. } if pending >= queue_cap.max(1) => {
            stats.shed_overload += 1;
            Pending::Fail { msg: "overloaded".into(), echo }
        }
        other => other,
    }
}

/// Build one response from the flush's precomputed rows. Embeds and
/// scores demux through the flush's shared id→row index; classes go
/// through [`Serving::classes_for_ids`] so remote backends can apply the
/// head worker-side (for local backends that path replays the rows the
/// flush just computed — through the cache — and is bit-identical by the
/// grouping-invariance rule).
fn respond(
    backend: &mut dyn Serving,
    req: &Request,
    index: &HashMap<u32, usize>,
    rows: &[f32],
    d: usize,
) -> Result<Json> {
    match req {
        Request::Embed(ids) => {
            let mut emb = vec![0.0f32; ids.len() * d];
            demux_rows_with(index, rows, d, ids, &mut emb)?;
            Ok(embed_response(ids, &emb, d))
        }
        Request::Score(edges) => {
            let ids = req.node_ids();
            let mut emb = vec![0.0f32; ids.len() * d];
            demux_rows_with(index, rows, d, &ids, &mut emb)?;
            Ok(score_response(edges, &dot_pairs(&emb, edges.len(), d)))
        }
        Request::Classes(ids) => {
            let (_logits, argmax) = backend.classes_for_ids(ids)?;
            Ok(classes_response(ids, &argmax))
        }
    }
}

/// Flush the pending set and emit one response per queued item, in queue
/// order, via `emit(conn, slot, line)`. Handles deadline shedding,
/// partial shard failures ([`Serving::embed_nodes_partial`]) and the
/// whole-union error path; records the flush latency.
#[allow(clippy::too_many_arguments)]
fn flush_core(
    backend: &mut dyn Serving,
    batcher: &mut CrossBatcher<Queued>,
    trigger: FlushTrigger,
    deadline: Option<Duration>,
    stats: &mut LoopStats,
    lat: &mut LatencyWindow,
    shard_lat: &mut LatencyWindow,
    emit: &mut dyn FnMut(u64, u64, &Json) -> Result<()>,
) -> Result<()> {
    if batcher.is_empty() {
        return Ok(());
    }
    let t0 = Instant::now();
    let (items, unique) = batcher.take(trigger);
    if trigger == FlushTrigger::Drain {
        stats.drained += items.len() as u64;
    }
    let computed = if unique.is_empty() {
        Ok(PartialRows::default())
    } else {
        backend.embed_nodes_partial(&unique)
    };
    // Sharded backends report how wide this flush fanned out and how
    // long each shard's sub-request took; fold both into the session's
    // observability counters.
    if let Some(report) = backend.take_fanout_report() {
        stats.fanout_width = stats.fanout_width.max(report.width as u64);
        for w in report.shard_wait_us {
            shard_lat.record(w);
        }
    }
    let d = backend.embed_dim();
    let now = Instant::now();
    match computed {
        Ok(part) => {
            // One id→row index per flush, shared by every request's demux.
            let index = row_index(&unique);
            for q in items {
                let Queued { conn, slot, at, item } = q;
                let line = match item {
                    Pending::Fail { msg, echo } => {
                        stats.errors += 1;
                        error_json(&msg, echo)
                    }
                    Pending::Req { req, echo } => {
                        let blown =
                            deadline.map(|dl| now.duration_since(at) >= dl).unwrap_or(false);
                        if blown {
                            stats.shed_deadline += 1;
                            stats.errors += 1;
                            error_json("deadline", echo)
                        } else if let Some(msg) =
                            req.node_ids().iter().find_map(|id| part.failed.get(id))
                        {
                            // Partial service: an id owned by a dead
                            // shard fails its own request; every other
                            // request demuxes bit-identically.
                            stats.errors += 1;
                            error_json(msg, echo)
                        } else {
                            match respond(backend, &req, &index, &part.rows, d) {
                                Ok(resp) => {
                                    stats.responses += 1;
                                    with_echo(resp, echo)
                                }
                                Err(e) => {
                                    stats.errors += 1;
                                    error_json(&format!("{e}"), echo)
                                }
                            }
                        }
                    }
                };
                emit(conn, slot, &line)?;
            }
        }
        Err(e) => {
            // The whole union failed (ids were pre-validated, so this is a
            // model/bundle-level fault): every queued line gets the error.
            let msg = format!("{e}");
            for q in items {
                stats.errors += 1;
                let Queued { conn, slot, item, .. } = q;
                let echo = match item {
                    Pending::Req { echo, .. } | Pending::Fail { echo, .. } => echo,
                };
                emit(conn, slot, &error_json(&msg, echo))?;
            }
        }
    }
    lat.record(t0.elapsed().as_micros() as u64);
    Ok(())
}

/// Single-writer flush: emit responses in queue order onto `out`.
#[allow(clippy::too_many_arguments)]
fn flush_to_writer(
    backend: &mut dyn Serving,
    batcher: &mut CrossBatcher<Queued>,
    trigger: FlushTrigger,
    cfg: &ServerCfg,
    stats: &mut LoopStats,
    lat: &mut LatencyWindow,
    shard_lat: &mut LatencyWindow,
    out: &mut dyn Write,
) -> Result<()> {
    let mut emit = |_conn: u64, _slot: u64, line: &Json| -> Result<()> {
        writeln!(out, "{}", ser::to_string_compact(line))?;
        Ok(())
    };
    flush_core(backend, batcher, trigger, cfg.deadline, stats, lat, shard_lat, &mut emit)?;
    out.flush()?;
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn stats_response(
    backend: &dyn Serving,
    stats: &LoopStats,
    batch: BatchStats,
    lat: &LatencyWindow,
    shard_lat: &LatencyWindow,
    queue_depth: usize,
    in_flight: usize,
) -> Json {
    let mut resp = Json::obj(vec![
        ("op", Json::str("stats")),
        ("requests", Json::num(stats.requests as f64)),
        ("responses", Json::num(stats.responses as f64)),
        ("errors", Json::num(stats.errors as f64)),
        ("flushes", Json::num(batch.flushes as f64)),
        ("fill_flushes", Json::num(batch.fill_flushes as f64)),
        ("budget_expiries", Json::num(batch.budget_expiries as f64)),
        ("drain_flushes", Json::num(batch.drain_flushes as f64)),
        ("coalesced_nodes", Json::num(batch.coalesced_nodes as f64)),
        ("unique_nodes", Json::num(batch.unique_nodes as f64)),
        ("shed_overload", Json::num(stats.shed_overload as f64)),
        ("shed_deadline", Json::num(stats.shed_deadline as f64)),
        ("drained_requests", Json::num(stats.drained as f64)),
        ("dropped_conns", Json::num(stats.dropped_conns as f64)),
        ("queue_depth", Json::num(queue_depth as f64)),
        ("in_flight", Json::num(in_flight as f64)),
        ("flush_p50_us", Json::num(lat.percentile(50) as f64)),
        ("flush_p99_us", Json::num(lat.percentile(99) as f64)),
        ("fanout_width", Json::num(stats.fanout_width as f64)),
        ("shard_wait_p50_us", Json::num(shard_lat.percentile(50) as f64)),
        ("shard_wait_p99_us", Json::num(shard_lat.percentile(99) as f64)),
        ("n_nodes", Json::num(backend.n_nodes() as f64)),
        ("dim", Json::num(backend.embed_dim() as f64)),
        ("model", Json::str(backend.model_name())),
        ("cache", backend.stats_json()),
    ]);
    // Cold-start telemetry of the served artifact(s): load wall time,
    // on-disk footprint, and whether int8 params were dequantized.
    // Absent for backends with no local bundle (the remote router).
    if let Some((load_us, bytes, quantized)) = backend.bundle_meta() {
        if let Json::Obj(o) = &mut resp {
            o.insert("bundle_load_us".to_string(), Json::num(load_us as f64));
            o.insert("bundle_bytes".to_string(), Json::num(bytes as f64));
            o.insert("quantized".to_string(), Json::Bool(quantized));
        }
    }
    // Shard workers advertise their owned range so the remote router can
    // validate the set in its stats-ping handshake.
    if let Some((lo, hi, index, count)) = backend.shard_info() {
        if let Json::Obj(o) = &mut resp {
            o.insert(
                "shard".to_string(),
                Json::obj(vec![
                    ("lo", Json::num(lo as f64)),
                    ("hi", Json::num(hi as f64)),
                    ("index", Json::num(index as f64)),
                    ("count", Json::num(count as f64)),
                ]),
            );
        }
    }
    resp
}

/// Lines the reader thread may buffer ahead of the serve loop. Bounded
/// so a client that floods requests (or never drains responses, wedging
/// the loop on socket backpressure) blocks its own reader instead of
/// growing server memory without limit.
const READER_BACKLOG: usize = 1024;

/// Responses a connection's writer may buffer before the engine declares
/// the client dead (it stopped draining) and drops the connection.
const WRITER_BACKLOG: usize = 4096;

/// Flush-latency samples the p50/p99 window keeps.
const LATENCY_WINDOW: usize = 4096;

/// Marker message for an input line that exceeded `max_line_bytes`; the
/// reader encodes it as an `InvalidData` io error so the channel type
/// stays `io::Result<String>`, and the loop answers
/// `{"error": "line_too_long"}` in position instead of ending the
/// session.
const LINE_TOO_LONG: &str = "line_too_long";

fn is_line_too_long(e: &std::io::Error) -> bool {
    e.kind() == std::io::ErrorKind::InvalidData && format!("{e}") == LINE_TOO_LONG
}

/// What one bounded line read produced.
pub(crate) enum RawLine {
    Eof,
    /// A complete line (without its newline) is in the caller's buffer.
    Line,
    /// The line exceeded the byte bound; its bytes were discarded up to
    /// (and including) the next newline.
    TooLong,
}

/// Read one `\n`-terminated line into `buf`, never buffering more than
/// `max` content bytes: once a line exceeds the bound, the remainder is
/// consumed and discarded chunk-by-chunk. A final unterminated line is
/// returned like `read_line` would.
pub(crate) fn read_bounded_line<R: BufRead>(
    r: &mut R,
    max: usize,
    buf: &mut Vec<u8>,
) -> std::io::Result<RawLine> {
    let mut too_long = false;
    loop {
        let avail = match r.fill_buf() {
            Ok(a) => a,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if avail.is_empty() {
            return Ok(if too_long {
                RawLine::TooLong
            } else if buf.is_empty() {
                RawLine::Eof
            } else {
                RawLine::Line
            });
        }
        match avail.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if !too_long && buf.len() + i > max {
                    too_long = true;
                    buf.clear();
                }
                if !too_long {
                    buf.extend_from_slice(&avail[..i]);
                }
                r.consume(i + 1);
                return Ok(if too_long { RawLine::TooLong } else { RawLine::Line });
            }
            None => {
                let n = avail.len();
                if !too_long && buf.len() + n > max {
                    too_long = true;
                    buf.clear();
                }
                if !too_long {
                    buf.extend_from_slice(avail);
                }
                r.consume(n);
            }
        }
    }
}

/// Spawn a detached thread reading raw lines into a bounded channel —
/// the select-able form of a blocking reader the budget wait needs.
/// Lines longer than `max_line_bytes` are reported as an `InvalidData`
/// error with message `line_too_long` (the loop answers them in
/// position); the channel closes at EOF or on the first real read error.
pub fn spawn_line_reader<R: BufRead + Send + 'static>(
    mut r: R,
    max_line_bytes: usize,
) -> Receiver<std::io::Result<String>> {
    let (tx, rx) = sync_channel(READER_BACKLOG);
    std::thread::spawn(move || {
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            match read_bounded_line(&mut r, max_line_bytes, &mut buf) {
                Ok(RawLine::Eof) => break,
                Ok(RawLine::Line) => {
                    let line = String::from_utf8_lossy(&buf).into_owned();
                    if tx.send(Ok(line)).is_err() {
                        break;
                    }
                }
                Ok(RawLine::TooLong) => {
                    let e =
                        std::io::Error::new(std::io::ErrorKind::InvalidData, LINE_TOO_LONG);
                    if tx.send(Err(e)).is_err() {
                        break;
                    }
                }
                Err(e) => {
                    let _ = tx.send(Err(e));
                    break;
                }
            }
        }
    });
    rx
}

/// Drive one NDJSON session to completion (EOF or `shutdown`); the core
/// the stdin, sequential-TCP and test front-ends share.
pub fn run_loop(
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    rx: &Receiver<std::io::Result<String>>,
    out: &mut dyn Write,
) -> Result<LoopStats> {
    let mut batcher: CrossBatcher<Queued> = CrossBatcher::new(cfg.max_batch, cfg.max_delay)?;
    let mut stats = LoopStats::default();
    let mut lat = LatencyWindow::new(LATENCY_WINDOW);
    let mut shard_lat = LatencyWindow::new(LATENCY_WINDOW);
    let mut slot = 0u64;
    let n_nodes = backend.n_nodes();
    let owned = backend.owned_range();
    loop {
        let msg = if batcher.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => None, // channel closed: EOF
            }
        } else {
            let deadline = batcher.deadline().expect("non-empty queue has a deadline");
            let wait = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(wait) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => {
                    flush_to_writer(
                        backend,
                        &mut batcher,
                        FlushTrigger::Budget,
                        cfg,
                        &mut stats,
                        &mut lat,
                        &mut shard_lat,
                        out,
                    )?;
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => None,
            }
        };
        let parsed = match msg {
            None => {
                flush_to_writer(
                    backend,
                    &mut batcher,
                    FlushTrigger::Drain,
                    cfg,
                    &mut stats,
                    &mut lat,
                    &mut shard_lat,
                    out,
                )?;
                break;
            }
            Some(Err(e)) if is_line_too_long(&e) => {
                // Oversized line: an in-position error, not a session end.
                stats.requests += 1;
                Line::Item(Pending::Fail { msg: LINE_TOO_LONG.into(), echo: None })
            }
            Some(Err(e)) => {
                flush_to_writer(
                    backend,
                    &mut batcher,
                    FlushTrigger::Drain,
                    cfg,
                    &mut stats,
                    &mut lat,
                    &mut shard_lat,
                    out,
                )?;
                return Err(e.into());
            }
            Some(Ok(line)) => {
                let line = line.trim().to_string();
                if line.is_empty() {
                    continue;
                }
                stats.requests += 1;
                parse_line(&line, n_nodes, owned)
            }
        };
        match parsed {
            Line::Item(item) => {
                let item = admit(item, batcher.len(), cfg.queue_cap, &mut stats);
                let ids = item_ids(&item);
                let s = slot;
                slot += 1;
                let q = Queued { conn: 0, slot: s, at: Instant::now(), item };
                let full = batcher.push(q, &ids, Instant::now());
                if full {
                    flush_to_writer(
                        backend,
                        &mut batcher,
                        FlushTrigger::Fill,
                        cfg,
                        &mut stats,
                        &mut lat,
                        &mut shard_lat,
                        out,
                    )?;
                } else if batcher.should_flush(Instant::now()) {
                    // Continuous traffic must still honor the budget even
                    // though recv_timeout never got to expire.
                    flush_to_writer(
                        backend,
                        &mut batcher,
                        FlushTrigger::Budget,
                        cfg,
                        &mut stats,
                        &mut lat,
                        &mut shard_lat,
                        out,
                    )?;
                }
            }
            Line::Stats(echo) => {
                let depth = batcher.len();
                flush_to_writer(
                    backend,
                    &mut batcher,
                    FlushTrigger::Drain,
                    cfg,
                    &mut stats,
                    &mut lat,
                    &mut shard_lat,
                    out,
                )?;
                stats.responses += 1;
                let resp = with_echo(
                    stats_response(backend, &stats, batcher.stats(), &lat, &shard_lat, depth, 1),
                    echo,
                );
                writeln!(out, "{}", ser::to_string_compact(&resp))?;
                out.flush()?;
            }
            Line::Shutdown(echo) => {
                flush_to_writer(
                    backend,
                    &mut batcher,
                    FlushTrigger::Drain,
                    cfg,
                    &mut stats,
                    &mut lat,
                    &mut shard_lat,
                    out,
                )?;
                stats.responses += 1;
                let resp = with_echo(
                    Json::obj(vec![("op", Json::str("shutdown")), ("ok", Json::Bool(true))]),
                    echo,
                );
                writeln!(out, "{}", ser::to_string_compact(&resp))?;
                out.flush()?;
                break;
            }
        }
    }
    stats.batch = batcher.stats();
    Ok(stats)
}

/// Run one NDJSON session over an arbitrary reader/writer pair (the
/// piped-session entry point the e2e tests drive).
pub fn run_ndjson<R: BufRead + Send + 'static>(
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    input: R,
    out: &mut dyn Write,
) -> Result<LoopStats> {
    let rx = spawn_line_reader(input, cfg.max_line_bytes);
    run_loop(backend, cfg, &rx, out)
}

/// `hashgnn serve --stdin`: one NDJSON session over stdin/stdout.
pub fn serve_stdin(backend: &mut dyn Serving, cfg: &ServerCfg) -> Result<LoopStats> {
    let rx =
        spawn_line_reader(std::io::BufReader::new(std::io::stdin()), cfg.max_line_bytes);
    let mut out = std::io::BufWriter::new(std::io::stdout());
    run_loop(backend, cfg, &rx, &mut out)
}

/// Sequential TCP accept loop: one NDJSON session at a time over a
/// shared backend (and so one warm cache across connections).
/// `max_conns = 0` accepts forever; a positive bound makes the call
/// return aggregate stats after that many connections (tests use 1).
/// The CLI's `--listen` front uses [`serve_concurrent`] instead.
pub fn serve_listener(
    listener: std::net::TcpListener,
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    max_conns: usize,
) -> Result<LoopStats> {
    let mut total = LoopStats::default();
    let mut served = 0usize;
    while max_conns == 0 || served < max_conns {
        let (stream, peer) = listener.accept()?;
        eprintln!("[serve] connection from {peer}");
        let reader = std::io::BufReader::new(stream.try_clone()?);
        let closer = stream.try_clone()?;
        let rx = spawn_line_reader(reader, cfg.max_line_bytes);
        let mut out = std::io::BufWriter::new(stream);
        match run_loop(backend, cfg, &rx, &mut out) {
            Ok(s) => {
                eprintln!("[serve] connection closed: {}", s.summary());
                total.absorb(&s);
            }
            Err(e) => eprintln!("[serve] connection error: {e}"),
        }
        // The reader thread still holds a clone of the socket blocked in
        // its read; shut the connection down so the client sees EOF and
        // the thread unblocks instead of leaking per connection.
        let _ = closer.shutdown(std::net::Shutdown::Both);
        served += 1;
    }
    Ok(total)
}

// ---------------------------------------------------------------------------
// Concurrent front: N connections, one engine, one shared CrossBatcher.
// ---------------------------------------------------------------------------

/// Engine-queue events. Per-connection reader threads produce `Line` /
/// `TooLong` / `Closed`; the accept thread produces `Open`.
enum Event {
    Open { conn: u64, tx: SyncSender<(u64, String)>, peer: String },
    Line { conn: u64, slot: u64, at: Instant, text: String },
    TooLong { conn: u64, slot: u64 },
    Closed { conn: u64 },
}

fn overloaded_line() -> String {
    ser::to_string_compact(&error_json("overloaded", None))
}

/// Write one response line through the (optional) fault plan — the hook
/// the deterministic degradation tests drive. Returns `Err` on a dead
/// socket, which ends the writer.
fn write_response(
    out: &mut dyn Write,
    line: &str,
    fault: &Option<Arc<Mutex<FaultState>>>,
) -> std::io::Result<()> {
    match fault {
        None => {
            out.write_all(line.as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()
        }
        Some(f) => {
            let decision = f.lock().expect("fault state lock").decide(line);
            if decision.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(decision.delay_ms));
            }
            if let Some(bytes) = &decision.bytes {
                out.write_all(bytes)?;
                out.flush()?;
            }
            if decision.kill {
                // kill-after-K: die abruptly, like a crashed worker.
                std::process::exit(9);
            }
            Ok(())
        }
    }
}

/// Per-connection writer: receives `(slot, line)` in any order, writes
/// strictly in slot order (responses leave in request order no matter
/// how flushes interleave connections), and shuts the connection down on
/// exit so the peer — and this connection's blocked reader — see EOF.
fn spawn_conn_writer(
    stream: TcpStream,
    rx: Receiver<(u64, String)>,
    fault: Option<Arc<Mutex<FaultState>>>,
) {
    std::thread::spawn(move || {
        let mut out = stream;
        let mut next = 0u64;
        let mut held: BTreeMap<u64, String> = BTreeMap::new();
        'recv: for (slot, line) in rx {
            held.insert(slot, line);
            while let Some(line) = held.remove(&next) {
                if write_response(&mut out, &line, &fault).is_err() {
                    break 'recv;
                }
                next += 1;
            }
        }
        let _ = out.shutdown(Shutdown::Both);
    });
}

/// Per-connection reader: bounded lines in, slot-stamped events out.
/// Data lines go through `try_send` against the bounded engine queue — a
/// full queue sheds the line right here with `{"error": "overloaded"}`
/// in position (via the writer, so ordering holds).
#[allow(clippy::too_many_arguments)]
fn spawn_conn_reader(
    conn: u64,
    stream: TcpStream,
    max_line_bytes: usize,
    etx: SyncSender<Event>,
    wtx: SyncSender<(u64, String)>,
    shed: Arc<AtomicU64>,
    active: Arc<AtomicUsize>,
) {
    std::thread::spawn(move || {
        let mut r = BufReader::new(stream);
        let mut slot = 0u64;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            match read_bounded_line(&mut r, max_line_bytes, &mut buf) {
                Ok(RawLine::Eof) | Err(_) => break,
                Ok(RawLine::TooLong) => {
                    let s = slot;
                    slot += 1;
                    if etx.send(Event::TooLong { conn, slot: s }).is_err() {
                        break;
                    }
                }
                Ok(RawLine::Line) => {
                    let text = String::from_utf8_lossy(&buf).into_owned();
                    if text.trim().is_empty() {
                        continue;
                    }
                    let s = slot;
                    slot += 1;
                    match etx.try_send(Event::Line { conn, slot: s, at: Instant::now(), text })
                    {
                        Ok(()) => {}
                        Err(TrySendError::Full(_)) => {
                            shed.fetch_add(1, Ordering::Relaxed);
                            if wtx.send((s, overloaded_line())).is_err() {
                                break;
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => break,
                    }
                }
            }
        }
        let _ = etx.send(Event::Closed { conn });
        active.fetch_sub(1, Ordering::Relaxed);
    });
}

/// Cross-connection flush: emit each response into its connection's
/// writer queue. Returns the connections whose writer buffer was full or
/// gone (the engine drops them — a client that stops draining responses
/// must not stall everyone else).
#[allow(clippy::too_many_arguments)]
fn flush_to_conns(
    backend: &mut dyn Serving,
    batcher: &mut CrossBatcher<Queued>,
    trigger: FlushTrigger,
    cfg: &ServerCfg,
    stats: &mut LoopStats,
    lat: &mut LatencyWindow,
    shard_lat: &mut LatencyWindow,
    conns: &HashMap<u64, SyncSender<(u64, String)>>,
) -> Result<Vec<u64>> {
    let dead = std::cell::RefCell::new(Vec::new());
    let mut emit = |conn: u64, slot: u64, line: &Json| -> Result<()> {
        if let Some(tx) = conns.get(&conn) {
            if tx.try_send((slot, ser::to_string_compact(line))).is_err() {
                dead.borrow_mut().push(conn);
            }
        }
        Ok(())
    };
    flush_core(backend, batcher, trigger, cfg.deadline, stats, lat, shard_lat, &mut emit)?;
    Ok(dead.into_inner())
}

/// `hashgnn serve --listen`: the concurrent front. Accepts up to
/// `max_conns` connections at once (0 = unbounded), funnels every
/// connection's lines through one bounded engine queue into the ONE
/// shared [`CrossBatcher`] — so deduplication coalesces across
/// connections — and answers each connection in its own request order
/// via a slot-reordering writer thread. The backend never leaves the
/// calling thread. Returns after a `shutdown` control op from any
/// connection (drain, answer, exit) or when the listener dies.
///
/// `fault` injects the deterministic failure plan into every writer
/// (shard workers use this; `None` serves cleanly).
pub fn serve_concurrent(
    listener: TcpListener,
    backend: &mut dyn Serving,
    cfg: &ServerCfg,
    max_conns: usize,
    fault: Option<FaultPlan>,
) -> Result<LoopStats> {
    let addr = listener.local_addr()?;
    let fault = fault
        .filter(|p| !p.is_empty())
        .map(|p| Arc::new(Mutex::new(FaultState::new(p))));
    let (etx, erx) = sync_channel::<Event>(cfg.queue_cap.max(1));
    let stop = Arc::new(AtomicBool::new(false));
    let shed_io = Arc::new(AtomicU64::new(0));
    let active = Arc::new(AtomicUsize::new(0));

    // Accept thread: owns the listener, spawns a reader + writer pair
    // per connection, registers it with the engine. The engine wakes it
    // at shutdown with a dummy connection so `accept` observes `stop`.
    {
        let etx = etx.clone();
        let stop = Arc::clone(&stop);
        let shed = Arc::clone(&shed_io);
        let active = Arc::clone(&active);
        let fault = fault.clone();
        let max_line = cfg.max_line_bytes;
        std::thread::spawn(move || {
            let mut next_conn = 0u64;
            loop {
                let (stream, peer) = match listener.accept() {
                    Ok(x) => x,
                    Err(_) => break,
                };
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                if max_conns > 0 && active.load(Ordering::Relaxed) >= max_conns {
                    // Connection cap: shed loudly with one line, then close.
                    shed.fetch_add(1, Ordering::Relaxed);
                    let mut s = stream;
                    let _ = writeln!(s, "{}", overloaded_line());
                    let _ = s.shutdown(Shutdown::Both);
                    continue;
                }
                let wstream = match stream.try_clone() {
                    Ok(c) => c,
                    Err(_) => continue,
                };
                next_conn += 1;
                let conn = next_conn;
                active.fetch_add(1, Ordering::Relaxed);
                let (wtx, wrx) = sync_channel::<(u64, String)>(WRITER_BACKLOG);
                spawn_conn_writer(wstream, wrx, fault.clone());
                if etx
                    .send(Event::Open { conn, tx: wtx.clone(), peer: peer.to_string() })
                    .is_err()
                {
                    break;
                }
                spawn_conn_reader(
                    conn,
                    stream,
                    max_line,
                    etx.clone(),
                    wtx,
                    Arc::clone(&shed),
                    Arc::clone(&active),
                );
            }
        });
    }
    drop(etx); // engine sees Disconnected once the accept thread and every reader are gone

    let mut batcher: CrossBatcher<Queued> = CrossBatcher::new(cfg.max_batch, cfg.max_delay)?;
    let mut stats = LoopStats::default();
    let mut lat = LatencyWindow::new(LATENCY_WINDOW);
    let mut shard_lat = LatencyWindow::new(LATENCY_WINDOW);
    let mut conns: HashMap<u64, SyncSender<(u64, String)>> = HashMap::new();
    let n_nodes = backend.n_nodes();
    let owned = backend.owned_range();

    macro_rules! engine_flush {
        ($trigger:expr) => {{
            let dead =
                flush_to_conns(
                backend, &mut batcher, $trigger, cfg, &mut stats, &mut lat, &mut shard_lat,
                &conns,
            )?;
            for c in dead {
                if conns.remove(&c).is_some() {
                    stats.dropped_conns += 1;
                }
            }
        }};
    }

    'engine: loop {
        let msg = if batcher.is_empty() {
            match erx.recv() {
                Ok(m) => Some(m),
                Err(_) => None,
            }
        } else {
            let deadline = batcher.deadline().expect("non-empty queue has a deadline");
            let wait = deadline.saturating_duration_since(Instant::now());
            match erx.recv_timeout(wait) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => {
                    engine_flush!(FlushTrigger::Budget);
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => None,
            }
        };
        let (conn, slot, at, parsed) = match msg {
            None => {
                // Listener gone and every reader exited: drain and stop.
                engine_flush!(FlushTrigger::Drain);
                break;
            }
            Some(Event::Open { conn, tx, peer }) => {
                eprintln!("[serve] connection {conn} from {peer}");
                conns.insert(conn, tx);
                continue;
            }
            Some(Event::Closed { conn }) => {
                // Answer everything this connection still has in flight
                // before its writer channel is dropped.
                engine_flush!(FlushTrigger::Drain);
                conns.remove(&conn);
                continue;
            }
            Some(Event::TooLong { conn, slot }) => {
                stats.requests += 1;
                (
                    conn,
                    slot,
                    Instant::now(),
                    Line::Item(Pending::Fail { msg: LINE_TOO_LONG.into(), echo: None }),
                )
            }
            Some(Event::Line { conn, slot, at, text }) => {
                stats.requests += 1;
                (conn, slot, at, parse_line(text.trim(), n_nodes, owned))
            }
        };
        match parsed {
            Line::Item(item) => {
                let item = admit(item, batcher.len(), cfg.queue_cap, &mut stats);
                let ids = item_ids(&item);
                let full = batcher.push(Queued { conn, slot, at, item }, &ids, Instant::now());
                if full {
                    engine_flush!(FlushTrigger::Fill);
                } else if batcher.should_flush(Instant::now()) {
                    engine_flush!(FlushTrigger::Budget);
                }
            }
            Line::Stats(echo) => {
                let depth = batcher.len();
                engine_flush!(FlushTrigger::Drain);
                stats.responses += 1;
                // Reader-side sheds live in the shared counter; fold them
                // into the reported view (and the final return value).
                let mut view = stats;
                view.shed_overload += shed_io.load(Ordering::Relaxed);
                let resp = with_echo(
                    stats_response(backend, &view, batcher.stats(), &lat, &shard_lat, depth, conns.len()),
                    echo,
                );
                let lost = conns
                    .get(&conn)
                    .map(|tx| tx.try_send((slot, ser::to_string_compact(&resp))).is_err())
                    .unwrap_or(false);
                if lost && conns.remove(&conn).is_some() {
                    stats.dropped_conns += 1;
                }
            }
            Line::Shutdown(echo) => {
                engine_flush!(FlushTrigger::Drain);
                stats.responses += 1;
                let resp = with_echo(
                    Json::obj(vec![("op", Json::str("shutdown")), ("ok", Json::Bool(true))]),
                    echo,
                );
                if let Some(tx) = conns.get(&conn) {
                    let _ = tx.try_send((slot, ser::to_string_compact(&resp)));
                }
                break 'engine;
            }
        }
    }

    // Graceful teardown: stop accepting (nudge the blocked accept with a
    // throwaway connection), drop every writer sender — each writer
    // drains its buffered responses, then shuts its connection down,
    // which also unblocks that connection's reader.
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    drop(conns);
    stats.shed_overload += shed_io.load(Ordering::Relaxed);
    stats.batch = batcher.stats();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_classifies_requests_controls_and_errors() {
        let all = (0u32, 10u32);
        match parse_line(r#"{"op": "embed", "nodes": [1, 2], "id": 7}"#, 10, all) {
            Line::Item(Pending::Req { req, echo }) => {
                assert_eq!(req, Request::Embed(vec![1, 2]));
                assert_eq!(echo, Some(Json::num(7.0)));
            }
            _ => panic!("expected a request"),
        }
        assert!(matches!(parse_line(r#"{"op": "stats"}"#, 10, all), Line::Stats(None)));
        assert!(matches!(parse_line(r#"{"op": "shutdown"}"#, 10, all), Line::Shutdown(None)));
        // Out-of-range id fails its own line at parse time.
        match parse_line(r#"{"op": "embed", "nodes": [99]}"#, 10, all) {
            Line::Item(Pending::Fail { msg, .. }) => assert!(msg.contains("out of range")),
            _ => panic!("expected a deferred failure"),
        }
        // A shard worker rejects ids outside its owned range per line.
        match parse_line(r#"{"op": "embed", "nodes": [7]}"#, 10, (0, 5)) {
            Line::Item(Pending::Fail { msg, .. }) => {
                assert!(msg.contains("owned range"), "{msg}")
            }
            _ => panic!("expected a deferred failure"),
        }
        // Malformed JSON and unknown ops likewise.
        assert!(matches!(
            parse_line("not json", 10, all),
            Line::Item(Pending::Fail { .. })
        ));
        assert!(matches!(
            parse_line(r#"{"op": "train"}"#, 10, all),
            Line::Item(Pending::Fail { .. })
        ));
    }

    #[test]
    fn loop_stats_absorb_covers_every_field() {
        // Exhaustive-destructuring absorb: every field must aggregate.
        // Counters sum; fanout_width is a high-water mark (the widest
        // dispatch any session saw), so absorb takes the max.
        let mut a = LoopStats {
            requests: 1,
            responses: 2,
            errors: 3,
            shed_overload: 4,
            shed_deadline: 5,
            drained: 6,
            dropped_conns: 7,
            fanout_width: 3,
            batch: BatchStats::default(),
        };
        let b = LoopStats {
            requests: 10,
            responses: 20,
            errors: 30,
            shed_overload: 40,
            shed_deadline: 50,
            drained: 60,
            dropped_conns: 70,
            fanout_width: 2,
            batch: BatchStats::default(),
        };
        a.absorb(&b);
        assert_eq!(a.requests, 11);
        assert_eq!(a.responses, 22);
        assert_eq!(a.errors, 33);
        assert_eq!(a.shed_overload, 44);
        assert_eq!(a.shed_deadline, 55);
        assert_eq!(a.drained, 66);
        assert_eq!(a.dropped_conns, 77);
        assert_eq!(a.fanout_width, 3, "width is max-aggregated, not summed");
        // And the max flows the other way too.
        let wide = LoopStats { fanout_width: 9, ..LoopStats::default() };
        a.absorb(&wide);
        assert_eq!(a.fanout_width, 9);
    }

    #[test]
    fn echo_attaches_to_objects_only() {
        let v = with_echo(Json::obj(vec![("a", Json::num(1.0))]), Some(Json::str("x")));
        assert_eq!(v.get("id").unwrap(), &Json::str("x"));
        let e = error_json("boom", None);
        assert!(e.get("error").is_ok() && e.opt("id").is_none());
    }

    #[test]
    fn admit_sheds_data_requests_at_capacity_only() {
        let mut stats = LoopStats::default();
        let req = Pending::Req { req: Request::Embed(vec![1]), echo: Some(Json::num(1.0)) };
        // Below the bound: passes through untouched.
        match admit(req, 3, 4, &mut stats) {
            Pending::Req { .. } => {}
            _ => panic!("under capacity must admit"),
        }
        assert_eq!(stats.shed_overload, 0);
        // At the bound: converted to an in-position overloaded error,
        // echo preserved.
        let req = Pending::Req { req: Request::Embed(vec![1]), echo: Some(Json::num(1.0)) };
        match admit(req, 4, 4, &mut stats) {
            Pending::Fail { msg, echo } => {
                assert_eq!(msg, "overloaded");
                assert_eq!(echo, Some(Json::num(1.0)));
            }
            _ => panic!("at capacity must shed"),
        }
        assert_eq!(stats.shed_overload, 1);
        // Deferred failures pass through even at capacity.
        let fail = Pending::Fail { msg: "x".into(), echo: None };
        match admit(fail, 100, 4, &mut stats) {
            Pending::Fail { msg, .. } => assert_eq!(msg, "x"),
            _ => panic!("failures are never converted"),
        }
        assert_eq!(stats.shed_overload, 1);
    }

    #[test]
    fn bounded_line_reader_discards_oversized_lines_in_position() {
        let input = b"short\n0123456789ABCDEF_too_long\nnext\nlast".to_vec();
        let mut r = std::io::BufReader::with_capacity(4, std::io::Cursor::new(input));
        let mut buf = Vec::new();
        assert!(matches!(read_bounded_line(&mut r, 8, &mut buf).unwrap(), RawLine::Line));
        assert_eq!(buf, b"short");
        buf.clear();
        assert!(matches!(read_bounded_line(&mut r, 8, &mut buf).unwrap(), RawLine::TooLong));
        assert!(buf.is_empty(), "oversized bytes are discarded, not buffered");
        buf.clear();
        assert!(matches!(read_bounded_line(&mut r, 8, &mut buf).unwrap(), RawLine::Line));
        assert_eq!(buf, b"next", "the line after an oversized one survives");
        buf.clear();
        // Final unterminated line comes back like read_line's would.
        assert!(matches!(read_bounded_line(&mut r, 8, &mut buf).unwrap(), RawLine::Line));
        assert_eq!(buf, b"last");
        buf.clear();
        assert!(matches!(read_bounded_line(&mut r, 8, &mut buf).unwrap(), RawLine::Eof));
    }
}
