//! Tiny declarative CLI argument parser (the offline crate set has no
//! `clap`). Supports `--flag value`, `--flag=value`, boolean `--flag`,
//! positional subcommands, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// One option specification.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Declarative parser: register options, then `parse` an argv tail.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Register a valued option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some(default.to_string()), is_flag: false });
        self
    }

    /// Register a required valued option.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false });
        self
    }

    /// Register a boolean flag (false unless present).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: Some("false".to_string()), is_flag: true });
        self
    }

    /// Render a --help string.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let d = match (&o.default, o.is_flag) {
                (_, true) => "".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, d));
        }
        s
    }

    /// Parse an argv tail (e.g. `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self> {
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(Error::Config(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| Error::Config(format!("unknown option --{name}\n{}", self.usage())))?
                    .clone();
                let value = if spec.is_flag {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| Error::Config(format!("option --{name} needs a value")))?
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(a);
            }
        }
        // Check required options.
        for o in &self.opts {
            if o.default.is_none() && !self.values.contains_key(o.name) {
                return Err(Error::Config(format!("missing required option --{}", o.name)));
            }
        }
        Ok(self)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be an unsigned integer")))
    }

    /// Like [`Self::get_usize`], but also accepts `auto` / `all` as `0`
    /// (the conventional "resolve against the machine" sentinel, used by
    /// parallelism knobs like `--threads`).
    pub fn get_usize_auto(&self, name: &str) -> Result<usize> {
        match self.get(name).as_str() {
            "auto" | "all" => Ok(0),
            v => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} must be an unsigned integer or 'auto'"))),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be a u64")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| Error::Config(format!("--{name} must be a float")))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name).as_str(), "true" | "1" | "yes")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = Args::new("t", "test")
            .opt("nodes", "100", "node count")
            .opt("seed", "42", "rng seed")
            .flag("verbose", "chatty")
            .parse(argv(&["--nodes", "5000", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("nodes").unwrap(), 5000);
        assert_eq!(a.get_u64("seed").unwrap(), 42);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t", "test")
            .opt("lr", "0.01", "learning rate")
            .parse(argv(&["--lr=0.5"]))
            .unwrap();
        assert_eq!(a.get_f64("lr").unwrap(), 0.5);
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t", "test").opt("x", "1", "x").parse(argv(&["--bogus", "3"]));
        assert!(r.is_err());
    }

    #[test]
    fn required_option_enforced() {
        let r = Args::new("t", "test").req("model", "model name").parse(argv(&[]));
        assert!(r.is_err());
        let ok = Args::new("t", "test")
            .req("model", "model name")
            .parse(argv(&["--model", "sage"]))
            .unwrap();
        assert_eq!(ok.get("model"), "sage");
    }

    #[test]
    fn positionals_collected() {
        let a = Args::new("t", "test")
            .opt("x", "1", "x")
            .parse(argv(&["cmd", "--x", "2", "sub"]))
            .unwrap();
        assert_eq!(a.positional(), &["cmd".to_string(), "sub".to_string()]);
    }

    #[test]
    fn usize_auto_accepts_sentinels() {
        let a = Args::new("t", "test")
            .opt("threads", "0", "worker threads")
            .parse(argv(&["--threads", "auto"]))
            .unwrap();
        assert_eq!(a.get_usize_auto("threads").unwrap(), 0);
        let a = Args::new("t", "test")
            .opt("threads", "0", "worker threads")
            .parse(argv(&["--threads", "4"]))
            .unwrap();
        assert_eq!(a.get_usize_auto("threads").unwrap(), 4);
        let a = Args::new("t", "test")
            .opt("threads", "0", "worker threads")
            .parse(argv(&["--threads", "lots"]))
            .unwrap();
        assert!(a.get_usize_auto("threads").is_err());
    }

    #[test]
    fn bad_numeric_value_reports_option() {
        let a = Args::new("t", "test").opt("n", "1", "n").parse(argv(&["--n", "abc"])).unwrap();
        let e = a.get_usize("n").unwrap_err();
        assert!(format!("{e}").contains("--n"));
    }
}
