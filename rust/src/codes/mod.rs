//! Compositional-code storage (Section 3.1).
//!
//! Codes are stored **bit-packed** (`m·log2(c)` bits per node in `u64`
//! words) "because the binary format is more space-efficient compared to
//! the integer format", and converted back to integer vectors `(n, m)` with
//! elements in `[0, c)` right before feeding the decoder (Figure 2's
//! binary→integer step).
//!
//! Also provides the **random coding** generator — the ALONE baseline
//! (Takase & Kobayashi 2020) the paper compares against.

use crate::cfg::CodingCfg;
use crate::rng::{Rng, Xoshiro256pp};
use crate::ser::section::SharedU64s;
use crate::{Error, Result};

/// Backing storage for packed code words: an owned `Vec` (the training /
/// encoding path) or a borrowed view into a serving-bundle section
/// buffer (`HGNB0002` zero-copy load). Reads see one flat `&[u64]`
/// either way; the first mutation of a view promotes it to an owned copy
/// (copy-on-write), so the encoder's in-place word writes keep working
/// unchanged.
#[derive(Clone, Debug)]
enum WordStore {
    Owned(Vec<u64>),
    View(SharedU64s),
}

impl WordStore {
    #[inline]
    fn as_slice(&self) -> &[u64] {
        match self {
            WordStore::Owned(v) => v,
            WordStore::View(s) => s.as_slice(),
        }
    }

    /// Mutable access; a borrowed view is copied out first (the only
    /// place a v2-loaded code section is ever duplicated).
    #[inline]
    fn make_mut(&mut self) -> &mut Vec<u64> {
        if let WordStore::View(s) = self {
            *self = WordStore::Owned(s.as_slice().to_vec());
        }
        match self {
            WordStore::Owned(v) => v,
            WordStore::View(_) => unreachable!("just promoted"),
        }
    }
}

/// A dense `n × n_bits` bit matrix, rows packed into `u64` words.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    n: usize,
    n_bits: usize,
    words_per_row: usize,
    words: WordStore,
}

/// Equality is by content: two matrices compare equal regardless of
/// whether their words are owned or borrowed from a bundle buffer.
impl PartialEq for BitMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.n_bits == other.n_bits
            && self.words_per_row == other.words_per_row
            && self.words.as_slice() == other.words.as_slice()
    }
}

impl BitMatrix {
    /// All-false matrix (Algorithm 1, line 3).
    pub fn zeros(n: usize, n_bits: usize) -> Self {
        let words_per_row = n_bits.div_ceil(64);
        Self { n, n_bits, words_per_row, words: WordStore::Owned(vec![0u64; n * words_per_row]) }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn n_bits(&self) -> usize {
        self.n_bits
    }

    /// Storage bytes (the quantity reported in Table 2).
    pub fn storage_bytes(&self) -> usize {
        self.words.as_slice().len() * 8
    }

    /// True when the words are a borrowed view into a bundle buffer
    /// rather than an owned heap `Vec` (zero-copy load diagnostics).
    pub fn words_borrowed(&self) -> bool {
        matches!(self.words, WordStore::View(_))
    }

    #[inline]
    pub fn set(&mut self, row: usize, bit: usize, value: bool) {
        debug_assert!(row < self.n && bit < self.n_bits);
        let w = row * self.words_per_row + bit / 64;
        let mask = 1u64 << (bit % 64);
        let words = self.words.make_mut();
        if value {
            words[w] |= mask;
        } else {
            words[w] &= !mask;
        }
    }

    #[inline]
    pub fn get(&self, row: usize, bit: usize) -> bool {
        debug_assert!(row < self.n && bit < self.n_bits);
        let w = row * self.words_per_row + bit / 64;
        (self.words.as_slice()[w] >> (bit % 64)) & 1 == 1
    }

    /// Words per packed row (`ceil(n_bits / 64)`).
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Overwrite one 64-bit word of a row — bits `[64·word, 64·word + 64)`
    /// in one store (64× fewer read-modify-write cycles than per-bit
    /// [`Self::set`]). This is the checked single-row counterpart of the
    /// parallel encoder's packer, which writes the same layout through
    /// disjoint [`Self::words_mut`] row views; external callers building
    /// packed codes word-at-a-time should come through here.
    ///
    /// Bits at positions `≥ n_bits` in the last word must be zero; the
    /// padding invariant is what lets row comparisons work on raw words.
    #[inline]
    pub fn set_word(&mut self, row: usize, word: usize, value: u64) {
        debug_assert!(row < self.n && word < self.words_per_row);
        debug_assert!(
            word + 1 < self.words_per_row
                || self.n_bits % 64 == 0
                || value >> (self.n_bits % 64) == 0,
            "set_word: nonzero padding bits past n_bits"
        );
        self.words.make_mut()[row * self.words_per_row + word] = value;
    }

    /// Raw words of one row.
    pub fn row_words(&self, row: usize) -> &[u64] {
        &self.words.as_slice()[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    /// All packed words, row-major with [`Self::words_per_row`] words per
    /// row. Exposed so the parallel encoder can split the storage into
    /// disjoint per-thread row ranges (`&mut words[r0*wpr .. r1*wpr]`) and
    /// assemble 64 bits per store without going through `&mut self`.
    /// Callers must keep the padding invariant of [`Self::set_word`].
    pub fn words_mut(&mut self) -> &mut [u64] {
        self.words.make_mut()
    }

    /// Number of rows that collide (i.e. `n − #distinct codes`) — the
    /// quantity histogrammed in Figures 3 and 6.
    ///
    /// Allocation-light: rows are reduced to a [`crate::rng::mix64`]-mixed
    /// content hash in one scratch `Vec<(u64, u32)>`, sorted, and only
    /// equal-hash runs fall back to exact word-slice comparison (so the
    /// count stays exact even under 64-bit hash collisions). The old
    /// implementation keyed a `HashMap` by `Vec<u64>` — one heap
    /// allocation per row, inside `collision_trials`' trial loop.
    pub fn n_collisions(&self) -> usize {
        let mut keyed: Vec<(u64, u32)> = Vec::with_capacity(self.n);
        for r in 0..self.n {
            let mut h = 0x243F_6A88_85A3_08D3u64;
            for &w in self.row_words(r) {
                h = crate::rng::mix64(h ^ w).wrapping_add(0x9E37_79B9_7F4A_7C15);
            }
            keyed.push((h, r as u32));
        }
        keyed.sort_unstable();
        let mut distinct = 0usize;
        let mut reps: Vec<u32> = Vec::new();
        let mut i = 0;
        while i < keyed.len() {
            let mut j = i + 1;
            while j < keyed.len() && keyed[j].0 == keyed[i].0 {
                j += 1;
            }
            reps.clear();
            for &(_, r) in &keyed[i..j] {
                let row = self.row_words(r as usize);
                if !reps.iter().any(|&p| self.row_words(p as usize) == row) {
                    reps.push(r);
                }
            }
            distinct += reps.len();
            i = j;
        }
        self.n - distinct
    }

    /// All packed words, row-major ([`Self::words_per_row`] per row) —
    /// read-only view for serializers (the serving bundle embeds the raw
    /// words verbatim).
    pub fn words(&self) -> &[u64] {
        self.words.as_slice()
    }

    /// Shared validation for [`Self::from_words`] / [`Self::from_shared_words`]:
    /// word count and the padding invariant of [`Self::set_word`].
    fn check_words(n: usize, n_bits: usize, words: &[u64]) -> Result<usize> {
        let words_per_row = n_bits.div_ceil(64);
        if words.len() != n * words_per_row {
            return Err(Error::Shape(format!(
                "bit matrix needs {} words for {n}×{n_bits}, got {}",
                n * words_per_row,
                words.len()
            )));
        }
        if n_bits % 64 != 0 && words_per_row > 0 {
            for r in 0..n {
                let last = words[r * words_per_row + words_per_row - 1];
                if last >> (n_bits % 64) != 0 {
                    return Err(Error::Shape(format!(
                        "bit matrix row {r} has nonzero padding past bit {n_bits}"
                    )));
                }
            }
        }
        Ok(words_per_row)
    }

    /// Rebuild from raw packed words (inverse of [`Self::words`]); the
    /// word count and the padding invariant of [`Self::set_word`] are
    /// checked.
    pub fn from_words(n: usize, n_bits: usize, words: Vec<u64>) -> Result<Self> {
        let words_per_row = Self::check_words(n, n_bits, &words)?;
        Ok(Self { n, n_bits, words_per_row, words: WordStore::Owned(words) })
    }

    /// Zero-copy counterpart of [`Self::from_words`]: the packed words
    /// stay a borrowed view into a serving-bundle section buffer. Same
    /// validation; reads are identical; the first mutation copies.
    pub fn from_shared_words(n: usize, n_bits: usize, words: SharedU64s) -> Result<Self> {
        let words_per_row = Self::check_words(n, n_bits, words.as_slice())?;
        Ok(Self { n, n_bits, words_per_row, words: WordStore::View(words) })
    }

    /// Serialize to a compact binary file.
    ///
    /// Format `HGNC0002`: 8-byte magic, payload byte count + FNV-1a
    /// checksum of the payload (u64 LE each), then the payload
    /// (`n`, `n_bits`, packed words, all LE) — truncation and bit rot are
    /// caught at [`Self::load`] before any decoding.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let words = self.words.as_slice();
        let mut payload = Vec::with_capacity(16 + words.len() * 8);
        payload.extend_from_slice(&(self.n as u64).to_le_bytes());
        payload.extend_from_slice(&(self.n_bits as u64).to_le_bytes());
        for w in words {
            payload.extend_from_slice(&w.to_le_bytes());
        }
        std::fs::write(path, crate::ser::write_envelope(b"HGNC0002", &payload))?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let buf = std::fs::read(path)?;
        if buf.len() >= 8 && &buf[..8] == b"HGNC0001" {
            return Err(Error::Config(format!(
                "{}: v1 code file (HGNC0001, no checksum header) is no longer readable — \
                 re-run `hashgnn encode --out` to regenerate it",
                path.display()
            )));
        }
        let (_, payload) = crate::ser::read_envelope(&buf, &[b"HGNC0002"], "code file", path)?;
        if payload.len() < 16 {
            return Err(Error::Config(format!(
                "{}: truncated code file ({} payload bytes, header needs 16)",
                path.display(),
                payload.len()
            )));
        }
        let n = u64::from_le_bytes(payload[0..8].try_into().unwrap()) as usize;
        let n_bits = u64::from_le_bytes(payload[8..16].try_into().unwrap()) as usize;
        let words_per_row = n_bits.div_ceil(64);
        if payload.len() != 16 + n * words_per_row * 8 {
            return Err(Error::Config(format!(
                "{}: code file declares {n}×{n_bits} but carries {} word bytes",
                path.display(),
                payload.len() - 16
            )));
        }
        let words = payload[16..]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Self::from_words(n, n_bits, words)
    }
}

/// A code table ready for the decoder: packed bits plus the `(c, m)` format
/// needed to slice them into integer indices.
#[derive(Clone, Debug)]
pub struct CodeTable {
    pub bits: BitMatrix,
    pub coding: CodingCfg,
}

impl CodeTable {
    pub fn new(bits: BitMatrix, coding: CodingCfg) -> Result<Self> {
        if bits.n_bits() != coding.n_bits() {
            return Err(Error::Shape(format!(
                "bit matrix has {} bits/row but coding (c={}, m={}) needs {}",
                bits.n_bits(),
                coding.c,
                coding.m,
                coding.n_bits()
            )));
        }
        Ok(Self { bits, coding })
    }

    pub fn n(&self) -> usize {
        self.bits.n()
    }

    /// Integer code vector of one entity: `m` values in `[0, c)`.
    /// Bit layout: element `e` occupies bits `[e·log2c, (e+1)·log2c)`,
    /// most-significant bit first within the element (so the paper's
    /// example `[10 00 11 01 00 01] ↔ [2,0,3,1,0,1]` holds).
    pub fn int_code(&self, entity: usize) -> Vec<i32> {
        let bpe = self.coding.bits_per_element();
        let mut out = Vec::with_capacity(self.coding.m);
        for e in 0..self.coding.m {
            let mut v = 0i32;
            for b in 0..bpe {
                v = (v << 1) | i32::from(self.bits.get(entity, e * bpe + b));
            }
            out.push(v);
        }
        out
    }

    /// Gather integer codes for a slice of entity ids into a flat
    /// `(ids.len(), m)` row-major buffer — the decoder's input tensor.
    pub fn gather_int_codes(&self, ids: &[u32], out: &mut Vec<i32>) {
        let bpe = self.coding.bits_per_element();
        out.clear();
        out.reserve(ids.len() * self.coding.m);
        for &id in ids {
            let entity = id as usize;
            for e in 0..self.coding.m {
                let mut v = 0i32;
                for b in 0..bpe {
                    v = (v << 1) | i32::from(self.bits.get(entity, e * bpe + b));
                }
                out.push(v);
            }
        }
    }

    /// Build from integer codes (inverse of [`Self::int_code`]).
    pub fn from_int_codes(codes: &[i32], n: usize, coding: CodingCfg) -> Result<Self> {
        if codes.len() != n * coding.m {
            return Err(Error::Shape(format!(
                "expected {} code values, got {}",
                n * coding.m,
                codes.len()
            )));
        }
        let bpe = coding.bits_per_element();
        let mut bits = BitMatrix::zeros(n, coding.n_bits());
        for row in 0..n {
            for e in 0..coding.m {
                let v = codes[row * coding.m + e];
                if v < 0 || v as usize >= coding.c {
                    return Err(Error::Shape(format!("code value {v} out of [0, {})", coding.c)));
                }
                for b in 0..bpe {
                    let bit = (v >> (bpe - 1 - b)) & 1 == 1;
                    bits.set(row, e * bpe + b, bit);
                }
            }
        }
        Self::new(bits, coding)
    }
}

/// ALONE baseline: uniformly random compositional codes (Takase &
/// Kobayashi 2020 generate each code element uniformly in `[0, c)`).
pub fn random_codes(n: usize, coding: CodingCfg, seed: u64) -> CodeTable {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut bits = BitMatrix::zeros(n, coding.n_bits());
    for row in 0..n {
        for bit in 0..coding.n_bits() {
            bits.set(row, bit, rng.bool_with(0.5));
        }
    }
    CodeTable::new(bits, coding).expect("format consistent by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coding(c: usize, m: usize) -> CodingCfg {
        CodingCfg::new(c, m).unwrap()
    }

    #[test]
    fn paper_example_roundtrip() {
        // §1: code [2,0,3,1,0,1] with c=4, m=6 ↔ bits [10 00 11 01 00 01].
        let codes = vec![2, 0, 3, 1, 0, 1];
        let t = CodeTable::from_int_codes(&codes, 1, coding(4, 6)).unwrap();
        let expect_bits = [true, false, false, false, true, true, false, true, false, false, false, true];
        for (i, &e) in expect_bits.iter().enumerate() {
            assert_eq!(t.bits.get(0, i), e, "bit {i}");
        }
        assert_eq!(t.int_code(0), codes);
    }

    #[test]
    fn int_code_roundtrip_many() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        for &(c, m) in &[(2usize, 128usize), (4, 64), (16, 32), (256, 16)] {
            let cfg = coding(c, m);
            let n = 20;
            let codes: Vec<i32> = (0..n * m).map(|_| rng.index(c) as i32).collect();
            let t = CodeTable::from_int_codes(&codes, n, cfg).unwrap();
            for row in 0..n {
                assert_eq!(t.int_code(row), codes[row * m..(row + 1) * m].to_vec());
            }
        }
    }

    #[test]
    fn gather_matches_int_code() {
        let t = random_codes(50, coding(16, 8), 7);
        let ids = vec![3u32, 49, 0, 3];
        let mut buf = Vec::new();
        t.gather_int_codes(&ids, &mut buf);
        assert_eq!(buf.len(), 4 * 8);
        for (k, &id) in ids.iter().enumerate() {
            assert_eq!(&buf[k * 8..(k + 1) * 8], t.int_code(id as usize).as_slice());
        }
    }

    #[test]
    fn bitmatrix_set_get() {
        let mut b = BitMatrix::zeros(3, 100);
        b.set(1, 63, true);
        b.set(1, 64, true);
        b.set(2, 99, true);
        assert!(b.get(1, 63));
        assert!(b.get(1, 64));
        assert!(b.get(2, 99));
        assert!(!b.get(0, 63));
        b.set(1, 63, false);
        assert!(!b.get(1, 63));
    }

    #[test]
    fn set_word_matches_per_bit_sets() {
        let mut by_bit = BitMatrix::zeros(3, 100);
        let mut by_word = BitMatrix::zeros(3, 100);
        let pattern = 0xDEAD_BEEF_CAFE_F00Du64;
        for bit in 0..64 {
            by_bit.set(1, bit, (pattern >> bit) & 1 == 1);
        }
        by_word.set_word(1, 0, pattern);
        // Second (partial) word: only 36 valid bits.
        let tail = pattern & ((1u64 << 36) - 1);
        for bit in 0..36 {
            by_bit.set(1, 64 + bit, (tail >> bit) & 1 == 1);
        }
        by_word.set_word(1, 1, tail);
        assert_eq!(by_bit, by_word);
        assert_eq!(by_word.words_per_row(), 2);
    }

    #[test]
    fn n_collisions_matches_hashmap_reference() {
        for seed in 0..5u64 {
            // Few bits over many rows → plenty of genuine duplicates.
            let t = random_codes(300, coding(2, 6), seed);
            let mut seen = std::collections::HashMap::new();
            for r in 0..300 {
                *seen.entry(t.bits.row_words(r).to_vec()).or_insert(0usize) += 1;
            }
            assert_eq!(t.bits.n_collisions(), 300 - seen.len(), "seed {seed}");
        }
    }

    #[test]
    fn collisions_counted() {
        let mut b = BitMatrix::zeros(4, 8);
        // rows 0 and 1 identical (all zero); row 2 distinct; row 3 = row 2.
        b.set(2, 1, true);
        b.set(3, 1, true);
        assert_eq!(b.n_collisions(), 2);
        b.set(3, 2, true);
        assert_eq!(b.n_collisions(), 1);
    }

    #[test]
    fn save_load_roundtrip() {
        let t = random_codes(17, coding(4, 10), 11);
        let dir = std::env::temp_dir().join("hashgnn_test_codes");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("codes.bin");
        t.bits.save(&path).unwrap();
        let back = BitMatrix::load(&path).unwrap();
        assert_eq!(t.bits, back);
    }

    #[test]
    fn load_rejects_corrupt() {
        let dir = std::env::temp_dir().join("hashgnn_test_codes");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a code file at all").unwrap();
        assert!(BitMatrix::load(&path).is_err());
        // A flipped payload byte fails the checksum.
        let t = random_codes(17, coding(4, 10), 11);
        let path = dir.join("flip.bin");
        t.bits.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = BitMatrix::load(&path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn words_roundtrip_and_padding_guard() {
        let t = random_codes(9, coding(4, 10), 2); // 20 bits/row → 1 word
        let back =
            BitMatrix::from_words(9, 20, t.bits.words().to_vec()).unwrap();
        assert_eq!(t.bits, back);
        assert!(BitMatrix::from_words(9, 20, vec![0; 5]).is_err(), "wrong word count");
        assert!(
            BitMatrix::from_words(1, 20, vec![1u64 << 20]).is_err(),
            "padding bit past n_bits"
        );
    }

    #[test]
    fn shared_words_view_reads_equal_and_copies_on_write() {
        use crate::ser::section::SectionBuf;
        let t = random_codes(9, coding(4, 10), 2); // 20 bits/row → 1 word/row
        let mut bytes = Vec::new();
        for w in t.bits.words() {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let buf = SectionBuf::from_bytes(&bytes);
        let shared = SharedU64s::new(buf, 0, t.bits.words().len()).unwrap();
        let view = BitMatrix::from_shared_words(9, 20, shared.clone()).unwrap();
        assert!(view.words_borrowed());
        assert_eq!(view, t.bits, "view reads bit-identically");
        // Mutation promotes to an owned copy; the backing stays untouched.
        let mut mutated = view.clone();
        mutated.set(0, 0, !mutated.get(0, 0));
        assert!(!mutated.words_borrowed());
        assert_ne!(mutated, t.bits);
        assert_eq!(shared.as_slice(), t.bits.words(), "backing unchanged");
        // Validation still applies to views.
        assert!(BitMatrix::from_shared_words(8, 20, shared).is_err(), "wrong count");
    }

    #[test]
    fn random_codes_bit_balance() {
        let t = random_codes(200, coding(2, 64), 5);
        let ones: usize = (0..200)
            .map(|r| (0..64).filter(|&b| t.bits.get(r, b)).count())
            .sum();
        let frac = ones as f64 / (200.0 * 64.0);
        assert!((frac - 0.5).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn storage_bytes_matches_formula() {
        let b = BitMatrix::zeros(1000, 128);
        assert_eq!(b.storage_bytes(), 1000 * 2 * 8); // 128 bits = 2 words
    }

    #[test]
    fn format_mismatch_rejected() {
        let bits = BitMatrix::zeros(5, 100);
        assert!(CodeTable::new(bits, coding(4, 64)).is_err()); // needs 128
    }
}
