//! Report rendering: ASCII tables (matching the paper's row/column
//! layout) and CSV output for the bench harnesses.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let sep_len = width.iter().sum::<usize>() + 3 * ncol + 1;
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float metric the way the paper prints them (4 decimals).
pub fn metric(v: f64) -> String {
    format!("{v:.4}")
}

/// Format megabytes with 2 decimals (Table 2 style).
pub fn mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// A text histogram (Figures 3/6 are histograms of collision counts).
pub fn histogram(title: &str, values: &[usize], n_bins: usize) -> String {
    if values.is_empty() {
        return format!("== {title} == (no data)\n");
    }
    let min = *values.iter().min().unwrap();
    let max = *values.iter().max().unwrap();
    let span = (max - min).max(1);
    let bins = n_bins.max(1);
    let mut counts = vec![0usize; bins];
    for &v in values {
        let b = ((v - min) * (bins - 1) / span).min(bins - 1);
        counts[b] += 1;
    }
    let peak = *counts.iter().max().unwrap().max(&1);
    let mut out = format!("== {title} == (n={}, min={min}, max={max})\n", values.len());
    for (i, &c) in counts.iter().enumerate() {
        let lo = min + i * span / bins;
        let hi = min + (i + 1) * span / bins;
        let bar = "#".repeat(c * 40 / peak);
        out.push_str(&format!("  [{lo:>6}..{hi:>6}) {c:>4} {bar}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["dataset", "NC", "Hash"]);
        t.row(vec!["ogbn-arxiv".into(), "0.6228".into(), "0.6259".into()]);
        t.row(vec!["x".into(), "1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("ogbn-arxiv"));
        // Alignment: both data lines have the same length.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn metric_and_mb() {
        assert_eq!(metric(0.62340), "0.6234");
        assert_eq!(mb(456_790_000), "435.63");
    }

    #[test]
    fn histogram_shape() {
        let h = histogram("coll", &[1, 2, 2, 3, 10], 3);
        assert!(h.contains("n=5"));
        assert!(h.contains('#'));
        let empty = histogram("none", &[], 3);
        assert!(empty.contains("no data"));
    }
}
