//! Evaluation metrics used across the paper's experiments:
//! accuracy (§5.2/§5.3), hits@k (ogbl + merchant hit rate), NMI for node
//! clustering (§5.1), Spearman's ρ for word similarity (§5.1), and Lloyd's
//! k-means as the clustering substrate (paper cites Lloyd 1982).

mod kmeans;

pub use kmeans::kmeans;

/// Classification accuracy from logits (row-major `n × k`) vs labels.
pub fn accuracy_from_logits(logits: &[f32], n: usize, k: usize, labels: &[u32]) -> f64 {
    assert_eq!(logits.len(), n * k);
    assert_eq!(labels.len(), n);
    if n == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits[i * k..(i + 1) * k];
        let pred = argmax(row);
        if pred as u32 == labels[i] {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Hit@k from logits: fraction of rows whose true label ranks in the top-k.
pub fn hits_at_k_from_logits(logits: &[f32], n: usize, c: usize, labels: &[u32], k: usize) -> f64 {
    assert_eq!(logits.len(), n * c);
    if n == 0 {
        return 0.0;
    }
    let mut hits = 0usize;
    for i in 0..n {
        let row = &logits[i * c..(i + 1) * c];
        let true_score = row[labels[i] as usize];
        // Rank = number of classes scoring strictly higher.
        let higher = row.iter().filter(|&&s| s > true_score).count();
        if higher < k {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// OGB-style link-prediction hits@k: fraction of positive edges whose score
/// exceeds the (k-th highest) negative-edge score threshold.
pub fn link_hits_at_k(pos_scores: &[f32], neg_scores: &[f32], k: usize) -> f64 {
    if pos_scores.is_empty() {
        return 0.0;
    }
    if neg_scores.len() < k {
        return 1.0;
    }
    let mut negs = neg_scores.to_vec();
    negs.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let threshold = negs[k - 1];
    let hits = pos_scores.iter().filter(|&&s| s > threshold).count();
    hits as f64 / pos_scores.len() as f64
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Spearman's rank correlation ρ (average-rank tie handling).
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

fn ranks(xs: &[f32]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut out = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let da = a[i] - ma;
        let db = b[i] - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Normalized mutual information between two labelings (arithmetic-mean
/// normalization, the scikit-learn default the paper's protocol implies).
pub fn nmi(a: &[u32], b: &[u32], ka: usize, kb: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let mut joint = vec![0.0f64; ka * kb];
    let mut pa = vec![0.0f64; ka];
    let mut pb = vec![0.0f64; kb];
    for i in 0..n {
        joint[a[i] as usize * kb + b[i] as usize] += 1.0;
        pa[a[i] as usize] += 1.0;
        pb[b[i] as usize] += 1.0;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for i in 0..ka {
        for j in 0..kb {
            let pij = joint[i * kb + j] / nf;
            if pij > 0.0 {
                mi += pij * (pij / ((pa[i] / nf) * (pb[j] / nf))).ln();
            }
        }
    }
    let ha: f64 = -pa
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| (p / nf) * (p / nf).ln())
        .sum::<f64>();
    let hb: f64 = -pb
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| (p / nf) * (p / nf).ln())
        .sum::<f64>();
    if ha == 0.0 || hb == 0.0 {
        return if ha == hb { 1.0 } else { 0.0 };
    }
    (mi / ((ha + hb) / 2.0)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        // 3 samples, 2 classes.
        let logits = vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4];
        assert_eq!(accuracy_from_logits(&logits, 3, 2, &[0, 1, 0]), 1.0);
        assert!((accuracy_from_logits(&logits, 3, 2, &[1, 1, 0]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn hits_at_k_ordering() {
        // 1 sample, 4 classes, true label ranked 2nd.
        let logits = vec![0.4, 0.3, 0.2, 0.1];
        assert_eq!(hits_at_k_from_logits(&logits, 1, 4, &[1], 1), 0.0);
        assert_eq!(hits_at_k_from_logits(&logits, 1, 4, &[1], 2), 1.0);
        assert_eq!(hits_at_k_from_logits(&logits, 1, 4, &[0], 1), 1.0);
    }

    #[test]
    fn link_hits() {
        let pos = vec![0.9, 0.5, 0.1];
        let neg = vec![0.8, 0.6, 0.4, 0.2];
        // k=2 → threshold is 0.6; only 0.9 exceeds.
        assert!((link_hits_at_k(&pos, &neg, 2) - 1.0 / 3.0).abs() < 1e-12);
        // k=4 → threshold 0.2; 0.9 and 0.5 exceed.
        assert!((link_hits_at_k(&pos, &neg, 4) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_perfect_and_inverse() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = vec![40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = vec![1.0, 1.0, 2.0];
        let b = vec![3.0, 3.0, 5.0];
        let rho = spearman(&a, &b);
        assert!(rho > 0.99, "rho={rho}");
    }

    #[test]
    fn nmi_identical_and_independent() {
        let a = vec![0u32, 0, 1, 1, 2, 2];
        assert!((nmi(&a, &a, 3, 3) - 1.0).abs() < 1e-12);
        // Permuted labels still perfect NMI.
        let b = vec![2u32, 2, 0, 0, 1, 1];
        assert!((nmi(&a, &b, 3, 3) - 1.0).abs() < 1e-12);
        // Constant labeling → 0 against non-constant.
        let c = vec![0u32; 6];
        assert_eq!(nmi(&a, &c, 3, 1), 0.0);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
