//! Lloyd's k-means (the paper clusters metapath2vec embeddings with
//! k-means and scores NMI, §5.1 / Appendix B.1.4).

use crate::rng::{Rng, Xoshiro256pp};

/// Cluster `data` (row-major `n × d`) into `k` clusters; returns the
/// assignment vector. k-means++ seeding, fixed iteration budget.
pub fn kmeans(data: &[f32], n: usize, d: usize, k: usize, iters: usize, seed: u64) -> Vec<u32> {
    assert_eq!(data.len(), n * d);
    assert!(k >= 1 && n >= k);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);

    // k-means++ initialization.
    let mut centers = vec![0.0f32; k * d];
    let first = rng.index(n);
    centers[..d].copy_from_slice(&data[first * d..(first + 1) * d]);
    let mut min_d2 = vec![0.0f32; n];
    for i in 0..n {
        min_d2[i] = dist2(&data[i * d..(i + 1) * d], &centers[..d]);
    }
    for c in 1..k {
        let total: f64 = min_d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.index(n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for i in 0..n {
                target -= min_d2[i] as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centers[c * d..(c + 1) * d].copy_from_slice(&data[pick * d..(pick + 1) * d]);
        for i in 0..n {
            let nd = dist2(&data[i * d..(i + 1) * d], &centers[c * d..(c + 1) * d]);
            if nd < min_d2[i] {
                min_d2[i] = nd;
            }
        }
    }

    let mut assign = vec![0u32; n];
    let mut counts = vec![0usize; k];
    for _ in 0..iters {
        // Assignment step.
        let mut changed = false;
        for i in 0..n {
            let row = &data[i * d..(i + 1) * d];
            let mut best = (f32::MAX, 0u32);
            for c in 0..k {
                let dd = dist2(row, &centers[c * d..(c + 1) * d]);
                if dd < best.0 {
                    best = (dd, c as u32);
                }
            }
            if assign[i] != best.1 {
                assign[i] = best.1;
                changed = true;
            }
        }
        // Update step.
        centers.fill(0.0);
        counts.fill(0);
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            for j in 0..d {
                centers[c * d + j] += data[i * d + j];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at a random point.
                let p = rng.index(n);
                centers[c * d..(c + 1) * d].copy_from_slice(&data[p * d..(p + 1) * d]);
            } else {
                let inv = 1.0 / counts[c] as f32;
                for j in 0..d {
                    centers[c * d + j] *= inv;
                }
            }
        }
        if !changed {
            break;
        }
    }
    assign
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let diff = a[i] - b[i];
        s += diff * diff;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::nmi;

    #[test]
    fn separates_obvious_clusters() {
        // Two tight blobs far apart.
        let mut data = Vec::new();
        let mut truth = Vec::new();
        for i in 0..40 {
            let offset = if i < 20 { 0.0 } else { 100.0 };
            data.push(offset + (i % 5) as f32 * 0.01);
            data.push(offset - (i % 3) as f32 * 0.01);
            truth.push(u32::from(i >= 20));
        }
        let assign = kmeans(&data, 40, 2, 2, 20, 1);
        assert!((nmi(&assign, &truth, 2, 2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let data: Vec<f32> = (0..200).map(|i| (i as f32 * 0.7).sin()).collect();
        let a = kmeans(&data, 50, 4, 3, 10, 9);
        let b = kmeans(&data, 50, 4, 3, 10, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn k_equals_one() {
        let data = vec![1.0, 2.0, 3.0, 4.0];
        let a = kmeans(&data, 4, 1, 1, 5, 2);
        assert!(a.iter().all(|&c| c == 0));
    }

    #[test]
    fn assignments_in_range() {
        let data: Vec<f32> = (0..300).map(|i| (i as f32).cos()).collect();
        let a = kmeans(&data, 100, 3, 7, 15, 3);
        assert!(a.iter().all(|&c| c < 7));
    }
}
