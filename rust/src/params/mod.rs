//! Parameter state: initialization from manifest specs, AdamW moment
//! buffers, checkpointing. The executables are pure functions — all state
//! lives here, threaded through every call (DESIGN.md §8.1).

use std::path::Path;

use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{InitKind, Manifest, Tensor};
use crate::{Error, Result};

/// All state for one model: parameters + AdamW moments + step counter.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub params: Vec<Tensor>,
    pub adam_m: Vec<Tensor>,
    pub adam_v: Vec<Tensor>,
    pub step: u64,
}

impl ParamStore {
    /// Initialize from manifest specs (rules mirror
    /// `python/compile/specs.py`): xavier_uniform uses fan_in/fan_out =
    /// first/last dims; normal uses the recorded std; zeros/ones as named.
    pub fn init(manifest: &Manifest, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let mut params = Vec::with_capacity(manifest.params.len());
        for spec in &manifest.params {
            let n = spec.n_elements();
            let mut data = vec![0.0f32; n];
            match spec.init {
                InitKind::Zeros => {}
                InitKind::Ones => data.fill(1.0),
                InitKind::Normal { std } => rng.fill_normal_f32(&mut data, 0.0, std),
                InitKind::XavierUniform => {
                    let fan_in = *spec.shape.first().unwrap_or(&1) as f64;
                    let fan_out = *spec.shape.last().unwrap_or(&1) as f64;
                    let a = (6.0 / (fan_in + fan_out)).sqrt() as f32;
                    rng.fill_uniform_f32(&mut data, -a, a);
                }
            }
            params.push(Tensor::F32 { shape: spec.shape.clone(), data });
        }
        let adam_m = manifest.params.iter().map(|s| Tensor::zeros_f32(&s.shape)).collect();
        let adam_v = manifest.params.iter().map(|s| Tensor::zeros_f32(&s.shape)).collect();
        Self { params, adam_m, adam_v, step: 0 }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Assemble the train-step input vector:
    /// `[params…, m…, v…, step, batch…]`.
    pub fn train_inputs(&self, batch: &[Tensor]) -> Vec<Tensor> {
        let mut inputs = Vec::with_capacity(3 * self.params.len() + 1 + batch.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.adam_m.iter().cloned());
        inputs.extend(self.adam_v.iter().cloned());
        inputs.push(Tensor::scalar_f32(self.step as f32));
        inputs.extend(batch.iter().cloned());
        inputs
    }

    /// Assemble the predict input vector: `[params…, batch…]`.
    pub fn pred_inputs(&self, batch: &[Tensor]) -> Vec<Tensor> {
        let mut inputs = Vec::with_capacity(self.params.len() + batch.len());
        inputs.extend(self.params.iter().cloned());
        inputs.extend(batch.iter().cloned());
        inputs
    }

    /// Absorb a train-step output tuple `(params…, m…, v…, loss)`;
    /// returns the loss.
    pub fn absorb(&mut self, mut outputs: Vec<Tensor>) -> Result<f32> {
        let p = self.params.len();
        if outputs.len() != 3 * p + 1 {
            return Err(Error::Runtime(format!(
                "train step returned {} tensors, expected {}",
                outputs.len(),
                3 * p + 1
            )));
        }
        let loss = outputs.pop().expect("checked length").scalar()?;
        let vs = outputs.split_off(2 * p);
        let ms = outputs.split_off(p);
        self.params = outputs;
        self.adam_m = ms;
        self.adam_v = vs;
        self.step += 1;
        Ok(loss)
    }

    /// Parameter bytes (f32), the Table-2 accounting unit.
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|t| t.len() * 4).sum()
    }

    /// Save a checkpoint (params + moments + step) to a binary file.
    ///
    /// Format `HGNP0002`: an 8-byte magic, then the payload byte count and
    /// an FNV-1a checksum of the payload (both u64 LE) — so a truncated or
    /// bit-rotted file fails loudly at [`Self::load`] instead of decoding
    /// into garbage parameters — then the payload (step, tensor count,
    /// three tensor groups of rank + dims + f32 data, all LE).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut payload: Vec<u8> = Vec::new();
        payload.extend_from_slice(&(self.step).to_le_bytes());
        payload.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for group in [&self.params, &self.adam_m, &self.adam_v] {
            for t in group.iter() {
                let data = t.as_f32()?;
                let shape = t.shape();
                payload.extend_from_slice(&(shape.len() as u64).to_le_bytes());
                for &d in shape {
                    payload.extend_from_slice(&(d as u64).to_le_bytes());
                }
                for &x in data {
                    payload.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        std::fs::write(path, crate::ser::write_envelope(b"HGNP0002", &payload))?;
        Ok(())
    }

    /// Load a checkpoint previously written by [`Self::save`], verifying
    /// the header's byte count and checksum before decoding anything.
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)?;
        if buf.len() >= 8 && &buf[..8] == b"HGNP0001" {
            return Err(Error::Config(format!(
                "{}: v1 checkpoint (HGNP0001, no checksum header) is no longer readable — \
                 re-train (or re-save) to produce a v2 checkpoint",
                path.display()
            )));
        }
        let (_, payload) = crate::ser::read_envelope(&buf, &[b"HGNP0002"], "checkpoint", path)?;
        let mut pos = 0usize;
        let read_u64 = |buf: &[u8], pos: &mut usize| -> Result<u64> {
            if *pos + 8 > buf.len() {
                return Err(Error::Config("truncated checkpoint".into()));
            }
            let v = u64::from_le_bytes(buf[*pos..*pos + 8].try_into().unwrap());
            *pos += 8;
            Ok(v)
        };
        let step = read_u64(payload, &mut pos)?;
        let n = read_u64(payload, &mut pos)? as usize;
        let mut groups: Vec<Vec<Tensor>> = Vec::with_capacity(3);
        for _ in 0..3 {
            let mut group = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = read_u64(payload, &mut pos)? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(read_u64(payload, &mut pos)? as usize);
                }
                let count: usize = shape.iter().product();
                if pos + count * 4 > payload.len() {
                    return Err(Error::Config("truncated checkpoint data".into()));
                }
                let data: Vec<f32> = payload[pos..pos + count * 4]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                pos += count * 4;
                group.push(Tensor::F32 { shape, data });
            }
            groups.push(group);
        }
        let adam_v = groups.pop().expect("3 groups");
        let adam_m = groups.pop().expect("2 groups");
        let params = groups.pop().expect("1 group");
        Ok(Self { params, adam_m, adam_v, step })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ser::parse;

    fn manifest() -> Manifest {
        let j = parse(
            r#"{
          "name": "t",
          "params": [
            {"name": "a", "shape": [4, 6], "init": "xavier_uniform", "std": 0.0, "trainable": true},
            {"name": "b", "shape": [6], "init": "zeros", "std": 0.0, "trainable": true},
            {"name": "c", "shape": [2, 3], "init": "normal", "std": 2.0, "trainable": false},
            {"name": "d", "shape": [3], "init": "ones", "std": 0.0, "trainable": true}
          ],
          "train_inputs": [],
          "pred_inputs": [],
          "pred_output": {"name": "x", "shape": [1], "dtype": "f32"},
          "hyper": {}
        }"#,
        )
        .unwrap();
        Manifest::from_json(&j).unwrap()
    }

    #[test]
    fn init_respects_kinds() {
        let store = ParamStore::init(&manifest(), 1);
        // xavier bounds: sqrt(6/10) ≈ 0.7746.
        let a = store.params[0].as_f32().unwrap();
        let bound = (6.0f32 / 10.0).sqrt() + 1e-6;
        assert!(a.iter().all(|&x| x.abs() <= bound));
        assert!(a.iter().any(|&x| x != 0.0));
        assert!(store.params[1].as_f32().unwrap().iter().all(|&x| x == 0.0));
        let c = store.params[2].as_f32().unwrap();
        assert!(c.iter().any(|&x| x.abs() > 0.5)); // std=2 normal
        assert!(store.params[3].as_f32().unwrap().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn init_deterministic() {
        let a = ParamStore::init(&manifest(), 42);
        let b = ParamStore::init(&manifest(), 42);
        let c = ParamStore::init(&manifest(), 43);
        assert_eq!(a.params, b.params);
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn train_inputs_layout() {
        let store = ParamStore::init(&manifest(), 1);
        let batch = vec![Tensor::scalar_f32(9.0)];
        let inputs = store.train_inputs(&batch);
        assert_eq!(inputs.len(), 3 * 4 + 1 + 1);
        assert_eq!(inputs[12].scalar().unwrap(), 0.0); // step
        assert_eq!(inputs[13].scalar().unwrap(), 9.0); // batch
    }

    #[test]
    fn absorb_roundtrip() {
        let mut store = ParamStore::init(&manifest(), 1);
        let mut outs: Vec<Tensor> = Vec::new();
        outs.extend(store.params.iter().cloned());
        outs.extend(store.adam_m.iter().cloned());
        outs.extend(store.adam_v.iter().cloned());
        outs.push(Tensor::scalar_f32(0.5));
        let loss = store.absorb(outs).unwrap();
        assert_eq!(loss, 0.5);
        assert_eq!(store.step, 1);
    }

    #[test]
    fn absorb_rejects_wrong_arity() {
        let mut store = ParamStore::init(&manifest(), 1);
        assert!(store.absorb(vec![Tensor::scalar_f32(0.0)]).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut store = ParamStore::init(&manifest(), 7);
        store.step = 123;
        let dir = std::env::temp_dir().join("hashgnn_test_params");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        store.save(&path).unwrap();
        let back = ParamStore::load(&path).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.params, store.params);
        assert_eq!(back.adam_m, store.adam_m);
        assert_eq!(back.adam_v, store.adam_v);
    }

    #[test]
    fn load_rejects_corrupt_and_truncated_checkpoints() {
        let store = ParamStore::init(&manifest(), 3);
        let dir = std::env::temp_dir().join("hashgnn_test_params");
        std::fs::create_dir_all(&dir).unwrap();

        // Not a checkpoint at all.
        let garbage = dir.join("garbage.bin");
        std::fs::write(&garbage, b"definitely not a checkpoint").unwrap();
        let err = ParamStore::load(&garbage).unwrap_err();
        assert!(format!("{err}").contains("not a checkpoint"), "{err}");

        // A single flipped payload byte must fail the checksum, not decode.
        let path = dir.join("ckpt_corrupt.bin");
        store.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = 24 + (bytes.len() - 24) / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = ParamStore::load(&path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");

        // Truncation is caught by the header byte count.
        let path = dir.join("ckpt_trunc.bin");
        store.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = ParamStore::load(&path).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("truncated") || msg.contains("header says"), "{msg}");
    }
}
