//! Graph substrate: the [`Graph`] type plus synthetic generators, fan-out
//! neighbor sampling (GraphSAGE-style, Section 4), and train/val/test
//! splitting.
//!
//! The OGB datasets and the in-house Visa transaction graph used by the
//! paper are not available here; [`generate`] provides seeded synthetic
//! analogs whose properties (community structure for labels, power-law
//! degrees, bipartite consumer–merchant wiring, class imbalance) exercise
//! the same code paths — see DESIGN.md §4.

pub mod generate;
pub mod sample;
pub mod split;

pub use generate::{barabasi_albert, bipartite_transactions, erdos_renyi, sbm, sbm_with_labels, BipartiteGraph, SbmCfg};
pub use sample::NeighborSampler;
pub use split::{split_nodes, Split};

use crate::sparse::Csr;
use crate::Result;

/// An undirected graph stored as a symmetric CSR adjacency, with optional
/// node labels (for node classification tasks).
#[derive(Clone, Debug)]
pub struct Graph {
    adj: Csr,
    labels: Option<Vec<u32>>,
    n_classes: usize,
}

impl Graph {
    /// Build from an edge list; the adjacency is symmetrized (the paper
    /// converts all directed graphs to undirected, §5.2.1).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Result<Self> {
        let adj = Csr::from_edges(n, edges)?.symmetrize()?;
        Ok(Self { adj, labels: None, n_classes: 0 })
    }

    /// [`Self::from_edges`] over any edge iterator (serving bundles keep
    /// edges as an in-place flat view; no pair `Vec` is materialized).
    pub fn from_edge_iter<I: IntoIterator<Item = (u32, u32)>>(n: usize, edges: I) -> Result<Self> {
        let adj = Csr::from_edge_iter(n, edges)?.symmetrize()?;
        Ok(Self { adj, labels: None, n_classes: 0 })
    }

    /// Attach node labels in `[0, n_classes)`.
    pub fn with_labels(mut self, labels: Vec<u32>, n_classes: usize) -> Result<Self> {
        if labels.len() != self.n_nodes() {
            return Err(crate::Error::Shape(format!(
                "labels length {} != n_nodes {}",
                labels.len(),
                self.n_nodes()
            )));
        }
        if let Some(&bad) = labels.iter().find(|&&l| l as usize >= n_classes) {
            return Err(crate::Error::Shape(format!("label {bad} ≥ n_classes {n_classes}")));
        }
        self.labels = Some(labels);
        self.n_classes = n_classes;
        Ok(self)
    }

    pub fn n_nodes(&self) -> usize {
        self.adj.n_rows()
    }

    pub fn n_edges_directed(&self) -> usize {
        self.adj.nnz()
    }

    pub fn adj(&self) -> &Csr {
        &self.adj
    }

    pub fn labels(&self) -> Option<&[u32]> {
        self.labels.as_deref()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        self.adj.row_indices(v)
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj.degree(v)
    }

    /// All undirected edges as (u, v) with u < v (for link-prediction
    /// splits).
    pub fn undirected_edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.adj.nnz() / 2);
        for u in 0..self.n_nodes() {
            for &v in self.adj.row_indices(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// True if the edge (u, v) exists.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj.row_indices(u).binary_search(&(v as u32)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_symmetrizes() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        assert!(g.has_edge(1, 0));
        assert!(g.has_edge(2, 1));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn labels_validated() {
        let g = Graph::from_edges(3, &[(0, 1)]).unwrap();
        assert!(g.clone().with_labels(vec![0, 1, 2], 3).is_ok());
        assert!(g.clone().with_labels(vec![0, 1], 3).is_err());
        assert!(g.clone().with_labels(vec![0, 1, 5], 3).is_err());
    }

    #[test]
    fn undirected_edges_unique() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 0), (2, 3)]).unwrap();
        let e = g.undirected_edges();
        assert_eq!(e, vec![(0, 1), (2, 3)]);
    }
}
