//! GraphSAGE-style fan-out neighbor sampling (Section 4, steps 1–2).
//!
//! For a batch of target nodes the sampler draws `k1` first neighbors per
//! node and `k2` second neighbors per first neighbor, **with replacement**
//! (matching the reference GraphSAGE implementation the paper uses), so the
//! output tensors have static shapes `(B, k1)` and `(B, k1, k2)` — a
//! requirement for the AOT-compiled executables.
//!
//! Isolated nodes self-loop: a node with no neighbors samples itself.
//!
//! Two sampling modes share the per-node draw logic:
//!
//! - [`NeighborSampler::sample`] / [`sample_seeded`]: one RNG walks the
//!   whole batch (the original sequential order — still used by serving's
//!   per-node fan-out and by old tests).
//! - [`NeighborSampler::sample_streams`] / [`sample_streams_par`]: batch
//!   position `i` gets its own RNG stream derived from `(seed, i)` via
//!   [`crate::rng::derive_stream_seed`] — the same per-stream trick the
//!   LSH encoder uses per bit. Because every position's draws are
//!   self-contained, the batch can be partitioned across worker threads
//!   and the result is bit-identical for any thread count and equal to
//!   the single-threaded stream walk.
//!
//! [`sample_seeded`]: NeighborSampler::sample_seeded
//! [`sample_streams_par`]: NeighborSampler::sample_streams_par

use super::Graph;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::native::par;

/// Two-hop fan-out sample for one batch. Target nodes are not stored —
/// the caller already owns them; this only holds what sampling produced.
#[derive(Clone, Debug)]
pub struct FanoutSample {
    /// First neighbors, row-major `(b, k1)`.
    pub hop1: Vec<u32>,
    /// Second neighbors, row-major `(b, k1, k2)`.
    pub hop2: Vec<u32>,
    pub k1: usize,
    pub k2: usize,
}

/// Reusable sampler over a graph.
pub struct NeighborSampler<'g> {
    graph: &'g Graph,
    k1: usize,
    k2: usize,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g Graph, k1: usize, k2: usize) -> Self {
        Self { graph, k1, k2 }
    }

    #[inline]
    fn sample_neighbor<R: Rng>(&self, v: u32, rng: &mut R) -> u32 {
        let nbrs = self.graph.neighbors(v as usize);
        if nbrs.is_empty() {
            v // isolated node: self-loop
        } else {
            nbrs[rng.index(nbrs.len())]
        }
    }

    /// The two-hop draws for one target node, written into that node's
    /// rows of the hop tensors. `h1` has length `k1`, `h2` length `k1*k2`;
    /// draw order (n1 then its k2 seconds) matches [`Self::sample`].
    #[inline]
    fn sample_node_into<R: Rng>(&self, u: u32, rng: &mut R, h1: &mut [u32], h2: &mut [u32]) {
        for j in 0..self.k1 {
            let n1 = self.sample_neighbor(u, rng);
            h1[j] = n1;
            for l in 0..self.k2 {
                h2[j * self.k2 + l] = self.sample_neighbor(n1, rng);
            }
        }
    }

    /// Sample the two-hop neighborhood of `batch` with one sequential RNG.
    pub fn sample<R: Rng>(&self, batch: &[u32], rng: &mut R) -> FanoutSample {
        let b = batch.len();
        let mut hop1 = vec![0u32; b * self.k1];
        let mut hop2 = vec![0u32; b * self.k1 * self.k2];
        for (i, &u) in batch.iter().enumerate() {
            let (k1, kk) = (self.k1, self.k1 * self.k2);
            self.sample_node_into(
                u,
                rng,
                &mut hop1[i * k1..(i + 1) * k1],
                &mut hop2[i * kk..(i + 1) * kk],
            );
        }
        FanoutSample { hop1, hop2, k1: self.k1, k2: self.k2 }
    }

    /// Convenience: deterministic sample with an explicit seed.
    pub fn sample_seeded(&self, batch: &[u32], seed: u64) -> FanoutSample {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        self.sample(batch, &mut rng)
    }

    /// Per-position seed streams, sequential reference: batch position `i`
    /// draws from its own RNG stream `(seed, i)`. Bit-identical to
    /// [`Self::sample_streams_par`] at every thread count.
    pub fn sample_streams(&self, batch: &[u32], seed: u64) -> FanoutSample {
        let b = batch.len();
        let (k1, kk) = (self.k1, self.k1 * self.k2);
        let mut hop1 = vec![0u32; b * k1];
        let mut hop2 = vec![0u32; b * kk];
        for (i, &u) in batch.iter().enumerate() {
            let mut rng = Xoshiro256pp::seed_for_stream(seed, i as u64);
            self.sample_node_into(
                u,
                &mut rng,
                &mut hop1[i * k1..(i + 1) * k1],
                &mut hop2[i * kk..(i + 1) * kk],
            );
        }
        FanoutSample { hop1, hop2, k1: self.k1, k2: self.k2 }
    }

    /// Pooled variant of [`Self::sample_streams`]: batch positions are
    /// partitioned into contiguous chunks, one worker each; every position
    /// still draws from the RNG stream keyed by its *global* index, so the
    /// output never depends on the thread count — only who computes it.
    pub fn sample_streams_par(&self, batch: &[u32], seed: u64, threads: usize) -> FanoutSample {
        let b = batch.len();
        let t = par::resolve_threads(threads);
        if b == 0 || t <= 1 || self.k1 == 0 || self.k2 == 0 {
            return self.sample_streams(batch, seed);
        }
        let t = t.min(b);
        let chunk = b.div_ceil(t);
        let (k1, kk) = (self.k1, self.k1 * self.k2);
        let mut hop1 = vec![0u32; b * k1];
        let mut hop2 = vec![0u32; b * kk];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = hop1
                .chunks_mut(chunk * k1)
                .zip(hop2.chunks_mut(chunk * kk))
                .enumerate()
                .map(|(ci, (h1c, h2c))| {
                    let node0 = ci * chunk;
                    let rows = h1c.len() / k1;
                    let targets = &batch[node0..node0 + rows];
                    Box::new(move || {
                        for (j, &u) in targets.iter().enumerate() {
                            let mut rng =
                                Xoshiro256pp::seed_for_stream(seed, (node0 + j) as u64);
                            self.sample_node_into(
                                u,
                                &mut rng,
                                &mut h1c[j * k1..(j + 1) * k1],
                                &mut h2c[j * kk..(j + 1) * kk],
                            );
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            par::join_all(jobs);
        }
        FanoutSample { hop1, hop2, k1: self.k1, k2: self.k2 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{barabasi_albert, erdos_renyi};

    #[test]
    fn shapes_are_static() {
        let g = barabasi_albert(200, 3, 1).unwrap();
        let s = NeighborSampler::new(&g, 5, 3);
        let sample = s.sample_seeded(&[0, 1, 2, 3], 9);
        assert_eq!(sample.hop1.len(), 4 * 5);
        assert_eq!(sample.hop2.len(), 4 * 5 * 3);
    }

    #[test]
    fn sampled_nodes_are_neighbors() {
        let g = erdos_renyi(100, 8.0, 2).unwrap();
        let s = NeighborSampler::new(&g, 4, 2);
        let batch: Vec<u32> = (0..10).collect();
        let sample = s.sample_seeded(&batch, 3);
        for (i, &u) in batch.iter().enumerate() {
            for j in 0..4 {
                let n1 = sample.hop1[i * 4 + j];
                assert!(
                    g.neighbors(u as usize).contains(&n1) || n1 == u,
                    "{n1} not neighbor of {u}"
                );
                for l in 0..2 {
                    let n2 = sample.hop2[(i * 4 + j) * 2 + l];
                    assert!(g.neighbors(n1 as usize).contains(&n2) || n2 == n1);
                }
            }
        }
    }

    #[test]
    fn isolated_node_self_loops() {
        let g = Graph::from_edges(3, &[(0, 1)]).unwrap(); // node 2 isolated
        let s = NeighborSampler::new(&g, 3, 2);
        let sample = s.sample_seeded(&[2], 1);
        assert!(sample.hop1.iter().all(|&v| v == 2));
        assert!(sample.hop2.iter().all(|&v| v == 2));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = barabasi_albert(100, 2, 5).unwrap();
        let s = NeighborSampler::new(&g, 4, 4);
        let a = s.sample_seeded(&[1, 2, 3], 7);
        let b = s.sample_seeded(&[1, 2, 3], 7);
        assert_eq!(a.hop1, b.hop1);
        assert_eq!(a.hop2, b.hop2);
        let c = s.sample_seeded(&[1, 2, 3], 8);
        assert_ne!(a.hop1, c.hop1);
    }

    #[test]
    fn stream_sampling_matches_pooled_at_any_thread_count() {
        let g = barabasi_albert(300, 3, 11).unwrap();
        let s = NeighborSampler::new(&g, 5, 3);
        // Batch sizes straddling chunk boundaries, incl. b < threads.
        for b in [1usize, 3, 7, 16, 65] {
            let batch: Vec<u32> = (0..b as u32).map(|i| (i * 37) % 300).collect();
            let reference = s.sample_streams(&batch, 0xFEED);
            for t in [1usize, 2, 8] {
                let pooled = s.sample_streams_par(&batch, 0xFEED, t);
                assert_eq!(reference.hop1, pooled.hop1, "hop1 b={b} t={t}");
                assert_eq!(reference.hop2, pooled.hop2, "hop2 b={b} t={t}");
            }
        }
    }

    #[test]
    fn stream_samples_are_valid_neighbors() {
        let g = erdos_renyi(120, 6.0, 4).unwrap();
        let s = NeighborSampler::new(&g, 4, 2);
        let batch: Vec<u32> = (0..30).collect();
        let sample = s.sample_streams_par(&batch, 5, 8);
        for (i, &u) in batch.iter().enumerate() {
            for j in 0..4 {
                let n1 = sample.hop1[i * 4 + j];
                assert!(g.neighbors(u as usize).contains(&n1) || n1 == u);
                for l in 0..2 {
                    let n2 = sample.hop2[(i * 4 + j) * 2 + l];
                    assert!(g.neighbors(n1 as usize).contains(&n2) || n2 == n1);
                }
            }
        }
    }

    #[test]
    fn stream_position_is_the_stream_key() {
        // The same node at a different batch position draws a different
        // neighborhood; the same position always draws the same one.
        let g = barabasi_albert(100, 3, 2).unwrap();
        let s = NeighborSampler::new(&g, 6, 2);
        let a = s.sample_streams(&[5, 5], 1);
        assert_eq!(&a.hop1[..6], s.sample_streams(&[5], 1).hop1.as_slice());
        let differs = a.hop1[..6] != a.hop1[6..] || a.hop2[..12] != a.hop2[12..];
        assert!(differs, "independent streams drew identical neighborhoods");
    }
}
