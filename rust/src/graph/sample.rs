//! GraphSAGE-style fan-out neighbor sampling (Section 4, steps 1–2).
//!
//! For a batch of target nodes the sampler draws `k1` first neighbors per
//! node and `k2` second neighbors per first neighbor, **with replacement**
//! (matching the reference GraphSAGE implementation the paper uses), so the
//! output tensors have static shapes `(B, k1)` and `(B, k1, k2)` — a
//! requirement for the AOT-compiled executables.
//!
//! Isolated nodes self-loop: a node with no neighbors samples itself.

use super::Graph;
use crate::rng::{Rng, Xoshiro256pp};

/// Two-hop fan-out sample for one batch.
#[derive(Clone, Debug)]
pub struct FanoutSample {
    /// Target nodes, length `b`.
    pub batch: Vec<u32>,
    /// First neighbors, row-major `(b, k1)`.
    pub hop1: Vec<u32>,
    /// Second neighbors, row-major `(b, k1, k2)`.
    pub hop2: Vec<u32>,
    pub k1: usize,
    pub k2: usize,
}

/// Reusable sampler over a graph.
pub struct NeighborSampler<'g> {
    graph: &'g Graph,
    k1: usize,
    k2: usize,
}

impl<'g> NeighborSampler<'g> {
    pub fn new(graph: &'g Graph, k1: usize, k2: usize) -> Self {
        Self { graph, k1, k2 }
    }

    #[inline]
    fn sample_neighbor<R: Rng>(&self, v: u32, rng: &mut R) -> u32 {
        let nbrs = self.graph.neighbors(v as usize);
        if nbrs.is_empty() {
            v // isolated node: self-loop
        } else {
            nbrs[rng.index(nbrs.len())]
        }
    }

    /// Sample the two-hop neighborhood of `batch`.
    pub fn sample<R: Rng>(&self, batch: &[u32], rng: &mut R) -> FanoutSample {
        let b = batch.len();
        let mut hop1 = Vec::with_capacity(b * self.k1);
        let mut hop2 = Vec::with_capacity(b * self.k1 * self.k2);
        for &u in batch {
            for _ in 0..self.k1 {
                let n1 = self.sample_neighbor(u, rng);
                hop1.push(n1);
                for _ in 0..self.k2 {
                    hop2.push(self.sample_neighbor(n1, rng));
                }
            }
        }
        FanoutSample { batch: batch.to_vec(), hop1, hop2, k1: self.k1, k2: self.k2 }
    }

    /// Convenience: deterministic sample with an explicit seed.
    pub fn sample_seeded(&self, batch: &[u32], seed: u64) -> FanoutSample {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        self.sample(batch, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{barabasi_albert, erdos_renyi};

    #[test]
    fn shapes_are_static() {
        let g = barabasi_albert(200, 3, 1).unwrap();
        let s = NeighborSampler::new(&g, 5, 3);
        let sample = s.sample_seeded(&[0, 1, 2, 3], 9);
        assert_eq!(sample.hop1.len(), 4 * 5);
        assert_eq!(sample.hop2.len(), 4 * 5 * 3);
    }

    #[test]
    fn sampled_nodes_are_neighbors() {
        let g = erdos_renyi(100, 8.0, 2).unwrap();
        let s = NeighborSampler::new(&g, 4, 2);
        let batch: Vec<u32> = (0..10).collect();
        let sample = s.sample_seeded(&batch, 3);
        for (i, &u) in batch.iter().enumerate() {
            for j in 0..4 {
                let n1 = sample.hop1[i * 4 + j];
                assert!(
                    g.neighbors(u as usize).contains(&n1) || n1 == u,
                    "{n1} not neighbor of {u}"
                );
                for l in 0..2 {
                    let n2 = sample.hop2[(i * 4 + j) * 2 + l];
                    assert!(g.neighbors(n1 as usize).contains(&n2) || n2 == n1);
                }
            }
        }
    }

    #[test]
    fn isolated_node_self_loops() {
        let g = Graph::from_edges(3, &[(0, 1)]).unwrap(); // node 2 isolated
        let s = NeighborSampler::new(&g, 3, 2);
        let sample = s.sample_seeded(&[2], 1);
        assert!(sample.hop1.iter().all(|&v| v == 2));
        assert!(sample.hop2.iter().all(|&v| v == 2));
    }

    #[test]
    fn deterministic_given_seed() {
        let g = barabasi_albert(100, 2, 5).unwrap();
        let s = NeighborSampler::new(&g, 4, 4);
        let a = s.sample_seeded(&[1, 2, 3], 7);
        let b = s.sample_seeded(&[1, 2, 3], 7);
        assert_eq!(a.hop1, b.hop1);
        assert_eq!(a.hop2, b.hop2);
        let c = s.sample_seeded(&[1, 2, 3], 8);
        assert_ne!(a.hop1, c.hop1);
    }
}
