//! Train/validation/test node and edge splitting.
//!
//! The paper uses 70/10/20 node splits for the merchant task (§5.3.1) and
//! the OGB-provided splits for §5.2; here all splits are seeded random
//! partitions with the same fractions.

use crate::rng::{Rng, Xoshiro256pp};
use crate::{Error, Result};

/// Index split into train/val/test.
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub test: Vec<u32>,
}

impl Split {
    pub fn total(&self) -> usize {
        self.train.len() + self.val.len() + self.test.len()
    }
}

/// Randomly split `items` with the given train/val fractions (test gets the
/// remainder).
pub fn split_items(items: &[u32], frac_train: f64, frac_val: f64, seed: u64) -> Result<Split> {
    if !(0.0..=1.0).contains(&frac_train)
        || !(0.0..=1.0).contains(&frac_val)
        || frac_train + frac_val > 1.0
    {
        return Err(Error::Config(format!(
            "invalid split fractions train={frac_train} val={frac_val}"
        )));
    }
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut perm: Vec<u32> = items.to_vec();
    rng.shuffle(&mut perm);
    let n = perm.len();
    let n_train = (n as f64 * frac_train).round() as usize;
    let n_val = (n as f64 * frac_val).round() as usize;
    let n_val_end = (n_train + n_val).min(n);
    Ok(Split {
        train: perm[..n_train].to_vec(),
        val: perm[n_train..n_val_end].to_vec(),
        test: perm[n_val_end..].to_vec(),
    })
}

/// Split all nodes `0..n`.
pub fn split_nodes(n: usize, frac_train: f64, frac_val: f64, seed: u64) -> Result<Split> {
    let items: Vec<u32> = (0..n as u32).collect();
    split_items(&items, frac_train, frac_val, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_exact() {
        let s = split_nodes(1000, 0.7, 0.1, 4).unwrap();
        assert_eq!(s.total(), 1000);
        assert_eq!(s.train.len(), 700);
        assert_eq!(s.val.len(), 100);
        assert_eq!(s.test.len(), 200);
        let mut all: Vec<u32> = s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = split_nodes(100, 0.5, 0.25, 1).unwrap();
        let b = split_nodes(100, 0.5, 0.25, 1).unwrap();
        let c = split_nodes(100, 0.5, 0.25, 2).unwrap();
        assert_eq!(a.train, b.train);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn bad_fractions_rejected() {
        assert!(split_nodes(10, 0.9, 0.2, 1).is_err());
        assert!(split_nodes(10, -0.1, 0.2, 1).is_err());
    }

    #[test]
    fn subset_split() {
        let items: Vec<u32> = vec![5, 9, 12, 40, 41, 42, 43, 44, 45, 46];
        let s = split_items(&items, 0.6, 0.2, 7).unwrap();
        assert_eq!(s.train.len(), 6);
        assert_eq!(s.val.len(), 2);
        assert_eq!(s.test.len(), 2);
        for v in s.train.iter().chain(&s.val).chain(&s.test) {
            assert!(items.contains(v));
        }
    }
}
