//! Seeded synthetic graph generators (OGB / transaction-graph analogs).
//!
//! | paper dataset | generator here | preserved property |
//! |---|---|---|
//! | ogbn-arxiv/mag/products | [`sbm`] (+ power-law via [`barabasi_albert`] mixing) | community structure ⇒ adjacency rows predict labels |
//! | ogbl-collab/ddi | [`sbm`] without labels / [`erdos_renyi`] | link structure for edge splits |
//! | Visa consumer–merchant graph (§5.3) | [`bipartite_transactions`] | bipartite wiring, Zipf-imbalanced categories & degrees |

use super::Graph;
use crate::rng::{Rng, Xoshiro256pp, Zipf};
use crate::Result;

/// Barabási–Albert preferential attachment: `n` nodes, `m_attach` edges per
/// new node. Produces the heavy-tailed degree distribution of real graphs.
pub fn barabasi_albert(n: usize, m_attach: usize, seed: u64) -> Result<Graph> {
    assert!(n > m_attach && m_attach >= 1, "BA requires n > m_attach ≥ 1");
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m_attach);
    // Repeated-endpoint list implements preferential attachment in O(1).
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m_attach);
    // Seed clique over the first m_attach+1 nodes.
    for i in 0..=m_attach {
        for j in 0..i {
            edges.push((i as u32, j as u32));
            endpoints.push(i as u32);
            endpoints.push(j as u32);
        }
    }
    for v in (m_attach + 1)..n {
        let mut targets = std::collections::BTreeSet::new();
        while targets.len() < m_attach {
            let t = endpoints[rng.index(endpoints.len())];
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((v as u32, t));
            endpoints.push(v as u32);
            endpoints.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Stochastic-block-model configuration.
#[derive(Clone, Copy, Debug)]
pub struct SbmCfg {
    pub n: usize,
    pub n_classes: usize,
    /// Expected intra-community degree.
    pub deg_in: f64,
    /// Expected inter-community degree.
    pub deg_out: f64,
}

impl SbmCfg {
    pub fn new(n: usize, n_classes: usize, deg_in: f64, deg_out: f64) -> Self {
        Self { n, n_classes, deg_in, deg_out }
    }
}

/// Stochastic block model with power-law-ish degree heterogeneity
/// (a degree-corrected SBM): nodes get a label, intra-class edges are more
/// likely. Labels double as the node-classification target; adjacency rows
/// carry the class signal the paper's LSH coding exploits.
pub fn sbm(cfg: SbmCfg, seed: u64) -> Result<Graph> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Balanced-ish random labels.
    let mut labels: Vec<u32> = (0..cfg.n).map(|i| (i % cfg.n_classes) as u32).collect();
    rng.shuffle(&mut labels);
    sbm_with_labels(cfg, labels, seed)
}

/// SBM wired around *given* community labels — used when another object
/// (e.g. a pre-trained-embedding mixture) already fixed the communities
/// and the graph must be consistent with them, as real graphs are with
/// the embeddings trained on them (Figure 1's "hashing/graph" arm).
pub fn sbm_with_labels(cfg: SbmCfg, labels: Vec<u32>, seed: u64) -> Result<Graph> {
    let SbmCfg { n, n_classes, deg_in, deg_out } = cfg;
    assert!(n_classes >= 2 && n >= n_classes);
    assert_eq!(labels.len(), n);
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x51B2);
    // Degree-correction factors: Zipf-flavored weights normalized to mean 1.
    let mut theta: Vec<f64> = (0..n).map(|_| 0.25 + rng.f64() * 1.5).collect();
    let mean_t = theta.iter().sum::<f64>() / n as f64;
    for t in theta.iter_mut() {
        *t /= mean_t;
    }
    // Expected edges per node pair class: sample via per-node stubs to stay
    // O(E). For each node draw ~deg_in intra and ~deg_out inter partners.
    let mut edges: Vec<(u32, u32)> = Vec::new();
    // Per-class node lists for partner sampling.
    let mut by_class: Vec<Vec<u32>> = vec![Vec::new(); n_classes];
    for (i, &l) in labels.iter().enumerate() {
        by_class[l as usize].push(i as u32);
    }
    for u in 0..n {
        let l = labels[u] as usize;
        let k_in = poisson_like(deg_in / 2.0 * theta[u], &mut rng);
        let k_out = poisson_like(deg_out / 2.0 * theta[u], &mut rng);
        for _ in 0..k_in {
            let peers = &by_class[l];
            let v = peers[rng.index(peers.len())];
            if v as usize != u {
                edges.push((u as u32, v));
            }
        }
        for _ in 0..k_out {
            let mut cls = rng.index(n_classes);
            if cls == l {
                cls = (cls + 1) % n_classes;
            }
            let peers = &by_class[cls];
            let v = peers[rng.index(peers.len())];
            edges.push((u as u32, v));
        }
    }
    Graph::from_edges(n, &edges)?.with_labels(labels, n_classes)
}

/// Erdős–Rényi G(n, p) via expected-edge-count sampling.
pub fn erdos_renyi(n: usize, avg_degree: f64, seed: u64) -> Result<Graph> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let target_edges = ((n as f64) * avg_degree / 2.0) as usize;
    let mut edges = Vec::with_capacity(target_edges);
    for _ in 0..target_edges {
        let u = rng.index(n) as u32;
        let v = rng.index(n) as u32;
        if u != v {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, &edges)
}

/// A consumer–merchant bipartite transaction graph (§5.3 analog).
///
/// Node ids: consumers are `[0, n_consumers)`, merchants are
/// `[n_consumers, n_consumers + n_merchants)`. Merchant categories are
/// Zipf-imbalanced (restaurants ≫ ambulance services); consumers have
/// Zipf-skewed activity and a category affinity so that a merchant's
/// consumer neighborhood is predictive of its category.
#[derive(Clone, Debug)]
pub struct BipartiteGraph {
    pub graph: Graph,
    pub n_consumers: usize,
    pub n_merchants: usize,
    /// Category per merchant (index by merchant id − n_consumers).
    pub merchant_category: Vec<u32>,
    pub n_categories: usize,
}

pub fn bipartite_transactions(
    n_consumers: usize,
    n_merchants: usize,
    n_categories: usize,
    avg_tx_per_consumer: f64,
    seed: u64,
) -> Result<BipartiteGraph> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let n = n_consumers + n_merchants;
    // Zipf-imbalanced category sizes.
    let cat_dist = Zipf::new(n_categories, 1.05);
    let merchant_category: Vec<u32> =
        (0..n_merchants).map(|_| cat_dist.sample(&mut rng) as u32).collect();
    // Merchant popularity: Zipf over merchants *within* category handled by
    // plain Zipf rank over all merchants (some merchants see ~10⁶ consumers,
    // some < 100 — §5.3.3).
    let mut merchants_by_cat: Vec<Vec<u32>> = vec![Vec::new(); n_categories];
    for (m, &c) in merchant_category.iter().enumerate() {
        merchants_by_cat[c as usize].push(m as u32);
    }
    // Each consumer prefers a small set of categories (shopping habit).
    let consumer_pref: Vec<(usize, usize)> = (0..n_consumers)
        .map(|_| {
            let a = cat_dist.sample(&mut rng);
            let b = cat_dist.sample(&mut rng);
            (a, b)
        })
        .collect();
    let activity = Zipf::new(64, 1.1); // activity multiplier ranks
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for cu in 0..n_consumers {
        let mult = 1 + activity.sample(&mut rng); // 1..=64
        let k = ((avg_tx_per_consumer * mult as f64 / 8.0).ceil() as usize).max(1);
        let (pa, pb) = consumer_pref[cu];
        for _ in 0..k {
            // 80% within preferred categories, 20% anywhere.
            let cat = if rng.bool_with(0.8) {
                if rng.bool_with(0.5) {
                    pa
                } else {
                    pb
                }
            } else {
                cat_dist.sample(&mut rng)
            };
            let pool = &merchants_by_cat[cat];
            if pool.is_empty() {
                continue;
            }
            // Zipf-ish within-pool popularity: square the uniform to bias
            // toward the head.
            let r = rng.f64();
            let idx = ((r * r) * pool.len() as f64) as usize;
            let m = pool[idx.min(pool.len() - 1)];
            edges.push((cu as u32, n_consumers as u32 + m));
        }
    }
    let graph = Graph::from_edges(n, &edges)?;
    Ok(BipartiteGraph { graph, n_consumers, n_merchants, merchant_category, n_categories })
}

/// Integer draw with mean `lambda` (geometric-ish approximation of Poisson;
/// exact distribution does not matter for the generators, the mean does).
fn poisson_like<R: Rng>(lambda: f64, rng: &mut R) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    let base = lambda.floor() as usize;
    let frac = lambda - base as f64;
    base + usize::from(rng.bool_with(frac))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ba_degree_heavy_tail() {
        let g = barabasi_albert(500, 3, 1).unwrap();
        assert_eq!(g.n_nodes(), 500);
        let mut degs: Vec<usize> = (0..500).map(|v| g.degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // Hub exists: max degree well above attachment parameter.
        assert!(degs[0] > 20, "max degree {}", degs[0]);
        // Everyone connected.
        assert!(degs[degs.len() - 1] >= 3);
    }

    #[test]
    fn ba_deterministic() {
        let g1 = barabasi_albert(100, 2, 7).unwrap();
        let g2 = barabasi_albert(100, 2, 7).unwrap();
        assert_eq!(g1.adj(), g2.adj());
    }

    #[test]
    fn sbm_has_community_structure() {
        let g = sbm(SbmCfg::new(600, 3, 12.0, 2.0), 42).unwrap();
        let labels = g.labels().unwrap();
        let mut intra = 0usize;
        let mut inter = 0usize;
        for u in 0..g.n_nodes() {
            for &v in g.neighbors(u) {
                if labels[u] == labels[v as usize] {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        assert!(intra > inter * 2, "intra={intra} inter={inter}");
    }

    #[test]
    fn sbm_label_balance() {
        let g = sbm(SbmCfg::new(300, 3, 8.0, 2.0), 9).unwrap();
        let mut counts = [0usize; 3];
        for &l in g.labels().unwrap() {
            counts[l as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 300);
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn er_edge_count_close() {
        let g = erdos_renyi(1000, 10.0, 3).unwrap();
        let e = g.undirected_edges().len();
        assert!((4000..6000).contains(&e), "edges={e}");
    }

    #[test]
    fn bipartite_structure_holds() {
        let b = bipartite_transactions(400, 200, 8, 6.0, 5).unwrap();
        let nc = b.n_consumers;
        // No consumer-consumer or merchant-merchant edges.
        for u in 0..b.graph.n_nodes() {
            for &v in b.graph.neighbors(u) {
                let u_is_c = u < nc;
                let v_is_c = (v as usize) < nc;
                assert_ne!(u_is_c, v_is_c, "edge within one side: {u}–{v}");
            }
        }
        assert_eq!(b.merchant_category.len(), 200);
        assert!(b.merchant_category.iter().all(|&c| (c as usize) < 8));
    }

    #[test]
    fn bipartite_category_imbalance() {
        let b = bipartite_transactions(100, 2000, 16, 4.0, 11).unwrap();
        let mut counts = vec![0usize; 16];
        for &c in &b.merchant_category {
            counts[c as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > min * 4, "imbalance expected: max={max} min={min}");
    }

    #[test]
    fn bipartite_neighborhood_predicts_category() {
        // Merchants of the same category should share more consumers than
        // merchants of different categories (this is what makes LSH coding
        // of adjacency rows informative).
        let b = bipartite_transactions(800, 100, 4, 12.0, 13).unwrap();
        let nc = b.n_consumers;
        let sets: Vec<std::collections::HashSet<u32>> = (0..b.n_merchants)
            .map(|m| b.graph.neighbors(nc + m).iter().copied().collect())
            .collect();
        let mut same = 0.0;
        let mut same_n = 0;
        let mut diff = 0.0;
        let mut diff_n = 0;
        for i in 0..b.n_merchants {
            for j in (i + 1)..b.n_merchants {
                if sets[i].is_empty() || sets[j].is_empty() {
                    continue;
                }
                let inter = sets[i].intersection(&sets[j]).count() as f64;
                let uni = sets[i].union(&sets[j]).count() as f64;
                let jac = inter / uni;
                if b.merchant_category[i] == b.merchant_category[j] {
                    same += jac;
                    same_n += 1;
                } else {
                    diff += jac;
                    diff_n += 1;
                }
            }
        }
        let same_avg = same / same_n.max(1) as f64;
        let diff_avg = diff / diff_n.max(1) as f64;
        assert!(same_avg > diff_avg, "same={same_avg:.4} diff={diff_avg:.4}");
    }
}
