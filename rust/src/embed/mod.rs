//! Synthetic "pre-trained embedding" substrate (§5.1 proxy tasks).
//!
//! The paper's reconstruction experiments use three pre-trained embedding
//! sets (GloVe 300-d, metapath2vec 128-d, metapath2vec++ 128-d) that are
//! not redistributable here. This module generates seeded analogs whose
//! *geometry encodes the evaluation task*:
//!
//! - [`gaussian_mixture`] — cluster-structured node embeddings with labels
//!   (metapath2vec analog; evaluated by k-means + NMI),
//! - [`analogy_embeddings`] — word embeddings with planted linear-offset
//!   analogy structure and similarity pairs (GloVe analog; evaluated by
//!   analogy accuracy and Spearman ρ),
//!
//! plus Zipf frequency ranks so "top-k by frequency" sampling (§5.1.2)
//! behaves like the paper's.

use crate::rng::{Rng, Xoshiro256pp};

/// A dense row-major embedding matrix with per-entity frequency ranks.
/// Entities are ordered by frequency: row 0 is the most frequent entity
/// (matching how the paper slices "first 200,000" / "top 5k" entities).
#[derive(Clone, Debug)]
pub struct EmbeddingSet {
    pub n: usize,
    pub d: usize,
    /// Row-major `n × d`.
    pub data: Vec<f32>,
    /// Optional ground-truth cluster labels (metapath2vec analog).
    pub labels: Option<Vec<u32>>,
    pub n_clusters: usize,
}

impl EmbeddingSet {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// First `k` rows (the paper evaluates on the top-5k most frequent
    /// entities regardless of how many were compressed).
    pub fn top(&self, k: usize) -> EmbeddingSet {
        let k = k.min(self.n);
        EmbeddingSet {
            n: k,
            d: self.d,
            data: self.data[..k * self.d].to_vec(),
            labels: self.labels.as_ref().map(|l| l[..k].to_vec()),
            n_clusters: self.n_clusters,
        }
    }
}

/// Gaussian-mixture embeddings: `k` well-separated centers, per-point
/// Gaussian noise. Row order is shuffled across clusters then treated as
/// frequency order (cluster membership is frequency-independent, as in
/// AMiner).
pub fn gaussian_mixture(n: usize, d: usize, k: usize, noise: f32, seed: u64) -> EmbeddingSet {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Centers: random Gaussian, scaled for separation.
    let mut centers = vec![0.0f32; k * d];
    rng.fill_normal_f32(&mut centers, 0.0, 1.0);
    let mut data = vec![0.0f32; n * d];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.index(k);
        labels.push(c as u32);
        for j in 0..d {
            data[i * d + j] = centers[c * d + j] + noise * rng.normal() as f32;
        }
    }
    EmbeddingSet { n, d, data, labels: Some(labels), n_clusters: k }
}

/// An analogy quadruple `a : b :: c : d` (answer `d`), plus its relation id.
#[derive(Clone, Copy, Debug)]
pub struct AnalogyQuad {
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub d: u32,
    pub relation: u32,
}

/// A similarity pair with planted ground-truth score.
#[derive(Clone, Copy, Debug)]
pub struct SimPair {
    pub a: u32,
    pub b: u32,
    pub score: f32,
}

/// GloVe-analog embeddings with planted analogy and similarity structure.
pub struct WordEmbeddings {
    pub set: EmbeddingSet,
    /// Analogy quads grouped into `n_relations` categories (paper: 14).
    pub analogies: Vec<AnalogyQuad>,
    pub n_relations: usize,
    /// Similarity pairs with ground-truth scores (paper: 13 datasets; we
    /// plant one pool and split it 13 ways at eval time).
    pub sim_pairs: Vec<SimPair>,
}

/// Generate `n` embeddings of dim `d` where, for each of `n_relations`
/// relations, a fixed offset vector `r` links word pairs:
/// `emb[b] ≈ emb[a] + r`. Analogy quads are pairs of such pairs; similarity
/// ground truth is the *pre-noise* cosine similarity.
pub fn analogy_embeddings(
    n: usize,
    d: usize,
    n_relations: usize,
    quads_per_relation: usize,
    n_sim_pairs: usize,
    noise: f32,
    seed: u64,
) -> WordEmbeddings {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    // Base embeddings: broad Gaussian cloud.
    let mut clean = vec![0.0f32; n * d];
    rng.fill_normal_f32(&mut clean, 0.0, 1.0);
    // Relation offsets, clearly larger than noise.
    let mut relations = vec![0.0f32; n_relations * d];
    rng.fill_normal_f32(&mut relations, 0.0, 1.2);

    // Plant pairs: for each relation pick `quads_per_relation + 1` disjoint
    // (a, b) pairs where b's embedding is overwritten to a + r. Planting is
    // confined to the most frequent entities (first `plant_within` rows) so
    // the §5.1 protocol — evaluate only the top-k slice while compressing
    // many more entities — keeps every eval item in range.
    let pairs_per_rel = quads_per_relation + 1;
    let need = n_relations * pairs_per_rel * 2;
    assert!(need <= n, "not enough entities ({n}) for {need} planted words");
    let plant_within = need.max(n.min(2000));
    let mut ids: Vec<usize> = (0..plant_within).collect();
    rng.shuffle(&mut ids);
    let mut analogies = Vec::with_capacity(n_relations * quads_per_relation);
    let mut cursor = 0usize;
    for rel in 0..n_relations {
        let mut pairs = Vec::with_capacity(pairs_per_rel);
        for _ in 0..pairs_per_rel {
            let a = ids[cursor];
            let b = ids[cursor + 1];
            cursor += 2;
            for j in 0..d {
                clean[b * d + j] = clean[a * d + j] + relations[rel * d + j];
            }
            pairs.push((a as u32, b as u32));
        }
        // Quads: consecutive pair combinations (a,b) :: (c,d).
        for w in 0..quads_per_relation {
            let (a, b) = pairs[w];
            let (c, dd) = pairs[w + 1];
            analogies.push(AnalogyQuad { a, b, c, d: dd, relation: rel as u32 });
        }
    }

    // Similarity pairs: random pairs among the frequent slice, ground
    // truth = clean cosine.
    let mut sim_pairs = Vec::with_capacity(n_sim_pairs);
    for _ in 0..n_sim_pairs {
        let a = rng.index(plant_within);
        let mut b = rng.index(plant_within);
        if b == a {
            b = (b + 1) % plant_within;
        }
        let score = cosine(&clean[a * d..(a + 1) * d], &clean[b * d..(b + 1) * d]);
        sim_pairs.push(SimPair { a: a as u32, b: b as u32, score });
    }

    // Observed embeddings: clean + small noise (pre-trained embeddings are
    // never exactly linear).
    let mut data = clean;
    for v in data.iter_mut() {
        *v += noise * rng.normal() as f32;
    }

    WordEmbeddings {
        set: EmbeddingSet { n, d, data, labels: None, n_clusters: 0 },
        analogies,
        n_relations,
        sim_pairs,
    }
}

/// Cosine similarity of two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    let mut na = 0.0f32;
    let mut nb = 0.0f32;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{kmeans, nmi};

    #[test]
    fn mixture_clusters_recoverable() {
        let e = gaussian_mixture(500, 16, 4, 0.2, 1);
        let assign = kmeans(&e.data, e.n, e.d, 4, 25, 3);
        let score = nmi(&assign, e.labels.as_ref().unwrap(), 4, 4);
        assert!(score > 0.9, "nmi={score}");
    }

    #[test]
    fn mixture_shapes() {
        let e = gaussian_mixture(100, 8, 3, 0.5, 2);
        assert_eq!(e.data.len(), 800);
        assert_eq!(e.labels.as_ref().unwrap().len(), 100);
        assert_eq!(e.row(5).len(), 8);
    }

    #[test]
    fn analogy_structure_holds_on_raw() {
        let w = analogy_embeddings(2000, 32, 6, 10, 100, 0.02, 3);
        // For most quads, emb[b] - emb[a] + emb[c] should be closest to d.
        let e = &w.set;
        let mut correct = 0;
        for q in &w.analogies {
            let mut query = vec![0.0f32; e.d];
            for j in 0..e.d {
                query[j] = e.row(q.b as usize)[j] - e.row(q.a as usize)[j]
                    + e.row(q.c as usize)[j];
            }
            // Exclude a, b, c per standard protocol.
            let mut best = (f32::MIN, usize::MAX);
            for i in 0..e.n {
                if i as u32 == q.a || i as u32 == q.b || i as u32 == q.c {
                    continue;
                }
                let s = cosine(&query, e.row(i));
                if s > best.0 {
                    best = (s, i);
                }
            }
            if best.1 as u32 == q.d {
                correct += 1;
            }
        }
        let acc = correct as f64 / w.analogies.len() as f64;
        assert!(acc > 0.8, "raw analogy accuracy = {acc}");
    }

    #[test]
    fn sim_pairs_scores_match_observed_cosine_rank() {
        let w = analogy_embeddings(500, 24, 4, 5, 200, 0.02, 7);
        let e = &w.set;
        let observed: Vec<f32> = w
            .sim_pairs
            .iter()
            .map(|p| cosine(e.row(p.a as usize), e.row(p.b as usize)))
            .collect();
        let truth: Vec<f32> = w.sim_pairs.iter().map(|p| p.score).collect();
        let rho = crate::eval::spearman(&observed, &truth);
        assert!(rho > 0.95, "rho={rho}");
    }

    #[test]
    fn top_slices_rows() {
        let e = gaussian_mixture(50, 4, 2, 0.1, 9);
        let t = e.top(10);
        assert_eq!(t.n, 10);
        assert_eq!(t.data, e.data[..40]);
    }
}
