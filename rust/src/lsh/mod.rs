//! Random-projection LSH coding — **Algorithm 1** of the paper.
//!
//! For each of the `m·log2(c)` output bits: draw a random Gaussian vector
//! `V ∈ R^d`, project every entity's auxiliary row (`U = A·V`), and set the
//! bit where `U[j] > t`. The threshold `t` is the **median** of `U`
//! (the paper's contribution over classic sign-LSH, which uses zero —
//! the median minimizes collisions by splitting entities 50/50 per bit;
//! Figures 3 and 6).
//!
//! Memory follows the paper's analysis: the outer loop is per-bit so only
//! one `V ∈ R^d` and one `U ∈ R^n` are live at a time —
//! `O(max(n·m·log2 c, d·f, n·f))` overall (the blocked engine trades a
//! factor `B = block_bits` of that for fewer traversals).
//!
//! ## §Perf — the encode engine
//!
//! [`encode`] is the verbatim bit-by-bit reference. Production encoding
//! goes through [`encode_with`] (see [`engine`] internals): `B` bits per
//! pass over `A` (one blocked CSR SpMM / row-tiled dense GEMV instead of
//! `B` traversals), per-bit medians computed in parallel, and word-packed
//! `BitMatrix` writes (64 bits per store through disjoint per-thread row
//! views). Every output bit draws its Gaussian vector from its own
//! [`crate::rng::derive_stream_seed`] stream, so all paths —
//! [`encode`], [`encode_blocked`], [`encode_with`] at any
//! `threads`/`block_bits` — produce **bit-identical** code tables; the
//! determinism is enforced by unit + property tests and re-checked by
//! `benches/perf_hotpath.rs`, which records encode throughput and
//! thread-scaling rows in `BENCH_perf_hotpath.json` at the repo root.
//!
//! **Compatibility note:** the per-bit stream derivation changed the
//! random stream layout, so codes for a given seed differ bitwise from
//! pre-engine versions of this crate (same distribution, different
//! draws). Persisted code files and decoder artifacts trained against
//! old codes must be regenerated.

mod engine;
mod median;

pub use engine::encode_with;
pub use median::median_in_place;

use crate::cfg::{CodingCfg, EncodeCfg};
use crate::codes::{BitMatrix, CodeTable};
use crate::rng::{Rng, Xoshiro256pp};
use crate::sparse::Csr;
use crate::Result;

/// Binarization threshold choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threshold {
    /// Median of the projected values (the paper's choice).
    Median,
    /// Zero (classic sign-LSH baseline, Charikar 2002).
    Zero,
}

/// Auxiliary-information source `A ∈ R^{n×d}`: anything that can project
/// all of its rows against a random vector. Implemented for sparse
/// adjacency matrices ([`Csr`]) and dense embedding matrices
/// ([`DenseAux`]).
pub trait AuxSource {
    fn n(&self) -> usize;
    fn d(&self) -> usize;
    /// `out[j] = dot(A[j, :], v)` for all rows `j` (Algorithm 1 lines 7–8).
    fn project(&self, v: &[f32], out: &mut [f32]);

    /// Blocked row-range projection, the engine's hot kernel:
    /// `outs[b][j - rows.start] = dot(A[j,:], V_b)` where `V_b` is column
    /// `b` of the coordinate-major block `vt` (`vt[k * n_vecs + b]`).
    ///
    /// Implementations must accumulate each dot product in ascending
    /// coordinate order with a single f32 accumulator so results are
    /// bit-identical to [`AuxSource::project`] — the engine's determinism
    /// contract depends on it.
    ///
    /// The default reconstitutes each vector and delegates to `project`
    /// (correct, but one full pass per vector — and since `project` covers
    /// all rows, under a multi-threaded plan *every worker* repeats that
    /// full pass and keeps only its row range: no speedup, `T×` the CPU).
    /// Any source used with `threads > 1` should override this; [`Csr`]
    /// and [`DenseAux`] do, with single-pass row-range kernels.
    fn project_block_rows(
        &self,
        rows: std::ops::Range<usize>,
        vt: &[f32],
        n_vecs: usize,
        outs: &mut [&mut [f32]],
    ) {
        let d = self.d();
        let n = self.n();
        let mut v = vec![0.0f32; d];
        let mut full = vec![0.0f32; n];
        for b in 0..n_vecs {
            for k in 0..d {
                v[k] = vt[k * n_vecs + b];
            }
            self.project(&v, &mut full);
            outs[b].copy_from_slice(&full[rows.clone()]);
        }
    }
}

impl AuxSource for Csr {
    fn n(&self) -> usize {
        self.n_rows()
    }

    fn d(&self) -> usize {
        self.n_cols()
    }

    fn project(&self, v: &[f32], out: &mut [f32]) {
        self.spmv(v, out);
    }

    fn project_block_rows(
        &self,
        rows: std::ops::Range<usize>,
        vt: &[f32],
        n_vecs: usize,
        outs: &mut [&mut [f32]],
    ) {
        self.spmm_block_rows(rows, vt, n_vecs, outs);
    }
}

/// Dense row-major auxiliary matrix (pre-trained embeddings path).
pub struct DenseAux<'a> {
    pub data: &'a [f32],
    pub n: usize,
    pub d: usize,
}

impl<'a> DenseAux<'a> {
    pub fn new(data: &'a [f32], n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d);
        Self { data, n, d }
    }
}

/// Rows per register tile of the blocked dense kernel: each coordinate row
/// of `vt` loaded from cache is reused across this many entity rows.
const DENSE_ROW_TILE: usize = 8;

impl<'a> AuxSource for DenseAux<'a> {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn project(&self, v: &[f32], out: &mut [f32]) {
        for j in 0..self.n {
            let row = &self.data[j * self.d..(j + 1) * self.d];
            let mut acc = 0.0f32;
            for k in 0..self.d {
                acc += row[k] * v[k];
            }
            out[j] = acc;
        }
    }

    /// Cache-blocked `(rows × d) · (d × n_vecs)` kernel: row tiles of
    /// [`DENSE_ROW_TILE`] share each streamed `vt` coordinate row. The
    /// per-`(j, b)` accumulation order (ascending `k`, one accumulator)
    /// matches [`Self::project`] exactly.
    fn project_block_rows(
        &self,
        rows: std::ops::Range<usize>,
        vt: &[f32],
        n_vecs: usize,
        outs: &mut [&mut [f32]],
    ) {
        debug_assert!(rows.end <= self.n);
        debug_assert_eq!(vt.len(), self.d * n_vecs);
        debug_assert_eq!(outs.len(), n_vecs);
        let row0 = rows.start;
        let mut acc = vec![0.0f32; DENSE_ROW_TILE * n_vecs];
        let mut j0 = rows.start;
        while j0 < rows.end {
            let jt = DENSE_ROW_TILE.min(rows.end - j0);
            acc[..jt * n_vecs].fill(0.0);
            for k in 0..self.d {
                let vrow = &vt[k * n_vecs..][..n_vecs];
                for t in 0..jt {
                    let a = self.data[(j0 + t) * self.d + k];
                    let arow = &mut acc[t * n_vecs..][..n_vecs];
                    for b in 0..n_vecs {
                        arow[b] += a * vrow[b];
                    }
                }
            }
            for t in 0..jt {
                for b in 0..n_vecs {
                    outs[b][j0 + t - row0] = acc[t * n_vecs + b];
                }
            }
            j0 += jt;
        }
    }
}

/// Algorithm 1, verbatim: bit-by-bit streaming encode (the reference
/// implementation — [`encode_with`] reproduces its output exactly).
pub fn encode<A: AuxSource>(
    aux: &A,
    coding: CodingCfg,
    threshold: Threshold,
    seed: u64,
) -> Result<CodeTable> {
    coding.validate()?;
    let n = aux.n();
    let d = aux.d();
    let n_bits = coding.n_bits();
    let mut bits = BitMatrix::zeros(n, n_bits);
    if n == 0 {
        return CodeTable::new(bits, coding);
    }
    let mut v = vec![0.0f32; d];
    let mut u = vec![0.0f32; n];
    let mut scratch = vec![0.0f32; n];
    for bit in 0..n_bits {
        // line 5: GetRandomVector(d) — one seed stream per output bit, so
        // every execution layout draws the same vector for the same bit.
        let mut rng = Xoshiro256pp::seed_for_stream(seed, bit as u64);
        rng.fill_normal_f32(&mut v, 0.0, 1.0);
        aux.project(&v, &mut u); // lines 7–8: U = A·V
        let t = match threshold {
            Threshold::Median => {
                scratch.copy_from_slice(&u);
                median_in_place(&mut scratch) // line 9: GetMedian(U)
            }
            Threshold::Zero => 0.0,
        };
        for j in 0..n {
            if u[j] > t {
                bits.set(j, bit, true); // lines 10–11
            }
        }
    }
    CodeTable::new(bits, coding)
}

/// Blocked single-thread encode (§Perf): `block_bits` projections per pass
/// over `A`, trading `B·(d+n)` floats of memory for a `B×` reduction in
/// sparse-matrix traversals. Output is **bit-identical** to [`encode`];
/// use [`encode_with`] directly to also parallelize across threads.
pub fn encode_blocked<A: AuxSource + Sync>(
    aux: &A,
    coding: CodingCfg,
    threshold: Threshold,
    seed: u64,
    block_bits: usize,
) -> Result<CodeTable> {
    encode_with(aux, coding, threshold, seed, EncodeCfg { threads: 1, block_bits })
}

/// Count collisions produced by a given (threshold, bits) setting over
/// `trials` seeds — the Figure 3 / Figure 6 experiment.
pub fn collision_trials<A: AuxSource + Sync>(
    aux: &A,
    n_bits: usize,
    threshold: Threshold,
    trials: usize,
    base_seed: u64,
) -> Vec<usize> {
    // Any (c, m) with the right product gives identical bits; use c=2.
    let coding = CodingCfg::new(2, n_bits).expect("valid coding");
    (0..trials)
        .map(|t| {
            let table = encode_with(aux, coding, threshold, base_seed + t as u64, EncodeCfg::default())
                .expect("encode cannot fail on valid input");
            table.bits.n_collisions()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::gaussian_mixture;
    use crate::graph::generate::barabasi_albert;

    fn coding(c: usize, m: usize) -> CodingCfg {
        CodingCfg::new(c, m).unwrap()
    }

    #[test]
    fn median_threshold_balances_bits() {
        let e = gaussian_mixture(400, 16, 4, 0.3, 1);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let t = encode(&aux, coding(2, 32), Threshold::Median, 7).unwrap();
        // Median split ⇒ every bit column is (almost) exactly half ones.
        for bit in 0..32 {
            let ones = (0..400).filter(|&r| t.bits.get(r, bit)).count();
            assert!((190..=210).contains(&ones), "bit {bit}: {ones} ones");
        }
    }

    #[test]
    fn zero_threshold_can_be_unbalanced() {
        // Shifted embeddings: all-positive projections ⇒ zero threshold
        // gives all-ones bits, median stays balanced.
        let n = 100;
        let d = 8;
        let data: Vec<f32> = (0..n * d).map(|i| 5.0 + (i % 7) as f32 * 0.01).collect();
        let aux = DenseAux::new(&data, n, d);
        let tz = encode(&aux, coding(2, 16), Threshold::Zero, 3).unwrap();
        let tm = encode(&aux, coding(2, 16), Threshold::Median, 3).unwrap();
        // Zero threshold: massively collided (rows nearly identical signs).
        // Median threshold: fewer collisions.
        assert!(tm.bits.n_collisions() <= tz.bits.n_collisions());
    }

    #[test]
    fn similar_rows_get_similar_codes() {
        // LSH property: two near-identical embedding rows should share most
        // code bits; two far rows should not.
        let d = 32;
        let mut data = vec![0.0f32; 3 * d];
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for j in 0..d {
            let v = rng.normal() as f32;
            data[j] = v;
            data[d + j] = v + 0.01 * rng.normal() as f32; // near-duplicate
            data[2 * d + j] = rng.normal() as f32 * 3.0; // unrelated
        }
        // Append background rows so the median is meaningful.
        let n = 200;
        let mut all = data.clone();
        let mut extra = vec![0.0f32; (n - 3) * d];
        rng.fill_normal_f32(&mut extra, 0.0, 1.0);
        all.extend_from_slice(&extra);
        let aux = DenseAux::new(&all, n, d);
        let t = encode(&aux, coding(2, 64), Threshold::Median, 11).unwrap();
        let ham = |a: usize, b: usize| (0..64).filter(|&k| t.bits.get(a, k) != t.bits.get(b, k)).count();
        assert!(ham(0, 1) < ham(0, 2), "near={} far={}", ham(0, 1), ham(0, 2));
        assert!(ham(0, 1) <= 8, "near rows differ in {} bits", ham(0, 1));
    }

    #[test]
    fn adjacency_source_works() {
        let g = barabasi_albert(300, 3, 2).unwrap();
        let t = encode(g.adj(), coding(4, 16), Threshold::Median, 1).unwrap();
        assert_eq!(t.n(), 300);
        // Codes should be far from all-identical.
        assert!(t.bits.n_collisions() < 150);
    }

    #[test]
    fn median_fewer_collisions_than_zero_fig3() {
        // The Figure 3 claim on a mixture whose projections are skewed.
        let e = gaussian_mixture(2000, 16, 8, 0.15, 9);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let med = collision_trials(&aux, 24, Threshold::Median, 5, 100);
        let zero = collision_trials(&aux, 24, Threshold::Zero, 5, 100);
        let med_avg: f64 = med.iter().sum::<usize>() as f64 / 5.0;
        let zero_avg: f64 = zero.iter().sum::<usize>() as f64 / 5.0;
        assert!(
            med_avg <= zero_avg,
            "median should not collide more: med={med_avg} zero={zero_avg}"
        );
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let e = gaussian_mixture(100, 8, 2, 0.5, 4);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let a = encode(&aux, coding(2, 24), Threshold::Median, 10).unwrap();
        let b = encode(&aux, coding(2, 24), Threshold::Median, 10).unwrap();
        let c = encode(&aux, coding(2, 24), Threshold::Median, 11).unwrap();
        assert_eq!(a.bits, b.bits);
        assert_ne!(a.bits, c.bits);
    }

    #[test]
    fn blocked_encode_bit_identical_to_plain() {
        let e = gaussian_mixture(500, 12, 4, 0.3, 6);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let plain = encode(&aux, coding(2, 32), Threshold::Median, 3).unwrap();
        for block in [1usize, 8, 64] {
            let blocked = encode_blocked(&aux, coding(2, 32), Threshold::Median, 3, block).unwrap();
            assert_eq!(plain.bits, blocked.bits, "block_bits={block}");
        }
    }

    #[test]
    fn parallel_encode_bit_identical_across_threads_and_blocks() {
        // The engine's determinism contract, over both aux sources and
        // both thresholds: output never depends on the execution plan.
        let g = barabasi_albert(400, 3, 9).unwrap();
        let e = gaussian_mixture(300, 16, 4, 0.3, 2);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        for threshold in [Threshold::Median, Threshold::Zero] {
            let ref_csr = encode(g.adj(), coding(4, 16), threshold, 11).unwrap();
            let ref_dense = encode(&aux, coding(4, 16), threshold, 11).unwrap();
            for threads in [1usize, 2, 8] {
                for block in [1usize, 8, 64] {
                    let plan = EncodeCfg::new(threads, block);
                    let t = encode_with(g.adj(), coding(4, 16), threshold, 11, plan).unwrap();
                    assert_eq!(ref_csr.bits, t.bits, "csr threads={threads} block={block}");
                    let t = encode_with(&aux, coding(4, 16), threshold, 11, plan).unwrap();
                    assert_eq!(ref_dense.bits, t.bits, "dense threads={threads} block={block}");
                }
            }
        }
    }

    #[test]
    fn encode_with_auto_plan_matches_reference() {
        let g = barabasi_albert(200, 2, 4).unwrap();
        let a = encode(g.adj(), coding(2, 24), Threshold::Median, 5).unwrap();
        let b = encode_with(g.adj(), coding(2, 24), Threshold::Median, 5, EncodeCfg::default())
            .unwrap();
        assert_eq!(a.bits, b.bits);
    }

    use crate::rng::Xoshiro256pp;
}
