//! Random-projection LSH coding — **Algorithm 1** of the paper.
//!
//! For each of the `m·log2(c)` output bits: draw a random Gaussian vector
//! `V ∈ R^d`, project every entity's auxiliary row (`U = A·V`), and set the
//! bit where `U[j] > t`. The threshold `t` is the **median** of `U`
//! (the paper's contribution over classic sign-LSH, which uses zero —
//! the median minimizes collisions by splitting entities 50/50 per bit;
//! Figures 3 and 6).
//!
//! Memory follows the paper's analysis: the outer loop is per-bit so only
//! one `V ∈ R^d` and one `U ∈ R^n` are live at a time —
//! `O(max(n·m·log2 c, d·f, n·f))` overall.
//!
//! [`encode_blocked`] is the §Perf variant: it processes `B` bits per pass
//! over `A`, trading `B·(d+n)` floats of memory for a `B×` reduction in
//! sparse-matrix traversals (the dominant cost: `A` is scanned once per
//! *block* instead of once per *bit*).

mod median;

pub use median::median_in_place;

use crate::cfg::CodingCfg;
use crate::codes::{BitMatrix, CodeTable};
use crate::rng::{Rng, Xoshiro256pp};
use crate::sparse::Csr;
use crate::Result;

/// Binarization threshold choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threshold {
    /// Median of the projected values (the paper's choice).
    Median,
    /// Zero (classic sign-LSH baseline, Charikar 2002).
    Zero,
}

/// Auxiliary-information source `A ∈ R^{n×d}`: anything that can project
/// all of its rows against a random vector. Implemented for sparse
/// adjacency matrices ([`Csr`]) and dense embedding matrices
/// ([`DenseAux`]).
pub trait AuxSource {
    fn n(&self) -> usize;
    fn d(&self) -> usize;
    /// `out[j] = dot(A[j, :], v)` for all rows `j` (Algorithm 1 lines 7–8).
    fn project(&self, v: &[f32], out: &mut [f32]);
}

impl AuxSource for Csr {
    fn n(&self) -> usize {
        self.n_rows()
    }

    fn d(&self) -> usize {
        self.n_cols()
    }

    fn project(&self, v: &[f32], out: &mut [f32]) {
        self.spmv(v, out);
    }
}

/// Dense row-major auxiliary matrix (pre-trained embeddings path).
pub struct DenseAux<'a> {
    pub data: &'a [f32],
    pub n: usize,
    pub d: usize,
}

impl<'a> DenseAux<'a> {
    pub fn new(data: &'a [f32], n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d);
        Self { data, n, d }
    }
}

impl<'a> AuxSource for DenseAux<'a> {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn project(&self, v: &[f32], out: &mut [f32]) {
        for j in 0..self.n {
            let row = &self.data[j * self.d..(j + 1) * self.d];
            let mut acc = 0.0f32;
            for k in 0..self.d {
                acc += row[k] * v[k];
            }
            out[j] = acc;
        }
    }
}

/// Algorithm 1, verbatim: bit-by-bit streaming encode.
pub fn encode<A: AuxSource>(
    aux: &A,
    coding: CodingCfg,
    threshold: Threshold,
    seed: u64,
) -> Result<CodeTable> {
    coding.validate()?;
    let n = aux.n();
    let d = aux.d();
    let n_bits = coding.n_bits();
    let mut bits = BitMatrix::zeros(n, n_bits);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; d];
    let mut u = vec![0.0f32; n];
    let mut scratch = vec![0.0f32; n];
    for bit in 0..n_bits {
        rng.fill_normal_f32(&mut v, 0.0, 1.0); // line 5: GetRandomVector(d)
        aux.project(&v, &mut u); // lines 7–8: U = A·V
        let t = match threshold {
            Threshold::Median => {
                scratch.copy_from_slice(&u);
                median_in_place(&mut scratch) // line 9: GetMedian(U)
            }
            Threshold::Zero => 0.0,
        };
        for j in 0..n {
            if u[j] > t {
                bits.set(j, bit, true); // lines 10–11
            }
        }
    }
    CodeTable::new(bits, coding)
}

/// Blocked encode (§Perf): identical output *distribution* (different
/// random stream layout), processing `block_bits` projections per pass.
/// With a CSR source this turns `n_bits` full sparse traversals into
/// `n_bits / block_bits` traversals of a multi-vector SpMM.
pub fn encode_blocked<A: AuxSource + Sync>(
    aux: &A,
    coding: CodingCfg,
    threshold: Threshold,
    seed: u64,
    block_bits: usize,
) -> Result<CodeTable> {
    coding.validate()?;
    let n = aux.n();
    let d = aux.d();
    let n_bits = coding.n_bits();
    let block = block_bits.clamp(1, n_bits);
    let mut bits = BitMatrix::zeros(n, n_bits);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut vs = vec![0.0f32; d * block];
    let mut us = vec![0.0f32; n * block];
    let mut scratch = vec![0.0f32; n];
    let mut start = 0usize;
    while start < n_bits {
        let cur = block.min(n_bits - start);
        rng.fill_normal_f32(&mut vs[..d * cur], 0.0, 1.0);
        // Multi-vector projection. For CSR this is the blocked SpMM fast
        // path; for dense it is a (n×d)·(d×cur) matmul done row-wise.
        project_block(aux, &vs[..d * cur], cur, &mut us[..n * cur]);
        for b in 0..cur {
            let u = &us[b * n..(b + 1) * n];
            let t = match threshold {
                Threshold::Median => {
                    scratch.copy_from_slice(u);
                    median_in_place(&mut scratch)
                }
                Threshold::Zero => 0.0,
            };
            let bit = start + b;
            for j in 0..n {
                if u[j] > t {
                    bits.set(j, bit, true);
                }
            }
        }
        start += cur;
    }
    CodeTable::new(bits, coding)
}

/// `us[b*n + j] = dot(A[j,:], vs[b*d..])` — one pass over `A` for all `b`.
fn project_block<A: AuxSource + ?Sized>(aux: &A, vs: &[f32], n_vecs: usize, us: &mut [f32]) {
    let n = aux.n();
    let d = aux.d();
    debug_assert_eq!(vs.len(), d * n_vecs);
    debug_assert_eq!(us.len(), n * n_vecs);
    // Generic fallback: delegate to per-vector project (already one pass
    // per vector). Csr gets a specialized single-pass loop below.
    for b in 0..n_vecs {
        // SAFETY of indexing: disjoint slices per b.
        let (v, u) = (&vs[b * d..(b + 1) * d], &mut us[b * n..(b + 1) * n]);
        aux.project(v, u);
    }
}

/// Count collisions produced by a given (threshold, bits) setting over
/// `trials` seeds — the Figure 3 / Figure 6 experiment.
pub fn collision_trials<A: AuxSource>(
    aux: &A,
    n_bits: usize,
    threshold: Threshold,
    trials: usize,
    base_seed: u64,
) -> Vec<usize> {
    // Any (c, m) with the right product gives identical bits; use c=2.
    let coding = CodingCfg::new(2, n_bits).expect("valid coding");
    (0..trials)
        .map(|t| {
            let table = encode(aux, coding, threshold, base_seed + t as u64)
                .expect("encode cannot fail on valid input");
            table.bits.n_collisions()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::gaussian_mixture;
    use crate::graph::generate::barabasi_albert;

    fn coding(c: usize, m: usize) -> CodingCfg {
        CodingCfg::new(c, m).unwrap()
    }

    #[test]
    fn median_threshold_balances_bits() {
        let e = gaussian_mixture(400, 16, 4, 0.3, 1);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let t = encode(&aux, coding(2, 32), Threshold::Median, 7).unwrap();
        // Median split ⇒ every bit column is (almost) exactly half ones.
        for bit in 0..32 {
            let ones = (0..400).filter(|&r| t.bits.get(r, bit)).count();
            assert!((190..=210).contains(&ones), "bit {bit}: {ones} ones");
        }
    }

    #[test]
    fn zero_threshold_can_be_unbalanced() {
        // Shifted embeddings: all-positive projections ⇒ zero threshold
        // gives all-ones bits, median stays balanced.
        let n = 100;
        let d = 8;
        let data: Vec<f32> = (0..n * d).map(|i| 5.0 + (i % 7) as f32 * 0.01).collect();
        let aux = DenseAux::new(&data, n, d);
        let tz = encode(&aux, coding(2, 16), Threshold::Zero, 3).unwrap();
        let tm = encode(&aux, coding(2, 16), Threshold::Median, 3).unwrap();
        // Zero threshold: massively collided (rows nearly identical signs).
        // Median threshold: fewer collisions.
        assert!(tm.bits.n_collisions() <= tz.bits.n_collisions());
    }

    #[test]
    fn similar_rows_get_similar_codes() {
        // LSH property: two near-identical embedding rows should share most
        // code bits; two far rows should not.
        let d = 32;
        let mut data = vec![0.0f32; 3 * d];
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for j in 0..d {
            let v = rng.normal() as f32;
            data[j] = v;
            data[d + j] = v + 0.01 * rng.normal() as f32; // near-duplicate
            data[2 * d + j] = rng.normal() as f32 * 3.0; // unrelated
        }
        // Append background rows so the median is meaningful.
        let n = 200;
        let mut all = data.clone();
        let mut extra = vec![0.0f32; (n - 3) * d];
        rng.fill_normal_f32(&mut extra, 0.0, 1.0);
        all.extend_from_slice(&extra);
        let aux = DenseAux::new(&all, n, d);
        let t = encode(&aux, coding(2, 64), Threshold::Median, 11).unwrap();
        let ham = |a: usize, b: usize| (0..64).filter(|&k| t.bits.get(a, k) != t.bits.get(b, k)).count();
        assert!(ham(0, 1) < ham(0, 2), "near={} far={}", ham(0, 1), ham(0, 2));
        assert!(ham(0, 1) <= 8, "near rows differ in {} bits", ham(0, 1));
    }

    #[test]
    fn adjacency_source_works() {
        let g = barabasi_albert(300, 3, 2).unwrap();
        let t = encode(g.adj(), coding(4, 16), Threshold::Median, 1).unwrap();
        assert_eq!(t.n(), 300);
        // Codes should be far from all-identical.
        assert!(t.bits.n_collisions() < 150);
    }

    #[test]
    fn median_fewer_collisions_than_zero_fig3() {
        // The Figure 3 claim on a mixture whose projections are skewed.
        let e = gaussian_mixture(2000, 16, 8, 0.15, 9);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let med = collision_trials(&aux, 24, Threshold::Median, 5, 100);
        let zero = collision_trials(&aux, 24, Threshold::Zero, 5, 100);
        let med_avg: f64 = med.iter().sum::<usize>() as f64 / 5.0;
        let zero_avg: f64 = zero.iter().sum::<usize>() as f64 / 5.0;
        assert!(
            med_avg <= zero_avg,
            "median should not collide more: med={med_avg} zero={zero_avg}"
        );
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let e = gaussian_mixture(100, 8, 2, 0.5, 4);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let a = encode(&aux, coding(2, 24), Threshold::Median, 10).unwrap();
        let b = encode(&aux, coding(2, 24), Threshold::Median, 10).unwrap();
        let c = encode(&aux, coding(2, 24), Threshold::Median, 11).unwrap();
        assert_eq!(a.bits, b.bits);
        assert_ne!(a.bits, c.bits);
    }

    #[test]
    fn blocked_encode_same_statistics() {
        let e = gaussian_mixture(500, 12, 4, 0.3, 6);
        let aux = DenseAux::new(&e.data, e.n, e.d);
        let plain = encode(&aux, coding(2, 32), Threshold::Median, 3).unwrap();
        let blocked = encode_blocked(&aux, coding(2, 32), Threshold::Median, 3, 8).unwrap();
        // Same RNG consumption order per block differs, so exact equality is
        // not required — but per-bit balance must hold for both.
        for t in [&plain, &blocked] {
            for bit in 0..32 {
                let ones = (0..500).filter(|&r| t.bits.get(r, bit)).count();
                assert!((230..=270).contains(&ones), "ones={ones}");
            }
        }
        assert_eq!(blocked.n(), 500);
    }

    use crate::rng::Xoshiro256pp;
}
