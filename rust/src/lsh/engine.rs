//! Deterministic multi-threaded encode engine (§Perf).
//!
//! Executes Algorithm 1 as a pipeline of three parallel stages per block
//! of `block_bits` output bits:
//!
//! 1. **Project** — one traversal of the auxiliary matrix produces the
//!    projections for every bit in the block
//!    ([`AuxSource::project_block_rows`]; blocked CSR SpMM for adjacency,
//!    row-tiled dense kernel for embeddings), rows partitioned into
//!    contiguous ranges across workers.
//! 2. **Threshold** — per-bit medians of the full projection columns,
//!    bits partitioned across workers.
//! 3. **Pack** — each worker binarizes its row range and assembles the
//!    packed [`BitMatrix`] words 64 bits per store through a disjoint
//!    `&mut` view of its rows (no per-bit read-modify-write under a
//!    shared `&mut BitMatrix`).
//!
//! **Determinism contract:** output is bit-identical for every
//! `threads` / `block_bits` choice and equal to the bit-by-bit reference
//! [`super::encode`]. This holds because (a) every output bit draws its
//! random vector from its own stream seed
//! ([`crate::rng::derive_stream_seed`]), independent of batching; (b) the
//! blocked kernels accumulate each dot product in the same order as the
//! per-vector path; (c) medians are a function of the full column, not of
//! the partition; and (d) workers write disjoint rows.
//!
//! Threading uses `std::thread::scope` only — no thread-pool dependency —
//! so spawn cost is paid once per stage per block; with the default
//! 64-bit blocks that is ~3 spawns per 64 sparse-matrix traversals saved.

use crate::cfg::{CodingCfg, EncodeCfg};
use crate::codes::{BitMatrix, CodeTable};
use crate::rng::{Rng, Xoshiro256pp};
use crate::Result;

use super::{median_in_place, AuxSource, Threshold};

/// Run `f` once per part, on scoped threads when there is more than one
/// part (the single-part case runs inline to keep `threads = 1` free of
/// spawn overhead and usable in no-thread environments).
fn for_each_part<T: Send>(parts: Vec<T>, f: impl Fn(usize, T) + Sync) {
    if parts.len() <= 1 {
        for (i, p) in parts.into_iter().enumerate() {
            f(i, p);
        }
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        for (i, p) in parts.into_iter().enumerate() {
            s.spawn(move || f(i, p));
        }
    });
}

/// Algorithm 1 under an explicit execution plan ([`EncodeCfg`]).
///
/// Equivalent to [`super::encode`] bit for bit; see the module docs for
/// why. `threads = 0` uses all available parallelism, `block_bits = 0`
/// processes one packed 64-bit word per pass over the auxiliary matrix.
pub fn encode_with<A: AuxSource + Sync>(
    aux: &A,
    coding: CodingCfg,
    threshold: Threshold,
    seed: u64,
    opts: EncodeCfg,
) -> Result<CodeTable> {
    coding.validate()?;
    let n = aux.n();
    let d = aux.d();
    let n_bits = coding.n_bits();
    let mut bits = BitMatrix::zeros(n, n_bits);
    if n == 0 {
        return CodeTable::new(bits, coding);
    }
    let threads = opts.resolved_threads().clamp(1, n);
    let block = opts.resolved_block_bits(n_bits);
    // Uniform row chunking so every stage can split storage with
    // `chunks_mut` on identical boundaries.
    let chunk = n.div_ceil(threads);
    let wpr = bits.words_per_row();

    let mut vs = vec![0.0f32; d * block];
    let mut vt = vec![0.0f32; d * block];
    let mut us = vec![0.0f32; n * block];
    let mut thr = vec![0.0f32; block];

    let mut start = 0usize;
    while start < n_bits {
        let cur = block.min(n_bits - start);

        // ---- stage 0: per-bit random vectors (Algorithm 1 line 5) ------
        // One generator per output bit, derived from (seed, bit): the
        // stream layout is a property of the bit index alone, so every
        // (block_bits, threads) execution draws identical vectors.
        for b in 0..cur {
            let mut rng = Xoshiro256pp::seed_for_stream(seed, (start + b) as u64);
            rng.fill_normal_f32(&mut vs[b * d..(b + 1) * d], 0.0, 1.0);
        }
        // Transpose to coordinate-major `vt[k*cur + b]` so the projection
        // kernels read one contiguous `cur`-row per coordinate.
        for b in 0..cur {
            for k in 0..d {
                vt[k * cur + b] = vs[b * d + k];
            }
        }
        let vt_cur = &vt[..d * cur];

        // ---- stage 1: blocked projection (lines 7–8), rows in parallel -
        {
            let us_cur = &mut us[..n * cur];
            let n_workers = n.div_ceil(chunk);
            let mut by_worker: Vec<Vec<&mut [f32]>> =
                (0..n_workers).map(|_| Vec::with_capacity(cur)).collect();
            for col in us_cur.chunks_mut(n) {
                for (w, piece) in col.chunks_mut(chunk).enumerate() {
                    by_worker[w].push(piece);
                }
            }
            for_each_part(by_worker, |w, mut outs| {
                let r0 = w * chunk;
                let r1 = r0 + outs[0].len();
                aux.project_block_rows(r0..r1, vt_cur, cur, &mut outs);
            });
        }

        // ---- stage 2: per-bit thresholds (line 9), bits in parallel ----
        match threshold {
            Threshold::Zero => thr[..cur].fill(0.0),
            Threshold::Median => {
                let us_cur = &us[..n * cur];
                let bchunk = cur.div_ceil(threads.min(cur));
                let parts: Vec<(usize, &mut [f32])> = thr[..cur]
                    .chunks_mut(bchunk)
                    .enumerate()
                    .map(|(i, c)| (i * bchunk, c))
                    .collect();
                for_each_part(parts, |_w, (b0, ts)| {
                    let mut scratch = vec![0.0f32; n];
                    for (off, t) in ts.iter_mut().enumerate() {
                        let b = b0 + off;
                        scratch.copy_from_slice(&us_cur[b * n..(b + 1) * n]);
                        *t = median_in_place(&mut scratch);
                    }
                });
            }
        }

        // ---- stage 3: word-packed binarization (lines 10–11) -----------
        {
            let us_cur = &us[..n * cur];
            let thr_cur = &thr[..cur];
            let parts: Vec<(usize, &mut [u64])> = bits
                .words_mut()
                .chunks_mut(chunk * wpr)
                .enumerate()
                .map(|(w, c)| (w * chunk, c))
                .collect();
            for_each_part(parts, |_w, (row0, wchunk)| {
                pack_rows(row0, wchunk, wpr, us_cur, thr_cur, n, start, cur);
            });
        }

        start += cur;
    }
    CodeTable::new(bits, coding)
}

/// Binarize bits `[start, start+cur)` for the rows backing `wchunk`
/// (`wchunk = words[row0*wpr ..]`), assembling each affected 64-bit word
/// in a register and committing it with a single OR-store per `(row, word)`.
///
/// Bit ranges of successive blocks are disjoint, so OR into the zeroed
/// matrix writes every bit exactly once.
fn pack_rows(
    row0: usize,
    wchunk: &mut [u64],
    wpr: usize,
    us: &[f32],
    thr: &[f32],
    n: usize,
    start: usize,
    cur: usize,
) {
    let n_rows = wchunk.len() / wpr;
    let w_lo = start / 64;
    let w_hi = (start + cur - 1) / 64;
    for w in w_lo..=w_hi {
        let bit_lo = start.max(w * 64);
        let bit_hi = (start + cur).min((w + 1) * 64);
        for jr in 0..n_rows {
            let j = row0 + jr;
            let mut word = 0u64;
            for bit in bit_lo..bit_hi {
                let b = bit - start;
                word |= u64::from(us[b * n + j] > thr[b]) << (bit % 64);
            }
            wchunk[jr * wpr + w] |= word;
        }
    }
}
