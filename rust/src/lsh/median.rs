//! O(n) median selection (Algorithm 1 line 9; the paper cites the Blum
//! et al. 1973 selection bound). Implemented as in-place quickselect with
//! median-of-three pivoting — O(n) expected, and the input is a fresh
//! scratch buffer so in-place partitioning is free.

/// Median of a slice, computed by quickselect. For even lengths returns the
/// lower median (any split point with half the mass below is a valid LSH
/// threshold; the lower median guarantees `> t` selects ≤ half the items).
/// NaNs are not expected (projections of finite data) and will panic in
/// debug builds.
pub fn median_in_place(xs: &mut [f32]) -> f32 {
    assert!(!xs.is_empty(), "median of empty slice");
    let k = (xs.len() - 1) / 2;
    quickselect(xs, k)
}

/// The k-th smallest element (0-based), partially sorting `xs`.
fn quickselect(xs: &mut [f32], k: usize) -> f32 {
    let mut lo = 0usize;
    let mut hi = xs.len();
    let mut k = k;
    loop {
        if hi - lo <= 8 {
            xs[lo..hi].sort_unstable_by(|a, b| a.partial_cmp(b).expect("NaN in median input"));
            return xs[lo + k];
        }
        let pivot = median_of_three(xs[lo], xs[lo + (hi - lo) / 2], xs[hi - 1]);
        // Three-way partition (Dutch national flag) to handle duplicates.
        let (mut lt, mut i, mut gt) = (lo, lo, hi);
        while i < gt {
            if xs[i] < pivot {
                xs.swap(lt, i);
                lt += 1;
                i += 1;
            } else if xs[i] > pivot {
                gt -= 1;
                xs.swap(i, gt);
            } else {
                i += 1;
            }
        }
        let n_lt = lt - lo;
        let n_eq = gt - lt;
        if k < n_lt {
            hi = lt;
        } else if k < n_lt + n_eq {
            return pivot;
        } else {
            k -= n_lt + n_eq;
            lo = gt;
        }
    }
}

#[inline]
fn median_of_three(a: f32, b: f32, c: f32) -> f32 {
    if (a <= b) == (b <= c) {
        b
    } else if (b <= a) == (a <= c) {
        a
    } else {
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    fn median_by_sort(xs: &[f32]) -> f32 {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[(v.len() - 1) / 2]
    }

    #[test]
    fn matches_sort_based_median() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        for trial in 0..200 {
            let n = 1 + rng.index(500);
            let xs: Vec<f32> = (0..n).map(|_| (rng.normal() * 10.0) as f32).collect();
            let expect = median_by_sort(&xs);
            let mut buf = xs.clone();
            let got = median_in_place(&mut buf);
            assert_eq!(got, expect, "trial {trial}, n={n}");
        }
    }

    #[test]
    fn handles_duplicates() {
        let mut xs = vec![3.0f32; 100];
        assert_eq!(median_in_place(&mut xs), 3.0);
        let mut xs: Vec<f32> = (0..101).map(|i| if i < 60 { 1.0 } else { 2.0 }).collect();
        assert_eq!(median_in_place(&mut xs), 1.0);
    }

    #[test]
    fn single_and_pair() {
        assert_eq!(median_in_place(&mut [5.0]), 5.0);
        assert_eq!(median_in_place(&mut [2.0, 1.0]), 1.0); // lower median
    }

    #[test]
    fn split_property_for_lsh() {
        // Strictly-greater-than-median count must be ≤ n/2 — the property
        // the LSH bit balance relies on.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for _ in 0..50 {
            let n = 10 + rng.index(200);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut buf = xs.clone();
            let t = median_in_place(&mut buf);
            let above = xs.iter().filter(|&&x| x > t).count();
            assert!(above <= n / 2, "n={n} above={above}");
        }
    }

    #[test]
    fn sorted_and_reversed_inputs() {
        let mut asc: Vec<f32> = (0..999).map(|i| i as f32).collect();
        assert_eq!(median_in_place(&mut asc), 499.0);
        let mut desc: Vec<f32> = (0..999).rev().map(|i| i as f32).collect();
        assert_eq!(median_in_place(&mut desc), 499.0);
    }
}
