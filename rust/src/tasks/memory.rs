//! Tables 2, 4 and 6 — memory accounting and compression ratios.
//!
//! These tables are analytic in the paper too; we reproduce them at the
//! paper's own dimensions (n = 1,871,031 for ogbn-products, etc.).
//!
//! **Accounting note** (documented reverse-engineering): the paper's §3.2
//! formula counts MLP weights `d_c·d_m + (l−2)·d_m² + d_m·d_e`, but the
//! numbers actually printed in Tables 2/4/6 reproduce exactly when the
//! middle `(l−2)·d_m²` term is omitted (e.g. Table 4 GloVe/5000 = 2.65
//! and Table 2's 9.13 MB decoder both match only then). We implement both
//! and use the *effective* variant for the table reproductions so the
//! printed numbers line up with the paper.

use crate::cfg::{CodingCfg, DecoderCfg};

/// Bytes per MiB (the paper's "MB" columns are mebibytes — 456.79 for
/// ogbn-products' raw table only matches with 2²⁰).
pub const MIB: f64 = 1024.0 * 1024.0;

/// Decoder parameter count as the paper's tables actually account it
/// (codebooks + first & last MLP layers; see module docs).
pub fn effective_decoder_params(c: usize, m: usize, d_c: usize, d_m: usize, d_e: usize) -> usize {
    m * c * d_c + d_c * d_m + d_m * d_e
}

/// Strict §3.2 decoder weight count (for comparison).
pub fn strict_decoder_params(cfg: &DecoderCfg) -> usize {
    cfg.codebook_params() + cfg.mlp_weight_params()
}

/// Bit-packed code storage bytes: `n·m·log2(c) / 8`.
pub fn code_bytes(n: usize, coding: CodingCfg) -> usize {
    n * coding.n_bits() / 8
}

/// Raw embedding-table bytes (f32).
pub fn raw_bytes(n: usize, d_e: usize) -> usize {
    n * d_e * 4
}

/// One row of Table 2.
#[derive(Clone, Debug)]
pub struct MemoryRow {
    pub method: String,
    pub cpu_code: f64,
    pub cpu_decoder: f64,
    pub cpu_total: f64,
    pub gpu_model: f64,
    pub gpu_gnn: f64,
    pub gpu_total: f64,
    pub gpu_ratio: f64,
    pub total: f64,
    pub total_ratio: f64,
}

/// Reproduce Table 2 (memory cost on ogbn-products): raw vs hash-light vs
/// hash-full. All quantities in MiB. `gnn_bytes` is the GNN's own
/// parameter memory (the paper reports 1.35).
pub fn table2(
    n: usize,
    d_e: usize,
    coding: CodingCfg,
    d_c: usize,
    d_m: usize,
    gnn_bytes: usize,
) -> Vec<MemoryRow> {
    let raw = raw_bytes(n, d_e) as f64 / MIB;
    let gnn = gnn_bytes as f64 / MIB;
    let codes = code_bytes(n, coding) as f64 / MIB;
    let books = (coding.m * coding.c * d_c * 4) as f64 / MIB;
    let mlp = ((d_c * d_m + d_m * d_e) * 4) as f64 / MIB;

    let raw_gpu_total = raw + gnn;
    let mut rows = vec![MemoryRow {
        method: "Raw".into(),
        cpu_code: 0.0,
        cpu_decoder: 0.0,
        cpu_total: 0.0,
        gpu_model: raw,
        gpu_gnn: gnn,
        gpu_total: raw_gpu_total,
        gpu_ratio: 1.0,
        total: raw_gpu_total,
        total_ratio: 1.0,
    }];
    // Light: codebooks live on CPU (frozen), MLP+W0 on GPU.
    let light_gpu = mlp + gnn;
    let light_total = codes + books + light_gpu;
    rows.push(MemoryRow {
        method: "Hash-Light".into(),
        cpu_code: codes,
        cpu_decoder: books,
        cpu_total: codes + books,
        gpu_model: mlp,
        gpu_gnn: gnn,
        gpu_total: light_gpu,
        gpu_ratio: raw_gpu_total / light_gpu,
        total: light_total,
        total_ratio: raw_gpu_total / light_total,
    });
    // Full ("Hash-Heavy" in the paper's table): codebooks trainable on GPU.
    let full_gpu = books + mlp + gnn;
    let full_total = codes + full_gpu;
    rows.push(MemoryRow {
        method: "Hash-Full".into(),
        cpu_code: codes,
        cpu_decoder: 0.0,
        cpu_total: codes,
        gpu_model: books + mlp,
        gpu_gnn: gnn,
        gpu_total: full_gpu,
        gpu_ratio: raw_gpu_total / full_gpu,
        total: full_total,
        total_ratio: raw_gpu_total / full_total,
    });
    rows
}

/// Tables 4 & 6 — compression ratio for `n` compressed entities:
/// `raw / (codes + decoder)`.
pub fn compression_ratio(
    n: usize,
    d_raw: usize,
    coding: CodingCfg,
    d_c: usize,
    d_m: usize,
    d_e: usize,
) -> f64 {
    let raw = raw_bytes(n, d_raw) as f64;
    let compressed = code_bytes(n, coding) as f64
        + (effective_decoder_params(coding.c, coding.m, d_c, d_m, d_e) * 4) as f64;
    raw / compressed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coding(c: usize, m: usize) -> CodingCfg {
        CodingCfg::new(c, m).unwrap()
    }

    #[test]
    fn table4_glove_row_matches_paper() {
        // Paper Table 4, GloVe (d=300, d_c=d_m=512, c=2, m=128):
        // 5000→2.65, 10000→5.11, 50000→20.09, 200000→44.55.
        let cases = [(5000, 2.65), (10000, 5.11), (50000, 20.09), (200000, 44.55)];
        for (n, expect) in cases {
            let r = compression_ratio(n, 300, coding(2, 128), 512, 512, 300);
            assert!((r - expect).abs() < 0.02, "n={n}: got {r}, paper {expect}");
        }
    }

    #[test]
    fn table4_metapath_row_matches_paper() {
        // metapath2vec (d=128, d_e=128): 5000→1.34, 200000→20.34.
        let cases = [(5000, 1.34), (10000, 2.57), (50000, 9.72), (200000, 20.34)];
        for (n, expect) in cases {
            let r = compression_ratio(n, 128, coding(2, 128), 512, 512, 128);
            assert!((r - expect).abs() < 0.02, "n={n}: got {r}, paper {expect}");
        }
    }

    #[test]
    fn table6_cm_sweep_matches_paper() {
        // GloVe rows of Table 6 at n=5000: (2,128)→2.65, (4,64)→2.65,
        // (16,32)→2.15, (256,16)→0.59.
        let cases = [((2usize, 128usize), 2.65), ((4, 64), 2.65), ((16, 32), 2.15), ((256, 16), 0.59)];
        for ((c, m), expect) in cases {
            let r = compression_ratio(5000, 300, coding(c, m), 512, 512, 300);
            assert!((r - expect).abs() < 0.02, "(c={c},m={m}): got {r}, paper {expect}");
        }
    }

    #[test]
    fn table2_matches_paper_headline_numbers() {
        // ogbn-products: n=1,871,031, d_e=64, c=256, m=16, d_c=d_m=512.
        let rows = table2(1_871_031, 64, coding(256, 16), 512, 512, (1.35 * MIB) as usize);
        let raw = &rows[0];
        assert!((raw.gpu_model - 456.79).abs() < 0.2, "raw={}", raw.gpu_model);
        let light = &rows[1];
        assert!((light.cpu_code - 28.55).abs() < 0.2, "codes={}", light.cpu_code);
        assert!((light.cpu_decoder - 8.0).abs() < 0.1);
        assert!((light.gpu_model - 1.13).abs() < 0.05);
        let full = &rows[2];
        assert!((full.gpu_model - 9.13).abs() < 0.05, "full gpu={}", full.gpu_model);
        assert!((full.gpu_ratio - 43.75).abs() < 0.3, "ratio={}", full.gpu_ratio);
        assert!((full.total_ratio - 11.74).abs() < 0.15, "total ratio={}", full.total_ratio);
    }

    #[test]
    fn strict_vs_effective_params_differ_by_middle_layer() {
        let cfg = DecoderCfg::paper_ogb(coding(256, 16), crate::cfg::DecoderVariant::Full);
        let strict = strict_decoder_params(&cfg);
        let effective = effective_decoder_params(256, 16, 512, 512, 64);
        assert_eq!(strict - effective, 512 * 512); // the (l-2)·d_m² term
    }
}
