//! Minibatch GraphSAGE pipeline (paper Section 4 / Figure 4): the
//! industrial-scale path. Target nodes are sampled in batches, two-hop
//! neighborhoods are fan-out sampled, codes are gathered from the
//! bit-packed store, and the train step runs — with batch production
//! overlapped against execution by the [`crate::train`] pipeline.
//!
//! The whole driver is backend-agnostic: the [`Model`] may hold AOT
//! HLO executables or the pure-Rust native backend
//! ([`crate::runtime::native`]); batching, training and evaluation are
//! identical on both.

use std::sync::Arc;

use crate::codes::CodeTable;
use crate::eval::{accuracy_from_logits, hits_at_k_from_logits};
use crate::graph::{Graph, NeighborSampler};
use crate::params::ParamStore;
use crate::rng::{derive_stream_seed, Rng, Xoshiro256pp};
use crate::runtime::{Model, Tensor};
use crate::train::{self, BatchSource, PipeCfg, TrainOpts};
use crate::{Error, Result};

/// Feature source for the minibatch pipeline.
#[derive(Clone)]
pub enum Features {
    /// Compressed: gather integer codes from the bit-packed table.
    Codes(Arc<CodeTable>),
    /// NC baseline: pass raw node ids (the executable owns the table).
    Ids,
}

/// The full task description (shared by Table-1 SAGE runs at scale, the
/// §5.3 merchant task and the e2e example).
pub struct SageTask {
    pub graph: Arc<Graph>,
    /// Label per node (only target nodes need real labels).
    pub labels: Arc<Vec<u32>>,
    pub features: Features,
    pub train_nodes: Arc<Vec<u32>>,
}

/// Batch producer: samples target nodes + two-hop neighborhoods and
/// assembles the train-step input tensors. Runs on the producer thread.
pub struct SageBatcher {
    task: SageTask,
    batch: usize,
    k1: usize,
    k2: usize,
    m: usize,
    seed: u64,
    /// Worker threads for the fan-out sampling inside each batch. Never
    /// changes the produced tensors (per-position seed streams), only how
    /// fast the producer runs.
    sample_threads: usize,
}

impl SageBatcher {
    pub fn new(task: SageTask, model: &Model, seed: u64) -> Result<Self> {
        Ok(Self {
            batch: model.manifest.hyper_usize("batch")?,
            k1: model.manifest.hyper_usize("k1")?,
            k2: model.manifest.hyper_usize("k2")?,
            m: model.manifest.hyper_usize("m")?,
            task,
            seed,
            sample_threads: 1,
        })
    }

    /// Pool the per-batch neighbor sampling across `t` workers
    /// (0 = all cores). Output tensors are bit-identical for any `t`.
    pub fn with_sample_threads(mut self, t: usize) -> Self {
        self.sample_threads = t;
        self
    }

    /// Node tensors for an explicit list of target nodes (used by eval).
    /// `seed` keys the per-position fan-out streams.
    pub fn node_tensors(&self, targets: &[u32], seed: u64) -> Result<Vec<Tensor>> {
        assert_eq!(targets.len(), self.batch);
        match &self.task.features {
            Features::Codes(table) => coded_fanout_tensors(
                &self.task.graph,
                table,
                self.k1,
                self.k2,
                self.m,
                targets,
                seed,
                self.sample_threads,
            ),
            Features::Ids => {
                let sampler = NeighborSampler::new(&self.task.graph, self.k1, self.k2);
                let sample = sampler.sample_streams_par(targets, seed, self.sample_threads);
                let ids =
                    |v: &[u32]| Tensor::i32(vec![v.len()], v.iter().map(|&x| x as i32).collect());
                Ok(vec![ids(targets)?, ids(&sample.hop1)?, ids(&sample.hop2)?])
            }
        }
    }

    fn train_batch(&self, step: u64) -> Vec<Tensor> {
        let step_seed = self.seed ^ step.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        // Target draws stay on one sequential stream (b cheap draws);
        // the fan-out gets its own derived root so per-position streams
        // can never collide with the target stream.
        let mut rng = Xoshiro256pp::seed_from_u64(step_seed);
        let pool = &self.task.train_nodes;
        let targets: Vec<u32> =
            (0..self.batch).map(|_| pool[rng.index(pool.len())]).collect();
        let fanout_seed = derive_stream_seed(step_seed, 1);
        let mut tensors = self.node_tensors(&targets, fanout_seed).expect("batch tensors");
        let labels: Vec<i32> =
            targets.iter().map(|&t| self.task.labels[t as usize] as i32).collect();
        tensors.push(Tensor::i32(vec![self.batch], labels).expect("labels tensor"));
        tensors
    }
}

impl BatchSource for SageBatcher {
    fn next_batch(&mut self, step: u64) -> Vec<Tensor> {
        self.train_batch(step)
    }
}

/// Fan-out sample `targets` and gather their integer codes — the three
/// `(rows, m)` tensors one encoder application consumes. Shared by the
/// classification batcher above and the link batcher in
/// [`crate::tasks::linkpred`], so the fan-out tensor contract lives in
/// one place. `seed` keys the per-position sampling streams;
/// `sample_threads` only partitions them (bit-identical for any count).
#[allow(clippy::too_many_arguments)]
pub fn coded_fanout_tensors(
    graph: &Graph,
    codes: &CodeTable,
    k1: usize,
    k2: usize,
    m: usize,
    targets: &[u32],
    seed: u64,
    sample_threads: usize,
) -> Result<Vec<Tensor>> {
    let sampler = NeighborSampler::new(graph, k1, k2);
    let sample = sampler.sample_streams_par(targets, seed, sample_threads);
    let mut buf = Vec::new();
    let gather = |ids: &[u32], buf: &mut Vec<i32>| -> Result<Tensor> {
        codes.gather_int_codes(ids, buf);
        Tensor::i32(vec![ids.len(), m], buf.clone())
    };
    Ok(vec![
        gather(targets, &mut buf)?,
        gather(&sample.hop1, &mut buf)?,
        gather(&sample.hop2, &mut buf)?,
    ])
}

/// Evaluation metrics over a node set.
#[derive(Clone, Copy, Debug, Default)]
pub struct SageMetrics {
    pub accuracy: f64,
    pub hit5: f64,
    pub hit10: f64,
    pub hit20: f64,
}

/// Run prediction over `nodes` in fixed-size batches and compute
/// accuracy + hit rates (Table 3 metrics).
pub fn evaluate(
    model: &Model,
    store: &ParamStore,
    batcher: &SageBatcher,
    nodes: &[u32],
    seed: u64,
) -> Result<SageMetrics> {
    if nodes.is_empty() {
        return Ok(SageMetrics::default());
    }
    let b = batcher.batch;
    let k = model.manifest.hyper_usize("n_classes")?;
    let mut all_logits: Vec<f32> = Vec::with_capacity(nodes.len() * k);
    let mut start = 0usize;
    let mut batch_idx = 0u64;
    while start < nodes.len() {
        let targets: Vec<u32> =
            (0..b).map(|i| nodes[(start + i).min(nodes.len() - 1)]).collect();
        // Per-batch derived seed (not one rng carried across batches), so
        // a batch's sample never depends on how many batches preceded it.
        let tensors = batcher.node_tensors(&targets, derive_stream_seed(seed, batch_idx))?;
        batch_idx += 1;
        let logits = train::predict(model, store, &tensors)?;
        let vals = logits.as_f32()?;
        let take = (nodes.len() - start).min(b);
        all_logits.extend_from_slice(&vals[..take * k]);
        start += b;
    }
    let labels: Vec<u32> = nodes.iter().map(|&n| batcher.task.labels[n as usize]).collect();
    let n = nodes.len();
    Ok(SageMetrics {
        accuracy: accuracy_from_logits(&all_logits, n, k, &labels),
        hit5: hits_at_k_from_logits(&all_logits, n, k, &labels, 5),
        hit10: hits_at_k_from_logits(&all_logits, n, k, &labels, 10),
        hit20: hits_at_k_from_logits(&all_logits, n, k, &labels, 20),
    })
}

/// Train for `epochs` passes over the training pool (steps =
/// epochs·⌈train/B⌉), evaluating on `val_nodes` after each epoch and
/// keeping the best-validation parameters (§5.3.2 protocol).
pub struct SageRun {
    pub store: ParamStore,
    pub best_val: SageMetrics,
    pub losses: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
pub fn train_sage(
    model: &Model,
    task: SageTask,
    epochs: usize,
    val_nodes: &[u32],
    seed: u64,
    log_every: u64,
) -> Result<SageRun> {
    train_sage_cfg(model, task, epochs, val_nodes, seed, log_every, PipeCfg::default())
}

/// [`train_sage`] with explicit pipeline knobs (`--sample-threads`,
/// `--prefetch`, serial vs pipelined). The loss curve and final params
/// are bit-identical for every `cfg` — only wall time moves.
#[allow(clippy::too_many_arguments)]
pub fn train_sage_cfg(
    model: &Model,
    task: SageTask,
    epochs: usize,
    val_nodes: &[u32],
    seed: u64,
    log_every: u64,
    cfg: PipeCfg,
) -> Result<SageRun> {
    let batcher = SageBatcher::new(
        SageTask {
            graph: task.graph.clone(),
            labels: task.labels.clone(),
            features: task.features.clone(),
            train_nodes: task.train_nodes.clone(),
        },
        model,
        seed,
    )?
    .with_sample_threads(cfg.sample_threads);
    let steps_per_epoch = (task.train_nodes.len().div_ceil(batcher.batch)).max(1) as u64;
    let mut store = ParamStore::init(&model.manifest, seed);
    let mut best_store = store.clone();
    let mut best = SageMetrics { accuracy: f64::MIN, ..Default::default() };
    let mut losses = Vec::new();
    for epoch in 0..epochs {
        let epoch_batcher = SageBatcher::new(
            SageTask {
                graph: task.graph.clone(),
                labels: task.labels.clone(),
                features: task.features.clone(),
                train_nodes: task.train_nodes.clone(),
            },
            model,
            seed ^ ((epoch as u64 + 1) << 32),
        )?
        .with_sample_threads(cfg.sample_threads);
        let mut opts = TrainOpts::new(steps_per_epoch);
        opts.log_every = log_every;
        opts.pipeline = cfg.pipeline;
        opts.prefetch = cfg.prefetch;
        let log = train::train(model, &mut store, epoch_batcher, opts)?;
        losses.extend(log.losses);
        if val_nodes.is_empty() {
            continue;
        }
        let val = evaluate(model, &store, &batcher, val_nodes, seed ^ 0xE7A1)?;
        if val.accuracy > best.accuracy {
            best = val;
            best_store = store.clone();
        }
    }
    if val_nodes.is_empty() {
        best_store = store;
        best = SageMetrics::default();
    }
    Ok(SageRun { store: best_store, best_val: best, losses })
}

/// Helper: uniform labels vector covering every node (targets overwritten
/// by the caller).
pub fn full_label_vec(n: usize, targets: &[u32], target_labels: &[u32]) -> Result<Vec<u32>> {
    if targets.len() != target_labels.len() {
        return Err(Error::Shape("targets/labels length mismatch".into()));
    }
    let mut labels = vec![0u32; n];
    for (&t, &l) in targets.iter().zip(target_labels) {
        if t as usize >= n {
            return Err(Error::Shape(format!("target {t} out of range {n}")));
        }
        labels[t as usize] = l;
    }
    Ok(labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::CodingCfg;
    use crate::codes::random_codes;
    use crate::graph::generate::{sbm, SbmCfg};

    #[test]
    fn full_label_vec_places_labels() {
        let v = full_label_vec(5, &[1, 3], &[7, 9]).unwrap();
        assert_eq!(v, vec![0, 7, 0, 9, 0]);
        assert!(full_label_vec(2, &[5], &[1]).is_err());
        assert!(full_label_vec(5, &[1], &[1, 2]).is_err());
    }

    #[test]
    fn batcher_shapes_without_runtime() {
        // Exercise the batching path without a PJRT engine by faking the
        // manifest-dependent fields directly.
        let g = Arc::new(sbm(SbmCfg::new(200, 4, 8.0, 2.0), 1).unwrap());
        let labels = Arc::new(g.labels().unwrap().to_vec());
        let coding = CodingCfg::new(16, 8).unwrap();
        let table = Arc::new(random_codes(200, coding, 3));
        let task = SageTask {
            graph: g,
            labels,
            features: Features::Codes(table),
            train_nodes: Arc::new((0..150u32).collect()),
        };
        let mut batcher = SageBatcher {
            task,
            batch: 16,
            k1: 4,
            k2: 3,
            m: 8,
            seed: 9,
            sample_threads: 1,
        };
        let tensors = batcher.next_batch(0);
        assert_eq!(tensors.len(), 4);
        assert_eq!(tensors[0].shape(), &[16, 8]);
        assert_eq!(tensors[1].shape(), &[16 * 4, 8]);
        assert_eq!(tensors[2].shape(), &[16 * 4 * 3, 8]);
        assert_eq!(tensors[3].shape(), &[16]);
        // Determinism per step index.
        let again = batcher.next_batch(0);
        assert_eq!(tensors[0], again[0]);
        let different = batcher.next_batch(1);
        assert_ne!(tensors[0], different[0]);
        // Pooled sampling produces the exact same batch tensors.
        for t in [2usize, 8] {
            let mut pooled = SageBatcher {
                task: SageTask {
                    graph: batcher.task.graph.clone(),
                    labels: batcher.task.labels.clone(),
                    features: batcher.task.features.clone(),
                    train_nodes: batcher.task.train_nodes.clone(),
                },
                batch: 16,
                k1: 4,
                k2: 3,
                m: 8,
                seed: 9,
                sample_threads: t,
            };
            for step in [0u64, 1, 5] {
                assert_eq!(batcher.next_batch(step), pooled.next_batch(step), "t={t}");
            }
        }
    }
}
