//! Serving drivers: assemble the frozen [`ServingBundle`] from training
//! artifacts (`hashgnn export`) and keep the graph/codes recipes in one
//! place so the train and export CLIs cannot drift apart.
//!
//! The bundle must freeze exactly what training saw: the same synthetic
//! graph (same generator, same seed), the same message-passing edge set
//! (link prediction trains on the 80% train split only — no leakage into
//! serving either), and the same compositional codes (Algorithm 1 over
//! the same adjacency with the same seed, or a pre-encoded code file).
//! Everything here is deterministic in `(manifest, seed)`.

use std::path::Path;

use crate::cfg::{Coder, CodingCfg};
use crate::codes::{BitMatrix, CodeTable};
use crate::graph::generate::{sbm, SbmCfg};
use crate::graph::Graph;
use crate::params::ParamStore;
use crate::runtime::Manifest;
use crate::serve::{Quant, ServingBundle};
use crate::tasks::coding::{make_codes, Aux};
use crate::tasks::linkpred::split_edges;
use crate::tasks::T1Dataset;
use crate::{Error, Result};

/// Regenerate the graph `hashgnn train` used for this manifest's task:
/// the §4 SBM for the minibatch pipeline, the Table-1 OGB analogs for
/// the full-batch grid, nothing for the plain decoder. Deterministic in
/// `(manifest, seed)`, and validated against the manifest's `n`.
pub fn training_graph(manifest: &Manifest, seed: u64) -> Result<Option<Graph>> {
    let task = manifest.hyper_str("task")?;
    let graph = match task {
        "recon" => return Ok(None),
        "sage_minibatch" | "sage_minibatch_link" => {
            let n = manifest.hyper_usize("n")?;
            let k = manifest.hyper_usize("n_classes")?;
            sbm(SbmCfg::new(n, k, 12.0, 2.0), seed)?
        }
        "nodeclf_fullbatch" => T1Dataset::Arxiv.generate(seed)?,
        "linkpred_fullbatch" => T1Dataset::Collab.generate(seed)?,
        other => {
            return Err(Error::Config(format!(
                "no serving-graph recipe for task '{other}'"
            )))
        }
    };
    let n = manifest.hyper_usize("n")?;
    if graph.n_nodes() != n {
        return Err(Error::Shape(format!(
            "regenerated training graph has {} nodes, manifest '{}' wants {n} — export the \
             bundle through the API (ServingBundle::new) for custom scales",
            graph.n_nodes(),
            manifest.name
        )));
    }
    Ok(Some(graph))
}

/// The message-passing edge set serving should propagate over — exactly
/// what training bound: the 80% train split for full-batch link
/// prediction (same split seed derivation as the training driver), the
/// whole graph otherwise.
pub fn serving_edges(manifest: &Manifest, graph: &Graph, seed: u64) -> Result<Vec<(u32, u32)>> {
    if manifest.hyper_str("task")? == "linkpred_fullbatch" {
        Ok(split_edges(graph, seed ^ 0x5A5A)?.train)
    } else {
        Ok(graph.undirected_edges())
    }
}

/// Export options (`hashgnn export` flags).
#[derive(Clone, Debug)]
pub struct ExportOpts {
    /// Coding scheme when codes are regenerated (hash = Algorithm 1).
    pub coder: Coder,
    /// Pre-encoded bit-packed code file (`hashgnn encode --out`); when
    /// absent, codes are regenerated from the training graph.
    pub codes_file: Option<std::path::PathBuf>,
    /// The training run's seed (graph, split and codes all derive from it).
    pub seed: u64,
    /// Parameter encoding of the written file(s): `f32` (exact) or
    /// `int8` (per-row asymmetric quantization of every rank-2 tensor,
    /// ~4× smaller params, dequantized once at load).
    pub quant: Quant,
    /// Write the superseded `HGNB0001`/`HGNS0001` envelope format
    /// instead of the v2 section table — back-compat fixtures and
    /// cold-start before/after benches only. Incompatible with int8.
    pub legacy_v1: bool,
}

/// Assemble a [`ServingBundle`] for a trained checkpoint: regenerate the
/// training graph and edge set, load or regenerate the codes, and
/// validate everything against the manifest.
pub fn export_bundle(
    manifest: &Manifest,
    store: &ParamStore,
    opts: &ExportOpts,
) -> Result<ServingBundle> {
    let task = manifest.hyper_str("task")?;
    let coded = if task == "recon" { true } else { manifest.hyper_bool("coded")? };
    let graph = training_graph(manifest, opts.seed)?;
    let edges = match &graph {
        Some(g) => serving_edges(manifest, g, opts.seed)?,
        None => Vec::new(),
    };
    let codes = if coded {
        let coding =
            CodingCfg::new(manifest.hyper_usize("c")?, manifest.hyper_usize("m")?)?;
        Some(match &opts.codes_file {
            Some(path) => CodeTable::new(BitMatrix::load(path)?, coding)?,
            None => {
                let g = graph.as_ref().ok_or_else(|| {
                    Error::Config(
                        "the plain decoder has no training graph to encode from — pass a \
                         pre-encoded code file (--codes, from `hashgnn encode --out`)"
                            .into(),
                    )
                })?;
                // Mirror the training drivers' codes source: link prediction
                // encodes the train-edge graph, everything else the full one.
                if task == "linkpred_fullbatch" {
                    let train_graph = Graph::from_edges(g.n_nodes(), &edges)?;
                    make_codes(&Aux::Graph(&train_graph), opts.coder, coding, opts.seed)?
                } else {
                    make_codes(&Aux::Graph(g), opts.coder, coding, opts.seed)?
                }
            }
        })
    } else {
        None
    };
    let n_nodes = match (&graph, &codes) {
        (Some(g), _) => g.n_nodes(),
        (None, Some(c)) => c.n(),
        (None, None) => {
            return Err(Error::Config("bundle would carry neither graph nor codes".into()))
        }
    };
    let mut bundle = ServingBundle::new(manifest.clone(), store, codes, edges, n_nodes)?;
    if task != "recon" && crate::runtime::native::front_end_name(manifest)? == "poshash" {
        // Freeze the degree-rank position map from the same graph
        // training ranked: the train-edge graph for link prediction (the
        // bound message-passing adjacency), the full graph otherwise.
        let g = graph.as_ref().ok_or_else(|| {
            Error::Config("poshash export needs a training graph to rank degrees".into())
        })?;
        let map = if task == "linkpred_fullbatch" {
            let train_graph = Graph::from_edge_iter(g.n_nodes(), bundle.edges.iter())?;
            crate::tasks::nodeclf::pos_map_for(manifest, &train_graph)?
        } else {
            crate::tasks::nodeclf::pos_map_for(manifest, g)?
        };
        bundle = bundle.with_pos_map(map.as_ref().clone())?;
    }
    Ok(bundle)
}

/// Export and write to disk; returns the bundle for reporting.
pub fn export_bundle_to(
    manifest: &Manifest,
    store: &ParamStore,
    opts: &ExportOpts,
    out: &Path,
) -> Result<ServingBundle> {
    let bundle = export_bundle(manifest, store, opts)?;
    write_bundle(&bundle, opts, out)?;
    Ok(bundle)
}

/// One save dispatch for every export path: v2 section table with the
/// chosen quantization, or the legacy v1 envelope (f32 only).
fn write_bundle(bundle: &ServingBundle, opts: &ExportOpts, out: &Path) -> Result<()> {
    if opts.legacy_v1 {
        if opts.quant != Quant::F32 {
            return Err(Error::Config(
                "--legacy-v1 writes the HGNB0001 envelope, which has no quantized \
                 section — drop --quant int8 or the legacy flag"
                    .into(),
            ));
        }
        bundle.save_legacy_v1(out)
    } else {
        bundle.save_with(out, opts.quant)
    }
}

/// Shard file naming: `bundle.bin` + (0, 2) → `bundle.bin.shard-0-of-2`.
/// Every consumer (CLI, docs, CI) derives names through here so a shard
/// set is always discoverable from its base path.
pub fn shard_path(base: &Path, index: usize, count: usize) -> std::path::PathBuf {
    let mut name = base.as_os_str().to_os_string();
    name.push(format!(".shard-{index}-of-{count}"));
    std::path::PathBuf::from(name)
}

/// `hashgnn export --shards K`: assemble the full bundle, split it into
/// K contiguous node-range shards
/// ([`ServingBundle::split_shards`]), and write one checksummed
/// `HGNS0002` file per shard next to `out_base`. Returns the written
/// paths with their bundles for reporting.
pub fn export_sharded_to(
    manifest: &Manifest,
    store: &ParamStore,
    opts: &ExportOpts,
    shards: usize,
    out_base: &Path,
) -> Result<Vec<(std::path::PathBuf, ServingBundle)>> {
    let bundle = export_bundle(manifest, store, opts)?;
    let split = bundle.split_shards(shards)?;
    let mut out = Vec::with_capacity(split.len());
    for shard in split {
        let info = shard.shard.as_ref().expect("split_shards tags every shard");
        let path = shard_path(out_base, info.index, info.count);
        write_bundle(&shard, opts, &path)?;
        out.push((path, shard));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::spec;

    #[test]
    fn training_graph_recipes_match_tasks() {
        let sage = spec::builtin("sage_mb_coded").unwrap();
        let g = training_graph(&sage, 7).unwrap().unwrap();
        assert_eq!(g.n_nodes(), 10_000);
        assert!(g.labels().is_some());

        let fb = spec::builtin("node_fb_sgc_coded").unwrap();
        let g = training_graph(&fb, 7).unwrap().unwrap();
        assert_eq!(g.n_nodes(), 1024);

        let recon = spec::builtin("recon_c16_m32").unwrap();
        assert!(training_graph(&recon, 7).unwrap().is_none());
    }

    #[test]
    fn linkpred_serving_edges_are_the_train_split() {
        let fb = spec::builtin("link_fb_sgc_coded").unwrap();
        let g = training_graph(&fb, 3).unwrap().unwrap();
        let edges = serving_edges(&fb, &g, 3).unwrap();
        let all = g.undirected_edges();
        assert!(edges.len() < all.len(), "train split is a strict subset");
        // Same derivation as the training driver's split.
        let again = split_edges(&g, 3 ^ 0x5A5A).unwrap().train;
        assert_eq!(edges, again);
    }

    #[test]
    fn export_regenerates_codes_deterministically() {
        let m = spec::builtin("node_fb_sgc_coded").unwrap();
        let store = ParamStore::init(&m, 7);
        let opts = ExportOpts {
            coder: Coder::Hash,
            codes_file: None,
            seed: 7,
            quant: Quant::F32,
            legacy_v1: false,
        };
        let a = export_bundle(&m, &store, &opts).unwrap();
        let b = export_bundle(&m, &store, &opts).unwrap();
        assert_eq!(a.codes.as_ref().unwrap().bits, b.codes.as_ref().unwrap().bits);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.n_nodes, 1024);
        // The plain decoder demands a code file.
        let recon = spec::builtin("recon_c16_m32").unwrap();
        let rstore = ParamStore::init(&recon, 7);
        let err = export_bundle(&recon, &rstore, &opts).unwrap_err();
        assert!(format!("{err}").contains("hashgnn encode"), "{err}");
    }
}
