//! Figures 3 & 6: collision counts of median- vs zero-threshold LSH over
//! repeated trials (Appendix A protocol: same random projections per
//! trial pair, only the threshold differs — guaranteed here because both
//! arms share the trial seed).

use crate::embed::EmbeddingSet;
use crate::lsh::{collision_trials, DenseAux, Threshold};

/// One (embedding-set, bit-length) experiment: `trials` paired runs.
#[derive(Clone, Debug)]
pub struct CollisionResult {
    pub dataset: String,
    pub n_bits: usize,
    pub median: Vec<usize>,
    pub zero: Vec<usize>,
}

impl CollisionResult {
    pub fn median_avg(&self) -> f64 {
        avg(&self.median)
    }

    pub fn zero_avg(&self) -> f64 {
        avg(&self.zero)
    }
}

fn avg(xs: &[usize]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<usize>() as f64 / xs.len() as f64
}

/// Run the Appendix-A experiment on one embedding set.
pub fn run(dataset: &str, set: &EmbeddingSet, n_bits: usize, trials: usize, seed: u64) -> CollisionResult {
    let aux = DenseAux::new(&set.data, set.n, set.d);
    CollisionResult {
        dataset: dataset.to_string(),
        n_bits,
        median: collision_trials(&aux, n_bits, Threshold::Median, trials, seed),
        zero: collision_trials(&aux, n_bits, Threshold::Zero, trials, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::gaussian_mixture;

    #[test]
    fn median_wins_on_clustered_embeddings() {
        // Clustered data gives skewed projections — the regime where the
        // median threshold matters (Figure 3's observation).
        let set = gaussian_mixture(1500, 16, 6, 0.1, 3);
        let r = run("m2v*", &set, 24, 5, 11);
        assert_eq!(r.median.len(), 5);
        assert!(
            r.median_avg() <= r.zero_avg(),
            "median {} vs zero {}",
            r.median_avg(),
            r.zero_avg()
        );
    }

    #[test]
    fn more_bits_fewer_collisions() {
        let set = gaussian_mixture(800, 12, 4, 0.2, 5);
        let short = run("x", &set, 16, 3, 7);
        let long = run("x", &set, 32, 3, 7);
        assert!(long.median_avg() <= short.median_avg());
    }
}
