//! Table 1 (link-prediction rows): full-batch encoders + dot-product
//! scorer, hits@K evaluation (hits@50 for the collab analog, hits@20 for
//! the ddi analog, matching §5.2.1).
//!
//! Edge protocol: undirected edges split 80/10/10; the message-passing
//! adjacency uses **training edges only** (no leakage); negatives are
//! uniform non-edges resampled per step.
//!
//! Two encoder paths:
//! - **Full-batch** ([`run_fullbatch`]): GCN / SGC / GIN / SAGE encoders
//!   over the training-edge graph. On the native backend the adjacency is
//!   a sparse CSR bound to the model (no artifacts, no dense `n×n`); the
//!   HLO executables still take a size-guarded dense `adj` tensor.
//! - **Minibatch** ([`SageLinkBatcher`] / [`train_sage_link`]): the §4
//!   fan-out GraphSAGE encoder with the dot-product/BPR link head — the
//!   native backend's `sage_mb_link` build.

use std::sync::Arc;

use crate::cfg::{CodingCfg, GnnKind};
use crate::codes::CodeTable;
use crate::eval::link_hits_at_k;
use crate::graph::{split::split_items, Graph};
use crate::params::ParamStore;
use crate::rng::{derive_stream_seed, Rng, Xoshiro256pp};
use crate::runtime::native::par;
use crate::runtime::{Engine, Model, Tensor};
use crate::tasks::nodeclf::{adj_input, all_codes_tensor, pos_map_for, AdjInput, Frontend, RunOpts};
use crate::tasks::sage;
use crate::train::{self, BatchSource, PipeCfg, TrainLog, TrainOpts};
use crate::{Error, Result};

/// Outcome of one link-prediction cell.
#[derive(Clone, Copy, Debug)]
pub struct LinkOutcome {
    pub val_hits: f64,
    pub test_hits: f64,
    pub final_loss: f32,
}

/// Edge split (indices into the undirected edge list).
pub struct EdgeSplit {
    pub train: Vec<(u32, u32)>,
    pub val: Vec<(u32, u32)>,
    pub test: Vec<(u32, u32)>,
}

pub fn split_edges(graph: &Graph, seed: u64) -> Result<EdgeSplit> {
    let edges = graph.undirected_edges();
    let idx: Vec<u32> = (0..edges.len() as u32).collect();
    let s = split_items(&idx, 0.8, 0.1, seed)?;
    let take = |ids: &[u32]| ids.iter().map(|&i| edges[i as usize]).collect::<Vec<_>>();
    Ok(EdgeSplit { train: take(&s.train), val: take(&s.val), test: take(&s.test) })
}

fn edges_tensor(edges: &[(u32, u32)], e: usize) -> Result<Tensor> {
    // Fixed-shape buffer: pad by repeating the last edge.
    assert!(!edges.is_empty());
    let mut data = Vec::with_capacity(e * 2);
    for i in 0..e {
        let (u, v) = edges[i.min(edges.len() - 1)];
        data.push(u as i32);
        data.push(v as i32);
    }
    Tensor::i32(vec![e, 2], data)
}

fn sample_negatives(n: usize, count: usize, graph: &Graph, rng: &mut Xoshiro256pp) -> Vec<(u32, u32)> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let u = rng.index(n);
        let v = rng.index(n);
        if u != v && !graph.has_edge(u, v) {
            out.push((u as u32, v as u32));
        }
    }
    out
}

/// Run one (gnn, frontend) link-prediction cell; returns hits@k at the
/// best validation epoch.
pub fn run_fullbatch(
    engine: &Engine,
    gnn: GnnKind,
    frontend: Frontend,
    graph: &Graph,
    hits_k: usize,
    opts: RunOpts,
) -> Result<LinkOutcome> {
    let model = engine.load(&format!("link_fb_{}_{}", gnn.as_str(), frontend.artifact_tag()))?;
    run_fullbatch_model(&model, frontend, graph, hits_k, opts).map(|(out, _store)| out)
}

/// Drive one already-loaded full-batch link-prediction model (any
/// backend, any scale). Native: the training-edge graph's normalized
/// adjacency is bound as a sparse CSR; HLO: densified (size-guarded) into
/// the batch. Returns the metrics together with the best-validation
/// parameters for checkpointing/export.
pub fn run_fullbatch_model(
    model: &Model,
    frontend: Frontend,
    graph: &Graph,
    hits_k: usize,
    opts: RunOpts,
) -> Result<(LinkOutcome, ParamStore)> {
    let n = model.manifest.hyper_usize("n")?;
    if graph.n_nodes() != n {
        return Err(Error::Shape(format!("model expects n={n}, got {}", graph.n_nodes())));
    }
    let e_train = model.manifest.hyper_usize("e_train")?;
    let e_pred = model.manifest.hyper_usize("e_pred")?;
    let coding = CodingCfg::new(model.manifest.hyper_usize("c")?, model.manifest.hyper_usize("m")?)?;

    let split = split_edges(graph, opts.seed ^ 0x5A5A)?;
    // Message-passing graph: training edges only.
    let train_graph = Graph::from_edges(n, &split.train)?;
    let native = model.backend_name() == "native";
    let adj = adj_input(&train_graph, model.manifest.hyper_str("adj")?, native)?;
    let codes = all_codes_tensor(&train_graph, frontend, coding, opts.seed)?;

    let mut store = ParamStore::init(&model.manifest, opts.seed);
    let mut rng = Xoshiro256pp::seed_from_u64(opts.seed ^ 0xBEEF);

    let mut base: Vec<Tensor> = Vec::new();
    if let Some(c) = &codes {
        base.push(c.clone());
    }
    match &adj {
        AdjInput::Csr(a) => model.bind_adjacency(a.clone())?,
        AdjInput::Dense(t) => base.push(t.clone()),
    }
    if model.needs_pos_map() {
        // Degree ranks come from the message-passing (training-edge)
        // graph — the same adjacency the model propagates over.
        model.bind_pos_map(pos_map_for(&model.manifest, &train_graph)?)?;
    }

    let mut best = LinkOutcome { val_hits: f64::MIN, test_hits: 0.0, final_loss: f32::NAN };
    let mut best_store = store.clone();
    let mut last_loss = f32::NAN;
    // Pre-draw the evaluation negative pool once (shared across epochs,
    // OGB-style fixed negatives).
    let eval_negs = sample_negatives(n, e_pred, graph, &mut rng);
    for epoch in 0..opts.epochs {
        // One step per epoch: full-batch encoder + fresh edge minibatch.
        let mut pos = Vec::with_capacity(e_train);
        for _ in 0..e_train {
            pos.push(split.train[rng.index(split.train.len())]);
        }
        let neg = sample_negatives(n, e_train, graph, &mut rng);
        let mut batch = base.clone();
        batch.push(edges_tensor(&pos, e_train)?);
        batch.push(edges_tensor(&neg, e_train)?);
        last_loss = train::run_step(&model, &mut store, &batch)?;

        if (epoch + 1) % opts.eval_every == 0 || epoch + 1 == opts.epochs {
            let score = |edges: &[(u32, u32)]| -> Result<Vec<f32>> {
                let mut b = base.clone();
                b.push(edges_tensor(edges, e_pred)?);
                let t = train::predict(&model, &store, &b)?;
                Ok(t.as_f32()?[..edges.len().min(e_pred)].to_vec())
            };
            let neg_scores = score(&eval_negs)?;
            let val_hits = link_hits_at_k(&score(&split.val)?, &neg_scores, hits_k);
            let test_hits = link_hits_at_k(&score(&split.test)?, &neg_scores, hits_k);
            if val_hits > best.val_hits {
                best = LinkOutcome { val_hits, test_hits, final_loss: last_loss };
                best_store = store.clone();
            }
        }
    }
    best.final_loss = last_loss;
    Ok((best, best_store))
}

// ---------------------------------------------------------------------------
// Minibatch link prediction (§4 encoder + dot-product/BPR head)
// ---------------------------------------------------------------------------

/// Batch producer for the `sage_mb_link` executable: per step it draws
/// `batch` positive edges `(u, v)` and uniform negative nodes `w` with
/// `(u, w)` not an edge, fan-out samples all three node sets, and gathers
/// their codes — nine tensors, seeded per step so runs are deterministic
/// regardless of pipelining.
pub struct SageLinkBatcher {
    graph: Arc<Graph>,
    codes: Arc<CodeTable>,
    pos_edges: Arc<Vec<(u32, u32)>>,
    batch: usize,
    k1: usize,
    k2: usize,
    m: usize,
    seed: u64,
    /// Worker threads for per-position edge drawing + fan-out sampling.
    /// Never changes the produced tensors, only producer wall time.
    sample_threads: usize,
}

impl SageLinkBatcher {
    pub fn new(
        graph: Arc<Graph>,
        codes: Arc<CodeTable>,
        pos_edges: Arc<Vec<(u32, u32)>>,
        model: &Model,
        seed: u64,
    ) -> Result<Self> {
        if !model.manifest.hyper_bool("coded")? {
            return Err(Error::Config("SageLinkBatcher needs a coded manifest".into()));
        }
        if pos_edges.is_empty() {
            return Err(Error::Config("link training needs at least one positive edge".into()));
        }
        Ok(Self {
            batch: model.manifest.hyper_usize("batch")?,
            k1: model.manifest.hyper_usize("k1")?,
            k2: model.manifest.hyper_usize("k2")?,
            m: model.manifest.hyper_usize("m")?,
            graph,
            codes,
            pos_edges,
            seed,
            sample_threads: 1,
        })
    }

    /// Pool the per-batch edge drawing + neighbor sampling across `t`
    /// workers (0 = all cores). Output tensors are bit-identical for any
    /// `t`.
    pub fn with_sample_threads(mut self, t: usize) -> Self {
        self.sample_threads = t;
        self
    }

    /// Fan-out sample + code gather for one node set → three tensors
    /// (shared contract with the classification batcher). `seed` keys the
    /// per-position sampling streams.
    fn node_set_tensors(&self, targets: &[u32], seed: u64) -> Result<Vec<Tensor>> {
        sage::coded_fanout_tensors(
            &self.graph,
            &self.codes,
            self.k1,
            self.k2,
            self.m,
            targets,
            seed,
            self.sample_threads,
        )
    }

    /// Draw batch position `i`'s training triple on its own RNG stream:
    /// one positive edge `(u, v)` and a bounded-rejection negative `w`
    /// with `(u, w)` not an edge. `None` = no non-edge found (too dense).
    fn draw_triple(&self, root: u64, i: usize, n: usize) -> Option<(u32, u32, u32)> {
        let mut rng = Xoshiro256pp::seed_for_stream(root, i as u64);
        let (u, v) = self.pos_edges[rng.index(self.pos_edges.len())];
        // Bounded rejection sampling: a full-degree hub (or a complete
        // graph) must error instead of hanging the producer thread.
        for _ in 0..10_000 {
            let w = rng.index(n);
            if w != u as usize && !self.graph.has_edge(u as usize, w) {
                return Some((u, v, w as u32));
            }
        }
        None
    }

    fn train_batch(&self, step: u64) -> Result<Vec<Tensor>> {
        let step_seed = self.seed ^ step.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let n = self.graph.n_nodes();
        let b = self.batch;
        // Stream roots under this step: 0 = edge/negative draws,
        // 1/2/3 = the u/v/w fan-outs. Each batch position then gets its
        // own sub-stream, so the drawing can be partitioned across
        // workers without any position seeing another's RNG state.
        let neg_root = derive_stream_seed(step_seed, 0);
        let mut triples: Vec<Option<(u32, u32, u32)>> = vec![None; b];
        let t = par::resolve_threads(self.sample_threads).min(b.max(1));
        if t <= 1 {
            for (i, slot) in triples.iter_mut().enumerate() {
                *slot = self.draw_triple(neg_root, i, n);
            }
        } else {
            let chunk = b.div_ceil(t);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = triples
                .chunks_mut(chunk)
                .enumerate()
                .map(|(ci, slots)| {
                    let pos0 = ci * chunk;
                    Box::new(move || {
                        for (j, slot) in slots.iter_mut().enumerate() {
                            *slot = self.draw_triple(neg_root, pos0 + j, n);
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            par::join_all(jobs);
        }
        let mut us = Vec::with_capacity(b);
        let mut vs = Vec::with_capacity(b);
        let mut ws = Vec::with_capacity(b);
        for (i, trip) in triples.iter().enumerate() {
            let (u, v, w) = trip.ok_or_else(|| {
                // Re-derive the failing position's positive edge for the
                // error message (rare path, one cheap draw).
                let mut rng = Xoshiro256pp::seed_for_stream(neg_root, i as u64);
                let (u, _) = self.pos_edges[rng.index(self.pos_edges.len())];
                Error::Config(format!("no non-edge negative found for node {u} (graph too dense)"))
            })?;
            us.push(u);
            vs.push(v);
            ws.push(w);
        }
        let mut tensors = self.node_set_tensors(&us, derive_stream_seed(step_seed, 1))?;
        tensors.extend(self.node_set_tensors(&vs, derive_stream_seed(step_seed, 2))?);
        tensors.extend(self.node_set_tensors(&ws, derive_stream_seed(step_seed, 3))?);
        Ok(tensors)
    }
}

impl BatchSource for SageLinkBatcher {
    fn next_batch(&mut self, step: u64) -> Vec<Tensor> {
        self.train_batch(step).expect("link batch tensors")
    }
}

/// Train the minibatch link model for `n_steps` (pipelined producer).
pub fn train_sage_link(
    model: &Model,
    graph: Arc<Graph>,
    codes: Arc<CodeTable>,
    pos_edges: Arc<Vec<(u32, u32)>>,
    n_steps: u64,
    seed: u64,
    log_every: u64,
) -> Result<(ParamStore, TrainLog)> {
    train_sage_link_cfg(model, graph, codes, pos_edges, n_steps, seed, log_every, PipeCfg::default())
}

/// [`train_sage_link`] with explicit pipeline knobs. The loss curve and
/// final params are bit-identical for every `cfg` — only wall time moves.
#[allow(clippy::too_many_arguments)]
pub fn train_sage_link_cfg(
    model: &Model,
    graph: Arc<Graph>,
    codes: Arc<CodeTable>,
    pos_edges: Arc<Vec<(u32, u32)>>,
    n_steps: u64,
    seed: u64,
    log_every: u64,
    cfg: PipeCfg,
) -> Result<(ParamStore, TrainLog)> {
    let batcher = SageLinkBatcher::new(graph, codes, pos_edges, model, seed)?
        .with_sample_threads(cfg.sample_threads);
    let mut store = ParamStore::init(&model.manifest, seed);
    let mut opts = TrainOpts::new(n_steps);
    opts.log_every = log_every;
    opts.pipeline = cfg.pipeline;
    opts.prefetch = cfg.prefetch;
    let log = train::train(model, &mut store, batcher, opts)?;
    Ok((store, log))
}

/// Score `(u, v)` pairs through the minibatch encoder in fixed-size
/// batches (padding by repeating the last pair).
pub fn score_edges_mb(
    model: &Model,
    store: &ParamStore,
    graph: &Arc<Graph>,
    codes: &Arc<CodeTable>,
    edges: &[(u32, u32)],
    seed: u64,
) -> Result<Vec<f32>> {
    if edges.is_empty() {
        return Ok(Vec::new());
    }
    let batcher = SageLinkBatcher::new(
        graph.clone(),
        codes.clone(),
        Arc::new(edges.to_vec()),
        model,
        seed,
    )?;
    let b = batcher.batch;
    let mut out = Vec::with_capacity(edges.len());
    let mut start = 0usize;
    let mut batch_idx = 0u64;
    while start < edges.len() {
        let us: Vec<u32> =
            (0..b).map(|i| edges[(start + i).min(edges.len() - 1)].0).collect();
        let vs: Vec<u32> =
            (0..b).map(|i| edges[(start + i).min(edges.len() - 1)].1).collect();
        // Per-batch derived seeds (streams 2i / 2i+1), so a batch's
        // sample never depends on how many batches preceded it.
        let mut tensors = batcher.node_set_tensors(&us, derive_stream_seed(seed, 2 * batch_idx))?;
        tensors
            .extend(batcher.node_set_tensors(&vs, derive_stream_seed(seed, 2 * batch_idx + 1))?);
        batch_idx += 1;
        let scores = train::predict(model, store, &tensors)?;
        let vals = scores.as_f32()?;
        let take = (edges.len() - start).min(b);
        out.extend_from_slice(&vals[..take]);
        start += b;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm, SbmCfg};

    #[test]
    fn edge_split_partitions() {
        let g = sbm(SbmCfg::new(300, 3, 8.0, 2.0), 1).unwrap();
        let s = split_edges(&g, 2).unwrap();
        let total = g.undirected_edges().len();
        assert_eq!(s.train.len() + s.val.len() + s.test.len(), total);
        assert!(s.train.len() > s.val.len());
    }

    #[test]
    fn negatives_are_nonedges() {
        let g = sbm(SbmCfg::new(100, 2, 6.0, 2.0), 3).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        for (u, v) in sample_negatives(100, 50, &g, &mut rng) {
            assert!(!g.has_edge(u as usize, v as usize));
            assert_ne!(u, v);
        }
    }

    #[test]
    fn edge_tensor_pads() {
        let t = edges_tensor(&[(1, 2), (3, 4)], 4).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3, 4, 3, 4, 3, 4]);
    }

    #[test]
    fn link_batcher_shapes_and_determinism() {
        use crate::codes::random_codes;
        use crate::runtime::native::spec::SageMbBuild;

        let manifest = SageMbBuild {
            name: "link_t".into(),
            coded: true,
            link: true,
            n: 120,
            n_classes: 2,
            d_e: 4,
            hidden: 6,
            batch: 8,
            k1: 3,
            k2: 2,
            c: 4,
            m: 3,
            d_c: 5,
            d_m: 6,
            l: 2,
            light: false,
            optim: crate::cfg::OptimCfg::adamw_gnn(),
        }
        .manifest();
        let model = Model::native(manifest, 1).unwrap();
        let g = Arc::new(sbm(SbmCfg::new(120, 3, 8.0, 2.0), 2).unwrap());
        let codes = Arc::new(random_codes(120, CodingCfg::new(4, 3).unwrap(), 5));
        let edges = Arc::new(g.undirected_edges());
        let mut batcher =
            SageLinkBatcher::new(g.clone(), codes, edges, &model, 11).unwrap();
        let b = batcher.train_batch(0).unwrap();
        assert_eq!(b.len(), 9);
        for set in 0..3 {
            assert_eq!(b[set * 3].shape(), &[8, 3]);
            assert_eq!(b[set * 3 + 1].shape(), &[8 * 3, 3]);
            assert_eq!(b[set * 3 + 2].shape(), &[8 * 3 * 2, 3]);
        }
        let again = batcher.next_batch(0);
        assert_eq!(b[0], again[0]);
        assert_eq!(b[8], again[8]);
        let different = batcher.next_batch(1);
        assert_ne!(b[0], different[0]);
        // Pooled edge drawing + sampling produces the exact same tensors.
        for t in [2usize, 8] {
            let mut pooled = SageLinkBatcher::new(
                batcher.graph.clone(),
                batcher.codes.clone(),
                batcher.pos_edges.clone(),
                &model,
                11,
            )
            .unwrap()
            .with_sample_threads(t);
            for step in [0u64, 1, 3] {
                assert_eq!(batcher.next_batch(step), pooled.next_batch(step), "t={t}");
            }
        }
    }
}
