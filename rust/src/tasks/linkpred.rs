//! Table 1 (link-prediction rows): full-batch encoders + dot-product
//! scorer, hits@K evaluation (hits@50 for the collab analog, hits@20 for
//! the ddi analog, matching §5.2.1).
//!
//! Edge protocol: undirected edges split 80/10/10; the message-passing
//! adjacency uses **training edges only** (no leakage); negatives are
//! uniform non-edges resampled per step.

use crate::cfg::{CodingCfg, GnnKind};
use crate::eval::link_hits_at_k;
use crate::graph::{split::split_items, Graph};
use crate::params::ParamStore;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{Engine, Tensor};
use crate::tasks::nodeclf::{adj_tensor, all_codes_tensor, Frontend, RunOpts};
use crate::train;
use crate::{Error, Result};

/// Outcome of one link-prediction cell.
#[derive(Clone, Copy, Debug)]
pub struct LinkOutcome {
    pub val_hits: f64,
    pub test_hits: f64,
    pub final_loss: f32,
}

/// Edge split (indices into the undirected edge list).
pub struct EdgeSplit {
    pub train: Vec<(u32, u32)>,
    pub val: Vec<(u32, u32)>,
    pub test: Vec<(u32, u32)>,
}

pub fn split_edges(graph: &Graph, seed: u64) -> Result<EdgeSplit> {
    let edges = graph.undirected_edges();
    let idx: Vec<u32> = (0..edges.len() as u32).collect();
    let s = split_items(&idx, 0.8, 0.1, seed)?;
    let take = |ids: &[u32]| ids.iter().map(|&i| edges[i as usize]).collect::<Vec<_>>();
    Ok(EdgeSplit { train: take(&s.train), val: take(&s.val), test: take(&s.test) })
}

fn edges_tensor(edges: &[(u32, u32)], e: usize) -> Result<Tensor> {
    // Fixed-shape buffer: pad by repeating the last edge.
    assert!(!edges.is_empty());
    let mut data = Vec::with_capacity(e * 2);
    for i in 0..e {
        let (u, v) = edges[i.min(edges.len() - 1)];
        data.push(u as i32);
        data.push(v as i32);
    }
    Tensor::i32(vec![e, 2], data)
}

fn sample_negatives(n: usize, count: usize, graph: &Graph, rng: &mut Xoshiro256pp) -> Vec<(u32, u32)> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let u = rng.index(n);
        let v = rng.index(n);
        if u != v && !graph.has_edge(u, v) {
            out.push((u as u32, v as u32));
        }
    }
    out
}

/// Run one (gnn, frontend) link-prediction cell; returns hits@k at the
/// best validation epoch.
pub fn run_fullbatch(
    engine: &Engine,
    gnn: GnnKind,
    frontend: Frontend,
    graph: &Graph,
    hits_k: usize,
    opts: RunOpts,
) -> Result<LinkOutcome> {
    let model = engine.load(&format!("link_fb_{}_{}", gnn.as_str(), frontend.artifact_tag()))?;
    let n = model.manifest.hyper_usize("n")?;
    if graph.n_nodes() != n {
        return Err(Error::Shape(format!("artifact expects n={n}, got {}", graph.n_nodes())));
    }
    let e_train = model.manifest.hyper_usize("e_train")?;
    let e_pred = model.manifest.hyper_usize("e_pred")?;
    let coding = CodingCfg::new(model.manifest.hyper_usize("c")?, model.manifest.hyper_usize("m")?)?;

    let split = split_edges(graph, opts.seed ^ 0x5A5A)?;
    // Message-passing graph: training edges only.
    let train_graph = Graph::from_edges(n, &split.train)?;
    let adj = adj_tensor(&train_graph, model.manifest.hyper_str("adj")?)?;
    let codes = all_codes_tensor(&train_graph, frontend, coding, opts.seed)?;

    let mut store = ParamStore::init(&model.manifest, opts.seed);
    let mut rng = Xoshiro256pp::seed_from_u64(opts.seed ^ 0xBEEF);

    let base: Vec<Tensor> = match &codes {
        Some(c) => vec![c.clone(), adj.clone()],
        None => vec![adj.clone()],
    };

    let mut best = LinkOutcome { val_hits: f64::MIN, test_hits: 0.0, final_loss: f32::NAN };
    let mut last_loss = f32::NAN;
    // Pre-draw the evaluation negative pool once (shared across epochs,
    // OGB-style fixed negatives).
    let eval_negs = sample_negatives(n, e_pred, graph, &mut rng);
    for epoch in 0..opts.epochs {
        // One step per epoch: full-batch encoder + fresh edge minibatch.
        let mut pos = Vec::with_capacity(e_train);
        for _ in 0..e_train {
            pos.push(split.train[rng.index(split.train.len())]);
        }
        let neg = sample_negatives(n, e_train, graph, &mut rng);
        let mut batch = base.clone();
        batch.push(edges_tensor(&pos, e_train)?);
        batch.push(edges_tensor(&neg, e_train)?);
        last_loss = train::run_step(&model, &mut store, &batch)?;

        if (epoch + 1) % opts.eval_every == 0 || epoch + 1 == opts.epochs {
            let score = |edges: &[(u32, u32)]| -> Result<Vec<f32>> {
                let mut b = base.clone();
                b.push(edges_tensor(edges, e_pred)?);
                let t = train::predict(&model, &store, &b)?;
                Ok(t.as_f32()?[..edges.len().min(e_pred)].to_vec())
            };
            let neg_scores = score(&eval_negs)?;
            let val_hits = link_hits_at_k(&score(&split.val)?, &neg_scores, hits_k);
            let test_hits = link_hits_at_k(&score(&split.test)?, &neg_scores, hits_k);
            if val_hits > best.val_hits {
                best = LinkOutcome { val_hits, test_hits, final_loss: last_loss };
            }
        }
    }
    best.final_loss = last_loss;
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm, SbmCfg};

    #[test]
    fn edge_split_partitions() {
        let g = sbm(SbmCfg::new(300, 3, 8.0, 2.0), 1).unwrap();
        let s = split_edges(&g, 2).unwrap();
        let total = g.undirected_edges().len();
        assert_eq!(s.train.len() + s.val.len() + s.test.len(), total);
        assert!(s.train.len() > s.val.len());
    }

    #[test]
    fn negatives_are_nonedges() {
        let g = sbm(SbmCfg::new(100, 2, 6.0, 2.0), 3).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        for (u, v) in sample_negatives(100, 50, &g, &mut rng) {
            assert!(!g.has_edge(u as usize, v as usize));
            assert_ne!(u, v);
        }
    }

    #[test]
    fn edge_tensor_pads() {
        let t = edges_tensor(&[(1, 2), (3, 4)], 4).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[1, 2, 3, 4, 3, 4, 3, 4]);
    }
}
