//! Experiment drivers — one per family of paper results.
//!
//! | module | paper artifact |
//! |---|---|
//! | [`coding`]     | shared coder dispatch (random / hash / learned) |
//! | [`recon`]      | Figure 1, Table 5 (reconstruction proxy tasks) |
//! | [`collisions`] | Figures 3 and 6 (median vs zero threshold) |
//! | [`nodeclf`]    | Table 1 node-classification rows |
//! | [`linkpred`]   | Table 1 link-prediction rows |
//! | [`sage`]       | minibatch GraphSAGE pipeline (§4, e2e example) |
//! | [`frontier`]   | accuracy-vs-bytes sweep over the front-end family |
//! | [`merchant`]   | Table 3 (§5.3 merchant-category identification) |
//! | [`memory`]     | Tables 2, 4 and 6 (memory accounting) |
//! | [`serve`]      | serving-bundle export (§1/§4 deployment payoff) |

pub mod coding;
pub mod collisions;
pub mod frontier;
pub mod linkpred;
pub mod memory;
pub mod merchant;
pub mod nodeclf;
pub mod recon;
pub mod sage;
pub mod serve;

use crate::graph::{generate, Graph};
use crate::Result;

/// Synthetic analogs of the five OGB datasets used in Table 1
/// (DESIGN.md §4). All share `n = 1024` so one artifact set serves every
/// dataset; they differ in density, community strength and class count
/// the way the originals differ in character.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum T1Dataset {
    /// ogbn-arxiv analog: moderate density, clear communities.
    Arxiv,
    /// ogbn-mag analog: sparse, weaker communities (hardest).
    Mag,
    /// ogbn-products analog: dense, strong communities.
    Products,
    /// ogbl-collab analog: community graph for link prediction.
    Collab,
    /// ogbl-ddi analog: dense link-prediction graph.
    Ddi,
}

impl T1Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            T1Dataset::Arxiv => "ogbn-arxiv*",
            T1Dataset::Mag => "ogbn-mag*",
            T1Dataset::Products => "ogbn-products*",
            T1Dataset::Collab => "ogbl-collab*",
            T1Dataset::Ddi => "ogbl-ddi*",
        }
    }

    pub fn is_linkpred(&self) -> bool {
        matches!(self, T1Dataset::Collab | T1Dataset::Ddi)
    }

    pub fn nodeclf_all() -> [T1Dataset; 3] {
        [T1Dataset::Arxiv, T1Dataset::Mag, T1Dataset::Products]
    }

    pub fn linkpred_all() -> [T1Dataset; 2] {
        [T1Dataset::Collab, T1Dataset::Ddi]
    }

    /// Generate the graph (n=1024, labels for node-clf datasets).
    pub fn generate(&self, seed: u64) -> Result<Graph> {
        let n = 1024;
        match self {
            T1Dataset::Arxiv => generate::sbm(generate::SbmCfg::new(n, 8, 10.0, 2.5), seed),
            T1Dataset::Mag => generate::sbm(generate::SbmCfg::new(n, 8, 6.0, 3.0), seed),
            T1Dataset::Products => generate::sbm(generate::SbmCfg::new(n, 8, 16.0, 2.0), seed),
            T1Dataset::Collab => generate::sbm(generate::SbmCfg::new(n, 8, 12.0, 2.0), seed),
            T1Dataset::Ddi => generate::sbm(generate::SbmCfg::new(n, 4, 20.0, 6.0), seed),
        }
    }
}
