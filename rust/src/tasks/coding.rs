//! Coder dispatch: produce a [`CodeTable`] for a set of entities with any
//! of the paper's three coding schemes.
//!
//! - **random** — ALONE baseline, no auxiliary information;
//! - **hash** — Algorithm 1 over either the graph adjacency
//!   ("hashing/graph" in Figure 1) or pre-trained embeddings
//!   ("hashing/pre-trained");
//! - **learned** — the autoencoder baseline, which needs pre-trained
//!   embeddings and a trained encoder (handled by [`recon`]'s AE path,
//!   not here — it is the only coder with a training stage, exactly the
//!   property the paper's method avoids).

use crate::cfg::{Coder, CodingCfg, EncodeCfg};
use crate::codes::{random_codes, CodeTable};
use crate::graph::Graph;
use crate::lsh::{self, DenseAux, Threshold};
use crate::{Error, Result};

/// Auxiliary information available to the coder.
pub enum Aux<'a> {
    /// Graph adjacency rows (the production path; works with no
    /// pre-training whatsoever).
    Graph(&'a Graph),
    /// Pre-trained embeddings (Figure-1 proxy path).
    Dense { data: &'a [f32], n: usize, d: usize },
    /// Nothing (only valid for the random coder).
    None { n: usize },
}

impl<'a> Aux<'a> {
    pub fn n(&self) -> usize {
        match self {
            Aux::Graph(g) => g.n_nodes(),
            Aux::Dense { n, .. } => *n,
            Aux::None { n } => *n,
        }
    }
}

/// Produce codes for all `aux.n()` entities, using all available cores
/// for the hash coder (output is independent of the thread count — see
/// [`lsh::encode_with`]).
pub fn make_codes(aux: &Aux, coder: Coder, coding: CodingCfg, seed: u64) -> Result<CodeTable> {
    make_codes_with(aux, coder, coding, seed, EncodeCfg::default())
}

/// [`make_codes`] under an explicit encode execution plan (CLI `--threads`
/// / `--block-bits`). The plan only affects speed, never the codes.
pub fn make_codes_with(
    aux: &Aux,
    coder: Coder,
    coding: CodingCfg,
    seed: u64,
    plan: EncodeCfg,
) -> Result<CodeTable> {
    match coder {
        Coder::Random => Ok(random_codes(aux.n(), coding, seed)),
        Coder::Hash => match aux {
            Aux::Graph(g) => lsh::encode_with(g.adj(), coding, Threshold::Median, seed, plan),
            Aux::Dense { data, n, d } => {
                let dense = DenseAux::new(data, *n, *d);
                lsh::encode_with(&dense, coding, Threshold::Median, seed, plan)
            }
            Aux::None { .. } => {
                Err(Error::Config("hash coder requires auxiliary information".into()))
            }
        },
        Coder::Learned => Err(Error::Config(
            "learned coder needs a trained autoencoder — use tasks::recon::learned_codes".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::barabasi_albert;

    #[test]
    fn random_needs_no_aux() {
        let t = make_codes(&Aux::None { n: 50 }, Coder::Random, CodingCfg::new(4, 8).unwrap(), 1)
            .unwrap();
        assert_eq!(t.n(), 50);
    }

    #[test]
    fn hash_over_graph() {
        let g = barabasi_albert(100, 3, 2).unwrap();
        let t =
            make_codes(&Aux::Graph(&g), Coder::Hash, CodingCfg::new(16, 8).unwrap(), 3).unwrap();
        assert_eq!(t.n(), 100);
        assert_eq!(t.coding.n_bits(), 32);
    }

    #[test]
    fn hash_codes_independent_of_plan() {
        let g = barabasi_albert(150, 3, 5).unwrap();
        let coding = CodingCfg::new(16, 8).unwrap();
        let base = make_codes(&Aux::Graph(&g), Coder::Hash, coding, 3).unwrap();
        for threads in [1usize, 4] {
            let t = make_codes_with(
                &Aux::Graph(&g),
                Coder::Hash,
                coding,
                3,
                EncodeCfg::new(threads, 8),
            )
            .unwrap();
            assert_eq!(base.bits, t.bits, "threads={threads}");
        }
    }

    #[test]
    fn hash_without_aux_rejected() {
        let r = make_codes(&Aux::None { n: 10 }, Coder::Hash, CodingCfg::new(4, 8).unwrap(), 1);
        assert!(r.is_err());
    }

    #[test]
    fn learned_redirects() {
        let r = make_codes(&Aux::None { n: 10 }, Coder::Learned, CodingCfg::new(4, 8).unwrap(), 1);
        assert!(r.is_err());
    }
}
