//! §5.3 — merchant-category identification (Table 3).
//!
//! The paper runs GraphSAGE + compressed embeddings on a 17.9M-node
//! consumer–merchant transaction graph with 651 Zipf-imbalanced
//! categories; the NC baseline cannot run at that scale at all. Here the
//! graph is the synthetic bipartite analog (DESIGN.md §4) at the scale the
//! `merchant` artifact was exported for, and the pipeline is identical:
//! bit-packed codes from adjacency LSH → minibatch SAGE → acc / hit@k
//! on the merchant test split. With no artifacts present the engine
//! resolves `merchant` to the native backend's synthesized build at the
//! same scale, so the whole §5.3 pipeline runs offline.

use std::sync::Arc;

use crate::cfg::{Coder, CodingCfg};
use crate::graph::generate::{bipartite_transactions, BipartiteGraph};
use crate::graph::split::split_items;
use crate::runtime::{Engine, Model};
use crate::tasks::coding::{make_codes, Aux};
use crate::tasks::sage::{self, Features, SageMetrics, SageTask};
use crate::Result;

/// Table 3 rows: one per coder.
#[derive(Clone, Copy, Debug)]
pub struct MerchantOutcome {
    pub coder: Coder,
    pub metrics: SageMetrics,
}

/// Build the transaction graph matching the `merchant` artifact's `n`
/// (2/3 consumers, 1/3 merchants).
pub fn build_graph(model: &Model, seed: u64) -> Result<BipartiteGraph> {
    let n = model.manifest.hyper_usize("n")?;
    let n_categories = model.manifest.hyper_usize("n_classes")?;
    let n_merchants = n / 3;
    let n_consumers = n - n_merchants;
    bipartite_transactions(n_consumers, n_merchants, n_categories, 8.0, seed)
}

/// Run one coder arm of Table 3.
pub fn run(
    engine: &Engine,
    bip: &BipartiteGraph,
    coder: Coder,
    epochs: usize,
    seed: u64,
) -> Result<MerchantOutcome> {
    let model = engine.load("merchant")?;
    let coding = CodingCfg::new(
        model.manifest.hyper_usize("c")?,
        model.manifest.hyper_usize("m")?,
    )?;
    let codes = make_codes(&Aux::Graph(&bip.graph), coder, coding, seed)?;

    // Merchant node ids and labels.
    let merchant_ids: Vec<u32> =
        (0..bip.n_merchants as u32).map(|m| bip.n_consumers as u32 + m).collect();
    let labels = sage::full_label_vec(bip.graph.n_nodes(), &merchant_ids, &bip.merchant_category)?;

    // 70/10/20 merchant split (§5.3.1).
    let split = split_items(&merchant_ids, 0.7, 0.1, seed ^ 0x77)?;

    let task = SageTask {
        graph: Arc::new(bip.graph.clone()),
        labels: Arc::new(labels),
        features: Features::Codes(Arc::new(codes)),
        train_nodes: Arc::new(split.train.clone()),
    };
    let run = sage::train_sage(&model, task, epochs, &split.val, seed, 0)?;

    // Final metrics on the held-out test merchants with best-val params.
    let batcher = sage::SageBatcher::new(
        SageTask {
            graph: Arc::new(bip.graph.clone()),
            labels: Arc::new(sage::full_label_vec(
                bip.graph.n_nodes(),
                &merchant_ids,
                &bip.merchant_category,
            )?),
            features: Features::Codes(Arc::new(make_codes(
                &Aux::Graph(&bip.graph),
                coder,
                coding,
                seed,
            )?)),
            train_nodes: Arc::new(split.train),
        },
        &model,
        seed,
    )?;
    let metrics = sage::evaluate(&model, &run.store, &batcher, &split.test, seed ^ 0x1234)?;
    Ok(MerchantOutcome { coder, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merchant_ids_are_the_second_block() {
        let bip = bipartite_transactions(60, 30, 4, 4.0, 1).unwrap();
        let ids: Vec<u32> = (0..30u32).map(|m| 60 + m).collect();
        let labels = sage::full_label_vec(90, &ids, &bip.merchant_category).unwrap();
        for (i, &cat) in bip.merchant_category.iter().enumerate() {
            assert_eq!(labels[60 + i], cat);
        }
    }
}
