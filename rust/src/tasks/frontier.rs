//! Accuracy-vs-bytes frontier over the compressed embedding front-ends.
//!
//! Trains the same full-batch GNN on the same Table-1 SBM analog once per
//! front-end — the paper's LSH coding (`hash`), the uncompressed `nc`
//! baseline, and the three hash-embedding competitors (`multihash`,
//! `bloom`, `poshash`) — at **matched byte budgets** (every hash
//! front-end is sized bytes-fair against the §3.2 coded front-end, see
//! [`crate::runtime::native::spec::HashFrontEnd::budget_matched`]). Emits
//! one `(coder, bytes, acc)` row per front-end: the accuracy-per-byte
//! frontier the `hashgnn frontier` verb writes as JSON.

use crate::cfg::GnnKind;
use crate::runtime::native::{front_end_name, spec};
use crate::runtime::{Manifest, Model};
use crate::ser::Json;
use crate::tasks::nodeclf::{self, Frontend, RunOpts};
use crate::tasks::T1Dataset;
use crate::{Error, Result};

/// One frontier sweep: which coders, which GNN, which Table-1 analog,
/// and the shared training protocol.
#[derive(Clone, Debug)]
pub struct FrontierOpts {
    /// Front-ends to sweep, in output order.
    pub coders: Vec<Frontend>,
    pub gnn: GnnKind,
    pub dataset: T1Dataset,
    pub run: RunOpts,
    pub threads: usize,
}

impl Default for FrontierOpts {
    fn default() -> Self {
        Self {
            coders: Frontend::frontier().to_vec(),
            gnn: GnnKind::Gin,
            dataset: T1Dataset::Arxiv,
            run: RunOpts::default(),
            threads: 1,
        }
    }
}

impl FrontierOpts {
    /// CI smoke configuration: two coders (one table-based, one hashed),
    /// a short epoch budget, everything else at defaults.
    pub fn quick() -> Self {
        Self {
            coders: vec![Frontend::Nc, Frontend::Bloom],
            run: RunOpts { epochs: 10, eval_every: 5, seed: 7 },
            ..Self::default()
        }
    }
}

/// One frontier point: a trained front-end's byte cost and accuracy.
#[derive(Clone, Debug)]
pub struct FrontierRow {
    /// CLI coder label (`hash` / `nc` / `multihash` / …).
    pub coder: String,
    /// The manifest's `front_end` hyper (`coded` for hash/random).
    pub front_end: String,
    /// Front-end bytes: 4·(front-end f32 params) + packed code bytes.
    pub bytes: usize,
    /// Test accuracy at the best-validation epoch.
    pub acc: f64,
    /// Best validation accuracy.
    pub val: f64,
    /// Final training loss.
    pub loss: f32,
}

/// The CLI-facing `--coders` label for a frontend (inverse of
/// [`Frontend::parse_coder`]'s canonical spellings).
pub fn coder_label(fe: Frontend) -> &'static str {
    match fe {
        Frontend::Nc => "nc",
        Frontend::Rand => "random",
        Frontend::Hash => "hash",
        Frontend::MultiHash => "multihash",
        Frontend::Bloom => "bloom",
        Frontend::PosHash => "poshash",
    }
}

/// Parse a comma-separated `--coders` list (e.g. `hash,nc,bloom`).
pub fn parse_coders(s: &str) -> Result<Vec<Frontend>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            Frontend::parse_coder(t).ok_or_else(|| {
                Error::Config(format!(
                    "unknown coder '{t}' (expected nc / hash / random / multihash / bloom / poshash)"
                ))
            })
        })
        .collect()
}

/// Bytes a trained model's feature front-end costs at serving time:
/// 4 bytes per front-end f32 parameter (`embed.table`, `dec.*`,
/// `hemb.*`), plus the bit-packed `(n, m)` code table for the coded
/// front-end. GNN/head parameters are excluded — they are identical
/// across the sweep.
pub fn frontend_bytes(manifest: &Manifest) -> Result<usize> {
    let fe = front_end_name(manifest)?;
    let f32s: usize = manifest
        .params
        .iter()
        .filter(|p| {
            p.name == "embed.table" || p.name.starts_with("dec.") || p.name.starts_with("hemb.")
        })
        .map(|p| p.n_elements())
        .sum();
    let mut bytes = 4 * f32s;
    if fe == "coded" {
        let n = manifest.hyper_usize("n")?;
        let m = manifest.hyper_usize("m")?;
        let c = manifest.hyper_usize("c")?;
        let code_bits = (usize::BITS - (c.max(2) - 1).leading_zeros()) as usize;
        bytes += (n * m * code_bits).div_ceil(8);
    }
    Ok(bytes)
}

/// Run the sweep: one full-batch training run per coder on a shared
/// graph, returning rows in the requested coder order.
pub fn run_frontier(opts: &FrontierOpts) -> Result<Vec<FrontierRow>> {
    if opts.coders.is_empty() {
        return Err(Error::Config("frontier sweep needs at least one coder".into()));
    }
    if opts.dataset.is_linkpred() {
        return Err(Error::Config(format!(
            "frontier sweeps the node-classification analogs; '{}' is a link-prediction graph",
            opts.dataset.name()
        )));
    }
    let graph = opts.dataset.generate(opts.run.seed)?;
    let mut rows = Vec::with_capacity(opts.coders.len());
    for &fe in &opts.coders {
        let name = format!("node_fb_{}_{}", opts.gnn.as_str(), fe.artifact_tag());
        let manifest = spec::builtin(&name)
            .ok_or_else(|| Error::Config(format!("no builtin model '{name}'")))?;
        let bytes = frontend_bytes(&manifest)?;
        let model = Model::native(manifest, opts.threads)?;
        let (out, _store) = nodeclf::run_fullbatch_model(&model, fe, &graph, opts.run)?;
        rows.push(FrontierRow {
            coder: coder_label(fe).to_string(),
            front_end: fe.artifact_tag().to_string(),
            bytes,
            acc: out.test,
            val: out.val,
            loss: out.final_loss,
        });
    }
    Ok(rows)
}

/// Serialize a sweep as the `frontier` JSON artifact: run metadata plus
/// one row object per coder.
pub fn rows_to_json(rows: &[FrontierRow], opts: &FrontierOpts) -> Json {
    Json::obj(vec![
        ("bench", Json::str("frontier")),
        ("dataset", Json::str(opts.dataset.name())),
        ("gnn", Json::str(opts.gnn.as_str())),
        ("epochs", Json::num(opts.run.epochs as f64)),
        ("seed", Json::num(opts.run.seed as f64)),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("coder", Json::str(r.coder.as_str())),
                            ("front_end", Json::str(r.front_end.as_str())),
                            ("bytes", Json::num(r.bytes as f64)),
                            ("acc", Json::num(r.acc)),
                            ("val", Json::num(r.val)),
                            ("loss", Json::num(r.loss as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_coders_accepts_the_full_frontier_list() {
        let coders = parse_coders("hash, nc,multihash,bloom,poshash").unwrap();
        assert_eq!(coders.len(), 5);
        assert_eq!(coders[0], Frontend::Hash);
        assert_eq!(coders[4], Frontend::PosHash);
        assert!(parse_coders("hash,quantum").is_err());
        assert!(parse_coders("").unwrap().is_empty());
    }

    #[test]
    fn frontend_bytes_are_budget_matched_across_the_family() {
        // The coded front-end sets the budget; every hash front-end must
        // land at or (by at most one pool row) under it. NC is just the
        // raw `n·d_e` table.
        let coded = frontend_bytes(&spec::builtin("node_fb_gin_coded").unwrap()).unwrap();
        let nc = frontend_bytes(&spec::builtin("node_fb_gin_nc").unwrap()).unwrap();
        assert_eq!(nc, 4 * 1024 * 64);
        for tag in ["multihash", "bloom", "poshash"] {
            let b = frontend_bytes(&spec::builtin(&format!("node_fb_gin_{tag}")).unwrap()).unwrap();
            assert!(b <= coded, "{tag}: {b} > coded budget {coded}");
            assert!(b > coded / 2, "{tag}: {b} wastes more than half the budget {coded}");
        }
    }
}
