//! Table 1 (node classification rows): full-batch GCN / SGC / GIN / SAGE
//! with NC (explicit embedding table), Rand (ALONE codes) and Hash
//! (Algorithm 1 over the adjacency) feature front-ends.
//!
//! Protocol (Appendix C.1): AdamW lr=0.01, train to a fixed epoch budget,
//! evaluate every few epochs on the validation split and report the test
//! metric from the best-validation epoch.
//!
//! Both backends run the full grid. The native path propagates over a
//! **sparse CSR adjacency** bound to the model
//! ([`crate::runtime::Model::bind_adjacency`]) — no `n×n` buffer ever
//! exists, so it scales to graphs far beyond what dense adjacency allows.
//! The HLO path still consumes a dense `adj` tensor and is size-guarded
//! by [`DENSE_ADJ_MAX_NODES`]; [`adj_input`] picks the right form.

use std::sync::Arc;

use crate::cfg::{CodingCfg, Coder, GnnKind};
use crate::eval::accuracy_from_logits;
use crate::graph::{split_nodes, Graph, Split};
use crate::params::ParamStore;
use crate::runtime::{Engine, Model, Tensor};
use crate::sparse::Csr;
use crate::tasks::coding::{make_codes, Aux};
use crate::train;
use crate::{Error, Result};

/// Which feature front-end (Table 1 columns, plus the hash-embedding
/// family the accuracy-vs-bytes frontier compares against).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frontend {
    /// "NC": explicit trainable embedding table (no compression).
    Nc,
    /// "Rand": ALONE random coding.
    Rand,
    /// "Hash": the paper's LSH coding over the adjacency matrix.
    Hash,
    /// Svenstrup-style multi-hash pool + learned importance weights.
    MultiHash,
    /// Bloom-filter-style multi-hash bucket sum + ReLU.
    Bloom,
    /// Kalantzi & Karypis position-based hash embeddings (degree-rank
    /// bucket map bound to the model).
    PosHash,
}

impl Frontend {
    pub fn name(&self) -> &'static str {
        match self {
            Frontend::Nc => "NC",
            Frontend::Rand => "Rand",
            Frontend::Hash => "Hash",
            Frontend::MultiHash => "MultiHash",
            Frontend::Bloom => "Bloom",
            Frontend::PosHash => "PosHash",
        }
    }

    /// The original Table-1 columns (the paper's own grid).
    pub fn all() -> [Frontend; 3] {
        [Frontend::Nc, Frontend::Rand, Frontend::Hash]
    }

    /// The frontier sweep's coder set: the paper's LSH front-end, the
    /// uncompressed baseline, and the three hash-embedding competitors.
    pub fn frontier() -> [Frontend; 5] {
        [Frontend::Hash, Frontend::Nc, Frontend::MultiHash, Frontend::Bloom, Frontend::PosHash]
    }

    /// The registry-name tag (`node_fb_{gnn}_{tag}`) and `front_end`
    /// hyper value this frontend trains.
    pub fn artifact_tag(&self) -> &'static str {
        match self {
            Frontend::Nc => "nc",
            Frontend::Rand | Frontend::Hash => "coded",
            Frontend::MultiHash => "multihash",
            Frontend::Bloom => "bloom",
            Frontend::PosHash => "poshash",
        }
    }

    /// Parse a `--coders` entry (`hash`/`random`/`nc`/`multihash`/…).
    pub fn parse_coder(s: &str) -> Option<Frontend> {
        match s {
            "nc" | "none" => Some(Frontend::Nc),
            "hash" | "lsh" => Some(Frontend::Hash),
            "random" | "rand" => Some(Frontend::Rand),
            "multihash" => Some(Frontend::MultiHash),
            "bloom" => Some(Frontend::Bloom),
            "poshash" => Some(Frontend::PosHash),
            _ => None,
        }
    }

    fn coder(&self) -> Option<Coder> {
        match self {
            Frontend::Rand => Some(Coder::Random),
            Frontend::Hash => Some(Coder::Hash),
            _ => None,
        }
    }
}

/// Degree-rank position map for a poshash model over this graph (bucket
/// count from the manifest's `hemb_bp`), ready for
/// [`crate::runtime::Model::bind_pos_map`].
pub fn pos_map_for(
    manifest: &crate::runtime::Manifest,
    graph: &Graph,
) -> Result<Arc<Vec<u32>>> {
    let bp = manifest.hyper_usize("hemb_bp")?;
    let degrees: Vec<usize> = (0..graph.n_nodes()).map(|v| graph.degree(v)).collect();
    Ok(Arc::new(crate::runtime::native::hashemb::degree_pos_map(&degrees, bp)))
}

/// Run options for one Table-1 cell.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub epochs: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self { epochs: 60, eval_every: 5, seed: 7 }
    }
}

/// Outcome of one (gnn, frontend, dataset) cell.
#[derive(Clone, Copy, Debug)]
pub struct CellOutcome {
    pub val: f64,
    pub test: f64,
    pub final_loss: f32,
}

/// Largest graph the HLO path may densify: beyond this, a dense `(n, n)`
/// f32 adjacency is the kind of allocation the paper's large-scale premise
/// forbids (4096² is already 64 MiB — per *input tensor copy*).
pub const DENSE_ADJ_MAX_NODES: usize = 4096;

/// Adjacency in the form the executing backend consumes.
pub enum AdjInput {
    /// Sparse CSR for the native backend — bound to the model via
    /// [`Model::bind_adjacency`], never materialized dense.
    Csr(Arc<Csr>),
    /// Dense `(n, n)` tensor for the HLO executables (size-guarded).
    Dense(Tensor),
}

/// Build the adjacency in the normalization the model expects (manifest
/// hyper `adj`), in the backend's preferred form. The native path always
/// stays sparse; the dense HLO form errors clearly above
/// [`DENSE_ADJ_MAX_NODES`] instead of silently allocating `n²` floats.
pub fn adj_input(graph: &Graph, adj_kind: &str, native: bool) -> Result<AdjInput> {
    let adj = graph.adj().normalized(adj_kind)?;
    if native {
        return Ok(AdjInput::Csr(Arc::new(adj)));
    }
    let n = graph.n_nodes();
    if n > DENSE_ADJ_MAX_NODES {
        return Err(Error::Config(format!(
            "the HLO full-batch path would materialize a dense {n}×{n} adjacency \
             ({:.2} GiB); the guard is {DENSE_ADJ_MAX_NODES} nodes — use \
             `--backend native`, which propagates over the sparse CSR",
            (n as f64) * (n as f64) * 4.0 / (1u64 << 30) as f64
        )));
    }
    Tensor::f32(vec![n, n], adj.to_dense()).map(AdjInput::Dense)
}

/// Gather all-node integer codes as the `(n, m)` input tensor.
pub fn all_codes_tensor(
    graph: &Graph,
    frontend: Frontend,
    coding: CodingCfg,
    seed: u64,
) -> Result<Option<Tensor>> {
    let Some(coder) = frontend.coder() else { return Ok(None) };
    let table = make_codes(&Aux::Graph(graph), coder, coding, seed)?;
    let n = graph.n_nodes();
    let ids: Vec<u32> = (0..n as u32).collect();
    let mut buf = Vec::new();
    table.gather_int_codes(&ids, &mut buf);
    Ok(Some(Tensor::i32(vec![n, coding.m], buf)?))
}

/// One full-batch node-classification run; returns val/test accuracy at
/// the best validation epoch. Resolves the Table-1 cell's model through
/// the engine's backend policy, then delegates to [`run_fullbatch_model`]
/// (whose trained parameters this convenience wrapper discards).
pub fn run_fullbatch(
    engine: &Engine,
    gnn: GnnKind,
    frontend: Frontend,
    graph: &Graph,
    opts: RunOpts,
) -> Result<CellOutcome> {
    let model = engine.load(&format!("node_fb_{}_{}", gnn.as_str(), frontend.artifact_tag()))?;
    run_fullbatch_model(&model, frontend, graph, opts).map(|(out, _store)| out)
}

/// Drive one already-loaded full-batch node-classification model (any
/// backend, any scale — tests use small custom builds). On the native
/// backend the graph's normalized adjacency is bound as a sparse CSR; on
/// HLO it is densified (size-guarded) into the batch. Returns the cell
/// metrics together with the best-validation parameters, so callers can
/// checkpoint or export the trained model (`hashgnn train --ckpt-out` →
/// `hashgnn export`).
pub fn run_fullbatch_model(
    model: &Model,
    frontend: Frontend,
    graph: &Graph,
    opts: RunOpts,
) -> Result<(CellOutcome, ParamStore)> {
    let n = model.manifest.hyper_usize("n")?;
    let k = model.manifest.hyper_usize("n_classes")?;
    if graph.n_nodes() != n {
        return Err(Error::Shape(format!(
            "model expects n={n}, graph has {}",
            graph.n_nodes()
        )));
    }
    let model_fe = crate::runtime::native::front_end_name(&model.manifest)?;
    if model_fe != frontend.artifact_tag() {
        return Err(Error::Config(format!(
            "frontend {} (front_end '{}') does not match model '{}' (front_end '{}')",
            frontend.name(),
            frontend.artifact_tag(),
            model.manifest.name,
            model_fe
        )));
    }
    let labels = graph
        .labels()
        .ok_or_else(|| Error::Config("node classification needs labels".into()))?;
    let coding = CodingCfg::new(model.manifest.hyper_usize("c")?, model.manifest.hyper_usize("m")?)?;
    let native = model.backend_name() == "native";
    let adj = adj_input(graph, model.manifest.hyper_str("adj")?, native)?;
    let codes = all_codes_tensor(graph, frontend, coding, opts.seed)?;

    let split = split_nodes(n, 0.7, 0.1, opts.seed ^ 0xA5A5)?;
    let mut mask = vec![0.0f32; n];
    for &i in &split.train {
        mask[i as usize] = 1.0;
    }
    let labels_t = Tensor::i32(vec![n], labels.iter().map(|&l| l as i32).collect())?;
    let mask_t = Tensor::f32(vec![n], mask)?;

    let mut batch: Vec<Tensor> = Vec::new();
    if let Some(c) = &codes {
        batch.push(c.clone());
    }
    match &adj {
        AdjInput::Csr(a) => model.bind_adjacency(a.clone())?,
        AdjInput::Dense(t) => batch.push(t.clone()),
    }
    if model.needs_pos_map() {
        model.bind_pos_map(pos_map_for(&model.manifest, graph)?)?;
    }
    batch.push(labels_t);
    batch.push(mask_t);

    let mut store = ParamStore::init(&model.manifest, opts.seed);
    let pred_batch: Vec<Tensor> = batch[..batch.len() - 2].to_vec(); // codes? (+ dense adj)

    let mut best = CellOutcome { val: f64::MIN, test: 0.0, final_loss: f32::NAN };
    let mut best_store = store.clone();
    let mut last_loss = f32::NAN;
    for epoch in 0..opts.epochs {
        last_loss = train::run_step(&model, &mut store, &batch)?;
        if (epoch + 1) % opts.eval_every == 0 || epoch + 1 == opts.epochs {
            let logits = train::predict(&model, &store, &pred_batch)?;
            let (val, test) = split_accuracy(logits.as_f32()?, n, k, labels, &split);
            if val > best.val {
                best = CellOutcome { val, test, final_loss: last_loss };
                best_store = store.clone();
            }
        }
    }
    best.final_loss = last_loss;
    Ok((best, best_store))
}

/// Accuracy over the val and test index sets.
pub fn split_accuracy(
    logits: &[f32],
    n: usize,
    k: usize,
    labels: &[u32],
    split: &Split,
) -> (f64, f64) {
    debug_assert_eq!(logits.len(), n * k);
    let acc_of = |ids: &[u32]| {
        if ids.is_empty() {
            return 0.0;
        }
        let sub_logits: Vec<f32> = ids
            .iter()
            .flat_map(|&i| logits[i as usize * k..(i as usize + 1) * k].iter().copied())
            .collect();
        let sub_labels: Vec<u32> = ids.iter().map(|&i| labels[i as usize]).collect();
        accuracy_from_logits(&sub_logits, ids.len(), k, &sub_labels)
    };
    (acc_of(&split.val), acc_of(&split.test))
}

/// Shared handle for tests/benches: codes quality sanity (hash codes over
/// an SBM adjacency should separate classes better than random codes).
pub fn code_label_consistency(graph: &Graph, coding: CodingCfg, coder: Coder, seed: u64) -> Result<f64> {
    let table = make_codes(&Aux::Graph(graph), coder, coding, seed)?;
    let labels = graph.labels().ok_or_else(|| Error::Config("needs labels".into()))?;
    let n = graph.n_nodes();
    let bits = coding.n_bits();
    // Average intra-class vs inter-class Hamming similarity over a sample.
    let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(seed);
    use crate::rng::Rng;
    let mut intra = 0.0f64;
    let mut inter = 0.0f64;
    let mut n_intra = 0usize;
    let mut n_inter = 0usize;
    for _ in 0..4000 {
        let a = rng.index(n);
        let b = rng.index(n);
        if a == b {
            continue;
        }
        let same_bits = (0..bits).filter(|&k| table.bits.get(a, k) == table.bits.get(b, k)).count();
        let sim = same_bits as f64 / bits as f64;
        if labels[a] == labels[b] {
            intra += sim;
            n_intra += 1;
        } else {
            inter += sim;
            n_inter += 1;
        }
    }
    Ok(intra / n_intra.max(1) as f64 - inter / n_inter.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm, SbmCfg};

    #[test]
    fn hash_codes_carry_label_signal_random_dont() {
        let g = sbm(SbmCfg::new(600, 4, 24.0, 2.0), 3).unwrap();
        let coding = CodingCfg::new(16, 8).unwrap();
        let hash_gap = code_label_consistency(&g, coding, Coder::Hash, 5).unwrap();
        let rand_gap = code_label_consistency(&g, coding, Coder::Random, 5).unwrap();
        assert!(hash_gap > 0.01, "hash intra-inter gap too small: {hash_gap}");
        assert!(rand_gap.abs() < 0.02, "random codes should carry no signal: {rand_gap}");
        assert!(hash_gap > rand_gap);
    }

    #[test]
    fn split_accuracy_math() {
        // 4 nodes, 2 classes; logits favor class of node id parity.
        let logits = vec![0.9, 0.1, 0.1, 0.9, 0.9, 0.1, 0.1, 0.9];
        let labels = vec![0u32, 1, 1, 1];
        let split = Split { train: vec![], val: vec![0, 1], test: vec![2, 3] };
        let (val, test) = split_accuracy(&logits, 4, 2, &labels, &split);
        assert_eq!(val, 1.0); // node0→0 ✓, node1→1 ✓
        assert_eq!(test, 0.5); // node2→0 ✗, node3→1 ✓
    }

    #[test]
    fn adj_kinds() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        for kind in ["sym_norm", "row_norm", "raw"] {
            match adj_input(&g, kind, false).unwrap() {
                AdjInput::Dense(t) => assert_eq!(t.shape(), &[3, 3]),
                AdjInput::Csr(_) => panic!("asked for the dense form"),
            }
            match adj_input(&g, kind, true).unwrap() {
                AdjInput::Csr(a) => {
                    assert_eq!(a.n_rows(), 3);
                    assert_eq!(a.n_cols(), 3);
                }
                AdjInput::Dense(_) => panic!("native form must stay sparse"),
            }
        }
        assert!(adj_input(&g, "bogus", true).is_err());
        assert!(adj_input(&g, "bogus", false).is_err());
    }

    #[test]
    fn dense_adj_is_size_guarded_but_sparse_is_not() {
        // A graph just over the guard: the sparse form is fine, the dense
        // HLO form must refuse (and do so *before* allocating n² floats).
        let n = DENSE_ADJ_MAX_NODES + 1;
        let g = Graph::from_edges(n, &[(0, 1), (2, 3)]).unwrap();
        assert!(matches!(adj_input(&g, "raw", true), Ok(AdjInput::Csr(_))));
        let err = adj_input(&g, "raw", false).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("native"), "error should point at the sparse path: {msg}");
    }
}
