//! Table 1 (node classification rows): full-batch GCN / SGC / GIN / SAGE
//! with NC (explicit embedding table), Rand (ALONE codes) and Hash
//! (Algorithm 1 over the adjacency) feature front-ends.
//!
//! Protocol (Appendix C.1): AdamW lr=0.01, train to a fixed epoch budget,
//! evaluate every few epochs on the validation split and report the test
//! metric from the best-validation epoch.
//!
//! The full-batch executables are the one family the native backend does
//! not implement — [`run_fullbatch`] needs AOT HLO artifacts (build with
//! `make artifacts` and the `xla` feature, or use the minibatch SAGE
//! drivers in [`crate::tasks::sage`] which run on either backend).

use crate::cfg::{CodingCfg, Coder, GnnKind};
use crate::eval::accuracy_from_logits;
use crate::graph::{split_nodes, Graph, Split};
use crate::params::ParamStore;
use crate::runtime::{Engine, Tensor};
use crate::tasks::coding::{make_codes, Aux};
use crate::train;
use crate::{Error, Result};

/// Which feature front-end (Table 1 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frontend {
    /// "NC": explicit trainable embedding table (no compression).
    Nc,
    /// "Rand": ALONE random coding.
    Rand,
    /// "Hash": the paper's LSH coding over the adjacency matrix.
    Hash,
}

impl Frontend {
    pub fn name(&self) -> &'static str {
        match self {
            Frontend::Nc => "NC",
            Frontend::Rand => "Rand",
            Frontend::Hash => "Hash",
        }
    }

    pub fn all() -> [Frontend; 3] {
        [Frontend::Nc, Frontend::Rand, Frontend::Hash]
    }

    pub fn artifact_tag(&self) -> &'static str {
        match self {
            Frontend::Nc => "nc",
            _ => "coded",
        }
    }

    fn coder(&self) -> Option<Coder> {
        match self {
            Frontend::Nc => None,
            Frontend::Rand => Some(Coder::Random),
            Frontend::Hash => Some(Coder::Hash),
        }
    }
}

/// Run options for one Table-1 cell.
#[derive(Clone, Copy, Debug)]
pub struct RunOpts {
    pub epochs: usize,
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self { epochs: 60, eval_every: 5, seed: 7 }
    }
}

/// Outcome of one (gnn, frontend, dataset) cell.
#[derive(Clone, Copy, Debug)]
pub struct CellOutcome {
    pub val: f64,
    pub test: f64,
    pub final_loss: f32,
}

/// Build the dense adjacency tensor in the normalization the artifact
/// expects (manifest hyper `adj`).
pub fn adj_tensor(graph: &Graph, adj_kind: &str) -> Result<Tensor> {
    let n = graph.n_nodes();
    let dense = match adj_kind {
        "sym_norm" => graph.adj().gcn_normalized_dense()?,
        "row_norm" => graph.adj().row_normalized_dense()?,
        "raw" => graph.adj().to_dense(),
        other => return Err(Error::Config(format!("unknown adj kind '{other}'"))),
    };
    Tensor::f32(vec![n, n], dense)
}

/// Gather all-node integer codes as the `(n, m)` input tensor.
pub fn all_codes_tensor(
    graph: &Graph,
    frontend: Frontend,
    coding: CodingCfg,
    seed: u64,
) -> Result<Option<Tensor>> {
    let Some(coder) = frontend.coder() else { return Ok(None) };
    let table = make_codes(&Aux::Graph(graph), coder, coding, seed)?;
    let n = graph.n_nodes();
    let ids: Vec<u32> = (0..n as u32).collect();
    let mut buf = Vec::new();
    table.gather_int_codes(&ids, &mut buf);
    Ok(Some(Tensor::i32(vec![n, coding.m], buf)?))
}

/// One full-batch node-classification run; returns val/test accuracy at
/// the best validation epoch.
pub fn run_fullbatch(
    engine: &Engine,
    gnn: GnnKind,
    frontend: Frontend,
    graph: &Graph,
    opts: RunOpts,
) -> Result<CellOutcome> {
    let model = engine.load(&format!("node_fb_{}_{}", gnn.as_str(), frontend.artifact_tag()))?;
    let n = model.manifest.hyper_usize("n")?;
    let k = model.manifest.hyper_usize("n_classes")?;
    if graph.n_nodes() != n {
        return Err(Error::Shape(format!(
            "artifact expects n={n}, graph has {}",
            graph.n_nodes()
        )));
    }
    let labels = graph
        .labels()
        .ok_or_else(|| Error::Config("node classification needs labels".into()))?;
    let coding = CodingCfg::new(model.manifest.hyper_usize("c")?, model.manifest.hyper_usize("m")?)?;
    let adj = adj_tensor(graph, model.manifest.hyper_str("adj")?)?;
    let codes = all_codes_tensor(graph, frontend, coding, opts.seed)?;

    let split = split_nodes(n, 0.7, 0.1, opts.seed ^ 0xA5A5)?;
    let mut mask = vec![0.0f32; n];
    for &i in &split.train {
        mask[i as usize] = 1.0;
    }
    let labels_t = Tensor::i32(vec![n], labels.iter().map(|&l| l as i32).collect())?;
    let mask_t = Tensor::f32(vec![n], mask)?;

    let mut batch: Vec<Tensor> = Vec::new();
    if let Some(c) = &codes {
        batch.push(c.clone());
    }
    batch.push(adj);
    batch.push(labels_t);
    batch.push(mask_t);

    let mut store = ParamStore::init(&model.manifest, opts.seed);
    let pred_batch: Vec<Tensor> = batch[..batch.len() - 2].to_vec(); // codes? + adj

    let mut best = CellOutcome { val: f64::MIN, test: 0.0, final_loss: f32::NAN };
    let mut last_loss = f32::NAN;
    for epoch in 0..opts.epochs {
        last_loss = train::run_step(&model, &mut store, &batch)?;
        if (epoch + 1) % opts.eval_every == 0 || epoch + 1 == opts.epochs {
            let logits = train::predict(&model, &store, &pred_batch)?;
            let (val, test) = split_accuracy(logits.as_f32()?, n, k, labels, &split);
            if val > best.val {
                best = CellOutcome { val, test, final_loss: last_loss };
            }
        }
    }
    best.final_loss = last_loss;
    Ok(best)
}

/// Accuracy over the val and test index sets.
pub fn split_accuracy(
    logits: &[f32],
    n: usize,
    k: usize,
    labels: &[u32],
    split: &Split,
) -> (f64, f64) {
    debug_assert_eq!(logits.len(), n * k);
    let acc_of = |ids: &[u32]| {
        if ids.is_empty() {
            return 0.0;
        }
        let sub_logits: Vec<f32> = ids
            .iter()
            .flat_map(|&i| logits[i as usize * k..(i as usize + 1) * k].iter().copied())
            .collect();
        let sub_labels: Vec<u32> = ids.iter().map(|&i| labels[i as usize]).collect();
        accuracy_from_logits(&sub_logits, ids.len(), k, &sub_labels)
    };
    (acc_of(&split.val), acc_of(&split.test))
}

/// Shared handle for tests/benches: codes quality sanity (hash codes over
/// an SBM adjacency should separate classes better than random codes).
pub fn code_label_consistency(graph: &Graph, coding: CodingCfg, coder: Coder, seed: u64) -> Result<f64> {
    let table = make_codes(&Aux::Graph(graph), coder, coding, seed)?;
    let labels = graph.labels().ok_or_else(|| Error::Config("needs labels".into()))?;
    let n = graph.n_nodes();
    let bits = coding.n_bits();
    // Average intra-class vs inter-class Hamming similarity over a sample.
    let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(seed);
    use crate::rng::Rng;
    let mut intra = 0.0f64;
    let mut inter = 0.0f64;
    let mut n_intra = 0usize;
    let mut n_inter = 0usize;
    for _ in 0..4000 {
        let a = rng.index(n);
        let b = rng.index(n);
        if a == b {
            continue;
        }
        let same_bits = (0..bits).filter(|&k| table.bits.get(a, k) == table.bits.get(b, k)).count();
        let sim = same_bits as f64 / bits as f64;
        if labels[a] == labels[b] {
            intra += sim;
            n_intra += 1;
        } else {
            inter += sim;
            n_inter += 1;
        }
    }
    Ok(intra / n_intra.max(1) as f64 - inter / n_inter.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{sbm, SbmCfg};

    #[test]
    fn hash_codes_carry_label_signal_random_dont() {
        let g = sbm(SbmCfg::new(600, 4, 24.0, 2.0), 3).unwrap();
        let coding = CodingCfg::new(16, 8).unwrap();
        let hash_gap = code_label_consistency(&g, coding, Coder::Hash, 5).unwrap();
        let rand_gap = code_label_consistency(&g, coding, Coder::Random, 5).unwrap();
        assert!(hash_gap > 0.01, "hash intra-inter gap too small: {hash_gap}");
        assert!(rand_gap.abs() < 0.02, "random codes should carry no signal: {rand_gap}");
        assert!(hash_gap > rand_gap);
    }

    #[test]
    fn split_accuracy_math() {
        // 4 nodes, 2 classes; logits favor class of node id parity.
        let logits = vec![0.9, 0.1, 0.1, 0.9, 0.9, 0.1, 0.1, 0.9];
        let labels = vec![0u32, 1, 1, 1];
        let split = Split { train: vec![], val: vec![0, 1], test: vec![2, 3] };
        let (val, test) = split_accuracy(&logits, 4, 2, &labels, &split);
        assert_eq!(val, 1.0); // node0→0 ✓, node1→1 ✓
        assert_eq!(test, 0.5); // node2→0 ✗, node3→1 ✓
    }

    #[test]
    fn adj_kinds() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]).unwrap();
        for kind in ["sym_norm", "row_norm", "raw"] {
            let t = adj_tensor(&g, kind).unwrap();
            assert_eq!(t.shape(), &[3, 3]);
        }
        assert!(adj_tensor(&g, "bogus").is_err());
    }
}
