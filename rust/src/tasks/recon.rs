//! §5.1 — pre-trained-embedding reconstruction (Figure 1, Table 5).
//!
//! Protocol (§5.1.2): compress the top-`n` entities by frequency with a
//! coder, train the decoder with MSE against the originals, then evaluate
//! the reconstructed embeddings of the top-5k entities on the proxy task
//! (analogy accuracy + similarity ρ for the GloVe analog, k-means NMI for
//! the metapath2vec analogs).

use std::sync::Arc;

use crate::codes::CodeTable;
use crate::embed::{cosine, AnalogyQuad, EmbeddingSet, SimPair, WordEmbeddings};
use crate::eval::{kmeans, nmi, spearman};
use crate::params::ParamStore;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{Engine, Model, Tensor};
use crate::train::{self, TrainOpts};
use crate::Result;

/// Train a reconstruction decoder on `codes` → `targets`.
pub fn train_decoder(
    model: &Model,
    codes: &CodeTable,
    targets: &EmbeddingSet,
    epochs: usize,
    seed: u64,
) -> Result<(ParamStore, train::TrainLog)> {
    let b = model.manifest.hyper_usize("batch")?;
    let m = model.manifest.hyper_usize("m")?;
    let d_e = model.manifest.hyper_usize("d_e")?;
    assert_eq!(targets.d, d_e, "target dim must match artifact d_e");
    let n = codes.n().min(targets.n);
    let mut store = ParamStore::init(&model.manifest, seed);
    let codes = Arc::new(codes.clone());
    let data = Arc::new(targets.data.clone());
    let steps = (epochs * n.div_ceil(b)) as u64;
    let source = move |step: u64| {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ (step.wrapping_mul(0x9E3779B97F4A7C15)));
        let mut ids = Vec::with_capacity(b);
        let mut tgt = Vec::with_capacity(b * d_e);
        for _ in 0..b {
            let id = rng.index(n);
            ids.push(id as u32);
            tgt.extend_from_slice(&data[id * d_e..(id + 1) * d_e]);
        }
        let mut code_buf = Vec::new();
        codes.gather_int_codes(&ids, &mut code_buf);
        vec![
            Tensor::i32(vec![b, m], code_buf).expect("code tensor"),
            Tensor::f32(vec![b, d_e], tgt).expect("target tensor"),
        ]
    };
    let log = train::train(model, &mut store, source, TrainOpts::new(steps))?;
    Ok((store, log))
}

/// Reconstruct embeddings for entities `0..k` (batched through pred).
pub fn reconstruct(model: &Model, store: &ParamStore, codes: &CodeTable, k: usize) -> Result<Vec<f32>> {
    let b = model.manifest.hyper_usize("batch")?;
    let m = model.manifest.hyper_usize("m")?;
    let d_e = model.manifest.hyper_usize("d_e")?;
    let mut out = Vec::with_capacity(k * d_e);
    let mut code_buf = Vec::new();
    let mut start = 0usize;
    while start < k {
        let ids: Vec<u32> = (start..start + b).map(|i| (i.min(k - 1)) as u32).collect();
        codes.gather_int_codes(&ids, &mut code_buf);
        let logits = train::predict(
            model,
            store,
            &[Tensor::i32(vec![b, m], code_buf.clone())?],
        )?;
        let vals = logits.as_f32()?;
        let take = (k - start).min(b);
        out.extend_from_slice(&vals[..take * d_e]);
        start += b;
    }
    Ok(out)
}

/// Train the autoencoder baseline and encode the first `n` entities
/// (the "learn" lines in Figure 1).
pub fn learned_codes(
    ae: &Model,
    set: &EmbeddingSet,
    n: usize,
    epochs: usize,
    seed: u64,
) -> Result<CodeTable> {
    let b = ae.manifest.hyper_usize("batch")?;
    let m = ae.manifest.hyper_usize("m")?;
    let c = ae.manifest.hyper_usize("c")?;
    let d_e = ae.manifest.hyper_usize("d_e")?;
    let n = n.min(set.n);
    let mut store = ParamStore::init(&ae.manifest, seed);
    let data = Arc::new(set.data.clone());
    let steps = (epochs * n.div_ceil(b)) as u64;
    let data_src = data.clone();
    let source = move |step: u64| {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ step.wrapping_mul(0x2545F4914F6CDD1D));
        let mut emb = Vec::with_capacity(b * d_e);
        for _ in 0..b {
            let id = rng.index(n);
            emb.extend_from_slice(&data_src[id * d_e..(id + 1) * d_e]);
        }
        let mut uniform = vec![0.0f32; b * m * c];
        rng.fill_uniform_f32(&mut uniform, 1e-6, 1.0);
        vec![
            Tensor::f32(vec![b, d_e], emb).expect("emb tensor"),
            Tensor::f32(vec![b, m, c], uniform).expect("gumbel tensor"),
        ]
    };
    train::train(ae, &mut store, source, TrainOpts::new(steps))?;
    // Encode all n entities with the trained encoder (argmax, no noise).
    let coding = crate::cfg::CodingCfg::new(c, m)?;
    let mut all_codes: Vec<i32> = Vec::with_capacity(n * m);
    let mut start = 0usize;
    while start < n {
        let mut emb = Vec::with_capacity(b * d_e);
        for i in 0..b {
            let id = (start + i).min(n - 1);
            emb.extend_from_slice(&data[id * d_e..(id + 1) * d_e]);
        }
        let codes_t = train::predict(ae, &store, &[Tensor::f32(vec![b, d_e], emb)?])?;
        let vals = codes_t.as_i32()?;
        let take = (n - start).min(b);
        all_codes.extend_from_slice(&vals[..take * m]);
        start += b;
    }
    CodeTable::from_int_codes(&all_codes, n, coding)
}

// ---------------------------------------------------------------------------
// Evaluation protocols (Appendix B.1)
// ---------------------------------------------------------------------------

/// Word-analogy accuracy: `argmax_i cos(emb_i, emb_b − emb_a + emb_c)`
/// must equal `d` (a, b, c excluded), averaged per relation then over
/// relations (B.1.2).
pub fn analogy_accuracy(emb: &[f32], n: usize, d: usize, quads: &[AnalogyQuad], n_relations: usize) -> f64 {
    let mut correct = vec![0usize; n_relations];
    let mut total = vec![0usize; n_relations];
    let mut query = vec![0.0f32; d];
    for q in quads {
        if (q.a as usize) >= n || (q.b as usize) >= n || (q.c as usize) >= n || (q.d as usize) >= n
        {
            continue; // outside the evaluated top-k slice
        }
        for j in 0..d {
            query[j] = emb[q.b as usize * d + j] - emb[q.a as usize * d + j]
                + emb[q.c as usize * d + j];
        }
        let mut best = (f32::MIN, usize::MAX);
        for i in 0..n {
            if i as u32 == q.a || i as u32 == q.b || i as u32 == q.c {
                continue;
            }
            let s = cosine(&query, &emb[i * d..(i + 1) * d]);
            if s > best.0 {
                best = (s, i);
            }
        }
        total[q.relation as usize] += 1;
        if best.1 as u32 == q.d {
            correct[q.relation as usize] += 1;
        }
    }
    let accs: Vec<f64> = correct
        .iter()
        .zip(&total)
        .filter(|(_, &t)| t > 0)
        .map(|(&c, &t)| c as f64 / t as f64)
        .collect();
    if accs.is_empty() {
        0.0
    } else {
        accs.iter().sum::<f64>() / accs.len() as f64
    }
}

/// Word-similarity Spearman ρ between reconstructed cosine similarities
/// and planted ground truth (B.1.3).
pub fn similarity_rho(emb: &[f32], n: usize, d: usize, pairs: &[SimPair]) -> f64 {
    let mut obs = Vec::new();
    let mut truth = Vec::new();
    for p in pairs {
        if (p.a as usize) >= n || (p.b as usize) >= n {
            continue;
        }
        obs.push(cosine(&emb[p.a as usize * d..(p.a as usize + 1) * d], &emb[p.b as usize * d..(p.b as usize + 1) * d]));
        truth.push(p.score);
    }
    spearman(&obs, &truth)
}

/// Node-clustering NMI: k-means on reconstructed embeddings vs labels
/// (B.1.4).
pub fn clustering_nmi(emb: &[f32], n: usize, d: usize, labels: &[u32], k: usize, seed: u64) -> f64 {
    let assign = kmeans(emb, n, d, k, 30, seed);
    nmi(&assign, &labels[..n], k, k)
}

/// Evaluate reconstructed GloVe-analog embeddings (both §5.1 word tasks).
pub fn eval_word(recon: &[f32], k: usize, w: &WordEmbeddings) -> (f64, f64) {
    let d = w.set.d;
    (
        analogy_accuracy(recon, k, d, &w.analogies, w.n_relations),
        similarity_rho(recon, k, d, &w.sim_pairs),
    )
}

/// A convenience wrapper: load engine + artifact by (c, m).
pub fn recon_model(engine: &Engine, c: usize, m: usize) -> Result<Model> {
    engine.load(&format!("recon_c{c}_m{m}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::analogy_embeddings;

    #[test]
    fn analogy_eval_on_raw_is_high() {
        let w = analogy_embeddings(800, 24, 4, 8, 50, 0.02, 3);
        let acc = analogy_accuracy(&w.set.data, w.set.n, w.set.d, &w.analogies, w.n_relations);
        assert!(acc > 0.8, "acc={acc}");
        let rho = similarity_rho(&w.set.data, w.set.n, w.set.d, &w.sim_pairs);
        assert!(rho > 0.9, "rho={rho}");
    }

    #[test]
    fn analogy_eval_on_noise_is_low() {
        let w = analogy_embeddings(400, 16, 4, 6, 50, 0.02, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut junk = vec![0.0f32; w.set.n * w.set.d];
        rng.fill_normal_f32(&mut junk, 0.0, 1.0);
        let acc = analogy_accuracy(&junk, w.set.n, w.set.d, &w.analogies, w.n_relations);
        assert!(acc < 0.2, "acc={acc}");
    }

    #[test]
    fn quads_outside_slice_skipped() {
        let w = analogy_embeddings(500, 16, 3, 5, 20, 0.02, 7);
        // Evaluating only the top 10 rows: most quads fall outside; the
        // function must not panic and must return a value in [0, 1].
        let acc = analogy_accuracy(&w.set.data[..10 * 16], 10, 16, &w.analogies, w.n_relations);
        assert!((0.0..=1.0).contains(&acc));
    }
}
