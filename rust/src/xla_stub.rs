//! Minimal host-only stand-in for the `xla` PJRT binding crate.
//!
//! Compiled (as `crate::xla`) only when the default-off `xla` feature is
//! disabled, so `cargo build && cargo test` work fully offline. The stub
//! mirrors exactly the API surface [`crate::runtime`] uses:
//!
//! - **Literals are real**: shape + typed data + tuples live on the host,
//!   so [`crate::runtime::Tensor`] round-trips (and its unit tests) behave
//!   identically to the real binding.
//! - **Compilation/execution are unavailable**: [`HloModuleProto::from_text_file`]
//!   and [`PjRtLoadedExecutable::execute`] return a clean [`Error`] telling
//!   the caller to build with the real backend. Callers already surface
//!   this as `Error::Xla(..)` through the crate-level `From` impl.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in the offline xla stub — rebuild with the real \
         PJRT backend (feature `xla`, see rust/Cargo.toml)"
    ))
}

/// Element types the artifacts use (plus common extras for completeness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
    U8,
}

/// Array shape: dimensions + element type.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: a shaped, typed buffer (or a tuple of literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Element types storable in a stub [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn vec1(data: &[Self]) -> Literal;
    fn read(lit: &Literal) -> Result<Vec<Self>, Error>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn vec1(data: &[Self]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: Payload::F32(data.to_vec()) }
    }

    fn read(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn vec1(data: &[Self]) -> Literal {
        Literal { dims: vec![data.len() as i64], payload: Payload::I32(data.to_vec()) }
    }

    fn read(lit: &Literal) -> Result<Vec<Self>, Error> {
        match &lit.payload {
            Payload::I32(v) => Ok(v.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

impl Literal {
    /// 1-D literal from a typed slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec1(data)
    }

    fn n_elements(&self) -> i64 {
        match &self.payload {
            Payload::F32(v) => v.len() as i64,
            Payload::I32(v) => v.len() as i64,
            Payload::Tuple(_) => -1,
        }
    }

    /// Reshape to the given dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let have = self.n_elements();
        if have < 0 {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        if want != have {
            return Err(Error(format!("reshape {dims:?} needs {want} elements, literal has {have}")));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::I32(_) => ElementType::S32,
            Payload::Tuple(_) => return Err(Error("tuple literal has no array shape".into())),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::read(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// PJRT client stub (host CPU, no device runtime behind it).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("HLO compilation"))
    }
}

/// Parsed HLO module stub.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("parsing HLO text ({path})")))
    }
}

/// Computation stub.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer stub returned by [`PjRtLoadedExecutable::execute`].
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// Loaded executable stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_stores_and_reshapes() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3]).is_err());
    }

    #[test]
    fn compile_and_execute_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = client.compile(&XlaComputation::from_proto(&HloModuleProto)).map(|_| ()).unwrap_err();
        assert!(msg.to_string().contains("stub"));
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
    }
}
