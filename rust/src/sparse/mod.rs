//! Compressed sparse row (CSR) matrix substrate.
//!
//! The paper stores the auxiliary matrix `A` (typically the adjacency
//! matrix) in "compressed row storage (CRS) format as all the operations on
//! A are row-wise operations" (Section 3.1). This module is that substrate:
//! a CSR builder from edge lists, row access, degree queries, SpMV against a
//! dense vector (the per-bit random projection `U = A·V`), symmetrization,
//! and the higher-order product used for the paper's future-work extension
//! (higher-order adjacency as auxiliary information, Section 6.1).

use crate::{Error, Result};

/// Register-tile width for [`Csr::spmm_row_major`]: 8 × f32 = one 256-bit
/// vector. Tiling runs across output columns (independent accumulators),
/// never across a single element's reduction, so tiled results are
/// bit-identical to the scalar walk.
pub const SPMM_LANES: usize = 8;

/// CSR sparse matrix with `f32` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    n_rows: usize,
    n_cols: usize,
    /// Row pointer array, length `n_rows + 1`.
    indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    indices: Vec<u32>,
    /// Values aligned with `indices`.
    values: Vec<f32>,
}

impl Csr {
    /// Build from COO triplets; duplicate entries are summed, columns
    /// sorted within each row.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(u32, u32, f32)],
    ) -> Result<Self> {
        for &(r, c, _) in triplets {
            if r as usize >= n_rows || c as usize >= n_cols {
                return Err(Error::Shape(format!(
                    "triplet ({r},{c}) out of bounds for {n_rows}×{n_cols}"
                )));
            }
        }
        // Counting sort by row.
        let mut counts = vec![0usize; n_rows + 1];
        for &(r, _, _) in triplets {
            counts[r as usize + 1] += 1;
        }
        for i in 0..n_rows {
            counts[i + 1] += counts[i];
        }
        let mut order: Vec<usize> = vec![0; triplets.len()];
        {
            let mut next = counts.clone();
            for (i, &(r, _, _)) in triplets.iter().enumerate() {
                order[next[r as usize]] = i;
                next[r as usize] += 1;
            }
        }
        // Per-row: sort by column, merge duplicates.
        let mut indptr = Vec::with_capacity(n_rows + 1);
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        indptr.push(0);
        let mut rowbuf: Vec<(u32, f32)> = Vec::new();
        for r in 0..n_rows {
            rowbuf.clear();
            for &i in &order[counts[r]..counts[r + 1]] {
                rowbuf.push((triplets[i].1, triplets[i].2));
            }
            rowbuf.sort_unstable_by_key(|&(c, _)| c);
            let mut j = 0;
            while j < rowbuf.len() {
                let col = rowbuf[j].0;
                let mut v = 0.0;
                while j < rowbuf.len() && rowbuf[j].0 == col {
                    v += rowbuf[j].1;
                    j += 1;
                }
                indices.push(col);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Ok(Self { n_rows, n_cols, indptr, indices, values })
    }

    /// Build an unweighted adjacency from an edge list (weight 1 per edge,
    /// duplicates collapse to their multiplicity).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Result<Self> {
        Self::from_edge_iter(n, edges.iter().copied())
    }

    /// [`Self::from_edges`] over any edge iterator — lets callers holding
    /// edges in a non-`Vec` layout (e.g. a serving bundle's in-place flat
    /// `u32` view) build the CSR without materializing a pair `Vec` first.
    pub fn from_edge_iter<I: IntoIterator<Item = (u32, u32)>>(n: usize, edges: I) -> Result<Self> {
        let triplets: Vec<(u32, u32, f32)> =
            edges.into_iter().map(|(a, b)| (a, b, 1.0)).collect();
        Self::from_triplets(n, n, &triplets)
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Values of row `r`.
    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Out-degree (stored entries) of row `r`.
    #[inline]
    pub fn degree(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Dot product of row `r` with a dense vector — the inner loop of
    /// Algorithm 1 (`U[j] ← DotProduct(A[j,:], V)`).
    #[inline]
    pub fn row_dot(&self, r: usize, v: &[f32]) -> f32 {
        debug_assert_eq!(v.len(), self.n_cols);
        let idx = self.row_indices(r);
        let val = self.row_values(r);
        let mut acc = 0.0f32;
        for k in 0..idx.len() {
            acc += val[k] * unsafe { *v.get_unchecked(idx[k] as usize) };
        }
        acc
    }

    /// Sparse matrix × dense vector: `out = A·v`.
    pub fn spmv(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.n_cols, "spmv: v length");
        assert_eq!(out.len(), self.n_rows, "spmv: out length");
        for r in 0..self.n_rows {
            out[r] = self.row_dot(r, v);
        }
    }

    /// Blocked SpMM over a row range: one traversal of the rows' stored
    /// entries produces the projections against all `n_vecs` dense vectors
    /// at once (the LSH engine's hot kernel — `A` is the dominant memory
    /// stream, so amortizing it across a block of vectors is the §Perf
    /// win over per-vector [`Self::spmv`]).
    ///
    /// `vt` is the **transposed** vector block, `vt[k * n_vecs + b]` =
    /// coordinate `k` of vector `b`, so the inner loop reads one
    /// contiguous `n_vecs`-row per stored entry. `outs[b][r - rows.start]`
    /// receives `dot(A[r,:], V_b)`.
    ///
    /// Per `(r, b)` the accumulation order (ascending stored-column order,
    /// one f32 accumulator) is identical to [`Self::row_dot`], so results
    /// are bit-identical to the per-vector path — the property the
    /// deterministic parallel encoder relies on.
    pub fn spmm_block_rows(
        &self,
        rows: std::ops::Range<usize>,
        vt: &[f32],
        n_vecs: usize,
        outs: &mut [&mut [f32]],
    ) {
        assert!(rows.end <= self.n_rows, "spmm: row range out of bounds");
        assert_eq!(vt.len(), self.n_cols * n_vecs, "spmm: vt length");
        assert_eq!(outs.len(), n_vecs, "spmm: outs count");
        let row0 = rows.start;
        let n_out = rows.end - rows.start;
        for out in outs.iter() {
            assert_eq!(out.len(), n_out, "spmm: out slice length");
        }
        let mut acc = vec![0.0f32; n_vecs];
        for r in rows {
            acc.fill(0.0);
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                let a = val[k];
                let vrow = &vt[idx[k] as usize * n_vecs..][..n_vecs];
                for b in 0..n_vecs {
                    acc[b] += a * vrow[b];
                }
            }
            for b in 0..n_vecs {
                outs[b][r - row0] = acc[b];
            }
        }
    }

    /// Sparse matrix × dense multi-vector block, single pass over `A`:
    /// `out[b * n_rows + r] = dot(A[r,:], vs[b*d .. (b+1)*d])`.
    ///
    /// `vs` is vector-major (vector `b` contiguous); the transpose into the
    /// layout [`Self::spmm_block_rows`] wants is done internally.
    pub fn spmm(&self, vs: &[f32], n_vecs: usize, out: &mut [f32]) {
        assert_eq!(vs.len(), self.n_cols * n_vecs, "spmm: vs length");
        assert_eq!(out.len(), self.n_rows * n_vecs, "spmm: out length");
        if self.n_rows == 0 || n_vecs == 0 {
            return;
        }
        let mut vt = vec![0.0f32; vs.len()];
        for b in 0..n_vecs {
            for k in 0..self.n_cols {
                vt[k * n_vecs + b] = vs[b * self.n_cols + k];
            }
        }
        let mut outs: Vec<&mut [f32]> = out.chunks_mut(self.n_rows).collect();
        self.spmm_block_rows(0..self.n_rows, &vt, n_vecs, &mut outs);
    }

    /// Sparse matrix × dense row-major matrix over a row range:
    /// `out[(r - rows.start) * d + j] = dot(A[r,:], x[:, j])` with
    /// `x (n_cols, d)` row-major. The per-element accumulation (ascending
    /// stored-column order, one f32 accumulator) is identical to
    /// [`Self::row_dot`] / [`Self::spmm_block_rows`], so results are
    /// bit-identical to the per-vector path — this is the full-batch GNN
    /// propagation kernel, shaped so callers can partition output rows
    /// across threads under the determinism rule.
    ///
    /// Columns run in register tiles of [`SPMM_LANES`]: each tile holds
    /// its partial sums in a stack array while re-streaming the row's
    /// stored entries (indices/values are contiguous and L1-resident on
    /// the second pass), so the gathered `x` rows are the only wide
    /// memory traffic and the accumulators vectorize. Per output element
    /// the addition order is still ascending stored-column order; the
    /// `d % SPMM_LANES` tail runs the same loop at partial width.
    pub fn spmm_row_major(
        &self,
        rows: std::ops::Range<usize>,
        x: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        assert!(rows.end <= self.n_rows, "spmm_row_major: row range out of bounds");
        assert_eq!(x.len(), self.n_cols * d, "spmm_row_major: x length");
        assert_eq!(out.len(), (rows.end - rows.start) * d, "spmm_row_major: out length");
        let row0 = rows.start;
        for r in rows {
            let orow = &mut out[(r - row0) * d..(r - row0 + 1) * d];
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            let mut o0 = 0;
            loop {
                let width = SPMM_LANES.min(d - o0);
                if width == 0 {
                    break;
                }
                let mut acc = [0.0f32; SPMM_LANES];
                for k in 0..idx.len() {
                    let a = val[k];
                    let xtile = &x[idx[k] as usize * d + o0..][..width];
                    for (o, &v) in acc[..width].iter_mut().zip(xtile) {
                        *o += a * v;
                    }
                }
                orow[o0..o0 + width].copy_from_slice(&acc[..width]);
                o0 += width;
            }
        }
    }

    /// Structural transpose `Aᵀ` (O(nnz) counting pass; columns of each
    /// output row come out ascending). The full-batch GNN backward passes
    /// need `Aᵀ·dz` for the non-symmetric normalizations (`row_norm`).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.n_cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 0..self.n_cols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut next = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.n_rows {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                let c = idx[k] as usize;
                let pos = next[c];
                next[c] += 1;
                indices[pos] = r as u32;
                values[pos] = val[k];
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, indptr, indices, values }
    }

    /// Symmetric GCN normalization with self-loops, **kept sparse**:
    /// `Â = D^{-1/2} (A + I) D^{-1/2}`. Values match
    /// [`Self::gcn_normalized_dense`] bit for bit (degree sums run in the
    /// same ascending order; adding structural zeros is an f32 no-op).
    pub fn gcn_normalized(&self) -> Result<Csr> {
        if self.n_rows != self.n_cols {
            return Err(Error::Shape("gcn normalization requires square".into()));
        }
        let n = self.n_rows;
        let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(self.nnz() + n);
        for r in 0..n {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                triplets.push((r as u32, idx[k], val[k]));
            }
        }
        for i in 0..n {
            triplets.push((i as u32, i as u32, 1.0));
        }
        let mut out = Csr::from_triplets(n, n, &triplets)?;
        let mut deg = vec![0.0f32; n];
        for r in 0..n {
            let mut s = 0.0f32;
            for &v in out.row_values(r) {
                s += v;
            }
            deg[r] = s;
        }
        let dinv: Vec<f32> =
            deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();
        for r in 0..n {
            for k in out.indptr[r]..out.indptr[r + 1] {
                let c = out.indices[k] as usize;
                out.values[k] *= dinv[r] * dinv[c];
            }
        }
        Ok(out)
    }

    /// Row normalization `D⁻¹A`, **kept sparse** (mean-aggregator input for
    /// full-batch GraphSAGE). Rows with no entries stay empty. Values match
    /// [`Self::row_normalized_dense`] bit for bit.
    pub fn row_normalized(&self) -> Result<Csr> {
        if self.n_rows != self.n_cols {
            return Err(Error::Shape("row normalization requires square".into()));
        }
        let mut out = self.clone();
        for r in 0..out.n_rows {
            let start = out.indptr[r];
            let end = out.indptr[r + 1];
            let mut sum = 0.0f32;
            for k in start..end {
                sum += out.values[k];
            }
            if sum > 0.0 {
                let inv = 1.0 / sum;
                for k in start..end {
                    out.values[k] *= inv;
                }
            }
        }
        Ok(out)
    }

    /// Dispatch a manifest's `adj` normalization kind to the matching
    /// sparse normalization (`raw` is a structural copy).
    pub fn normalized(&self, kind: &str) -> Result<Csr> {
        match kind {
            "sym_norm" => self.gcn_normalized(),
            "row_norm" => self.row_normalized(),
            "raw" => Ok(self.clone()),
            other => Err(Error::Config(format!("unknown adj kind '{other}'"))),
        }
    }

    /// Materialize row `r` into a dense buffer (zero-filled first).
    pub fn densify_row(&self, r: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.n_cols);
        out.fill(0.0);
        let idx = self.row_indices(r);
        let val = self.row_values(r);
        for k in 0..idx.len() {
            out[idx[k] as usize] = val[k];
        }
    }

    /// Symmetrize a square matrix: `A ← A + Aᵀ` structurally (values summed;
    /// the paper makes all directed graphs undirected this way, §5.2.1).
    pub fn symmetrize(&self) -> Result<Self> {
        if self.n_rows != self.n_cols {
            return Err(Error::Shape("symmetrize requires a square matrix".into()));
        }
        let mut triplets = Vec::with_capacity(self.nnz() * 2);
        for r in 0..self.n_rows {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                triplets.push((r as u32, idx[k], val[k]));
                triplets.push((idx[k], r as u32, val[k]));
            }
        }
        Self::from_triplets(self.n_rows, self.n_cols, &triplets)
    }

    /// `A²` (boolean-ish structural product with summed multiplicities) —
    /// higher-order adjacency for the §6.1 extension. Row-by-row sparse
    /// accumulator (SPA) algorithm.
    pub fn square(&self) -> Result<Self> {
        if self.n_rows != self.n_cols {
            return Err(Error::Shape("square requires a square matrix".into()));
        }
        let n = self.n_rows;
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices: Vec<u32> = Vec::new();
        let mut values: Vec<f32> = Vec::new();
        indptr.push(0);
        let mut acc: Vec<f32> = vec![0.0; n];
        let mut touched: Vec<u32> = Vec::new();
        for r in 0..n {
            touched.clear();
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                let mid = idx[k] as usize;
                let w = val[k];
                let idx2 = self.row_indices(mid);
                let val2 = self.row_values(mid);
                for k2 in 0..idx2.len() {
                    let c = idx2[k2] as usize;
                    if acc[c] == 0.0 {
                        touched.push(c as u32);
                    }
                    acc[c] += w * val2[k2];
                }
            }
            touched.sort_unstable();
            for &c in &touched {
                indices.push(c);
                values.push(acc[c as usize]);
                acc[c as usize] = 0.0;
            }
            indptr.push(indices.len());
        }
        Ok(Self { n_rows: n, n_cols: n, indptr, indices, values })
    }

    /// Dense materialization (tests / small full-batch GNN inputs).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n_rows * self.n_cols];
        for r in 0..self.n_rows {
            let idx = self.row_indices(r);
            let val = self.row_values(r);
            for k in 0..idx.len() {
                out[r * self.n_cols + idx[k] as usize] = val[k];
            }
        }
        out
    }

    /// Row-normalized dense adjacency `D⁻¹A` — [`Self::row_normalized`]
    /// materialized for the HLO full-batch executables.
    pub fn row_normalized_dense(&self) -> Result<Vec<f32>> {
        Ok(self.row_normalized()?.to_dense())
    }

    /// Symmetric GCN normalization `Â = D^{-1/2} (A + I) D^{-1/2}` —
    /// [`Self::gcn_normalized`] materialized for the HLO full-batch
    /// executables.
    pub fn gcn_normalized_dense(&self) -> Result<Vec<f32>> {
        Ok(self.gcn_normalized()?.to_dense())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // 0→1, 0→2, 1→2, 2→0
        Csr::from_edges(3, &[(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap()
    }

    #[test]
    fn build_and_access() {
        let a = small();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.row_indices(0), &[1, 2]);
        assert_eq!(a.row_indices(1), &[2]);
        assert_eq!(a.degree(2), 1);
    }

    #[test]
    fn duplicates_sum() {
        let a = Csr::from_triplets(2, 2, &[(0, 1, 1.0), (0, 1, 2.0)]).unwrap();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.row_values(0), &[3.0]);
    }

    #[test]
    fn out_of_bounds_rejected() {
        assert!(Csr::from_edges(2, &[(0, 5)]).is_err());
    }

    #[test]
    fn row_dot_matches_dense() {
        let a = small();
        let v = vec![0.5, -1.0, 2.0];
        let dense = a.to_dense();
        for r in 0..3 {
            let expect: f32 = (0..3).map(|c| dense[r * 3 + c] * v[c]).sum();
            assert!((a.row_dot(r, &v) - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn spmv_matches_rowdot() {
        let a = small();
        let v = vec![1.0, 2.0, 3.0];
        let mut out = vec![0.0; 3];
        a.spmv(&v, &mut out);
        assert_eq!(out, vec![a.row_dot(0, &v), a.row_dot(1, &v), a.row_dot(2, &v)]);
    }

    #[test]
    fn spmm_matches_per_vector_spmv_bitwise() {
        // Random-ish rectangular matrix with duplicate-free triplets.
        let mut triplets = Vec::new();
        for r in 0..13u32 {
            for c in 0..7u32 {
                if (r * 31 + c * 17) % 3 == 0 {
                    triplets.push((r, c, (r as f32 * 0.37 - c as f32 * 1.21).sin()));
                }
            }
        }
        let a = Csr::from_triplets(13, 7, &triplets).unwrap();
        let n_vecs = 5;
        let vs: Vec<f32> = (0..7 * n_vecs).map(|i| ((i * 29 + 3) % 11) as f32 * 0.3 - 1.5).collect();
        let mut blocked = vec![0.0f32; 13 * n_vecs];
        a.spmm(&vs, n_vecs, &mut blocked);
        for b in 0..n_vecs {
            let mut single = vec![0.0f32; 13];
            a.spmv(&vs[b * 7..(b + 1) * 7], &mut single);
            // Bit-identical, not approximately equal: the parallel encoder
            // depends on the accumulation orders matching exactly.
            assert_eq!(&blocked[b * 13..(b + 1) * 13], single.as_slice(), "vector {b}");
        }
    }

    #[test]
    fn spmm_block_rows_covers_partial_ranges() {
        let a = small().symmetrize().unwrap();
        let n_vecs = 3;
        let mut vt = vec![0.0f32; 3 * n_vecs];
        for k in 0..3 {
            for b in 0..n_vecs {
                vt[k * n_vecs + b] = (k * n_vecs + b) as f32 * 0.5 - 1.0;
            }
        }
        let mut full = vec![0.0f32; 3 * n_vecs];
        {
            let mut outs: Vec<&mut [f32]> = full.chunks_mut(3).collect();
            a.spmm_block_rows(0..3, &vt, n_vecs, &mut outs);
        }
        // Same computation over the split ranges [0,2) and [2,3).
        let mut lo = vec![0.0f32; 2 * n_vecs];
        let mut hi = vec![0.0f32; n_vecs];
        {
            let mut outs: Vec<&mut [f32]> = lo.chunks_mut(2).collect();
            a.spmm_block_rows(0..2, &vt, n_vecs, &mut outs);
        }
        {
            let mut outs: Vec<&mut [f32]> = hi.chunks_mut(1).collect();
            a.spmm_block_rows(2..3, &vt, n_vecs, &mut outs);
        }
        for b in 0..n_vecs {
            assert_eq!(full[b * 3], lo[b * 2]);
            assert_eq!(full[b * 3 + 1], lo[b * 2 + 1]);
            assert_eq!(full[b * 3 + 2], hi[b]);
        }
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let a = small().symmetrize().unwrap();
        let d = a.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], d[c * 3 + r]);
            }
        }
        // 0→1 means 1 now links back to 0.
        assert!(a.row_indices(1).contains(&0));
    }

    #[test]
    fn square_matches_dense_matmul() {
        let a = small();
        let sq = a.square().unwrap();
        let d = a.to_dense();
        let mut expect = vec![0.0f32; 9];
        for i in 0..3 {
            for j in 0..3 {
                for k in 0..3 {
                    expect[i * 3 + j] += d[i * 3 + k] * d[k * 3 + j];
                }
            }
        }
        assert_eq!(sq.to_dense(), expect);
    }

    #[test]
    fn densify_row_roundtrip() {
        let a = small();
        let mut buf = vec![9.0f32; 3];
        a.densify_row(0, &mut buf);
        assert_eq!(buf, vec![0.0, 1.0, 1.0]);
    }

    #[test]
    fn gcn_normalization_row_properties() {
        let a = small().symmetrize().unwrap();
        let norm = a.gcn_normalized_dense().unwrap();
        // Symmetric and non-negative, self-loops present.
        for r in 0..3 {
            assert!(norm[r * 3 + r] > 0.0);
            for c in 0..3 {
                assert!((norm[r * 3 + c] - norm[c * 3 + r]).abs() < 1e-6);
                assert!(norm[r * 3 + c] >= 0.0);
            }
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let a = Csr::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, -1.0), (1, 0, 0.5), (2, 3, 4.0), (2, 0, 1.5)],
        )
        .unwrap();
        let t = a.transpose();
        assert_eq!(t.n_rows(), 4);
        assert_eq!(t.n_cols(), 3);
        let d = a.to_dense();
        let dt = t.to_dense();
        for r in 0..3 {
            for c in 0..4 {
                assert_eq!(d[r * 4 + c], dt[c * 3 + r]);
            }
        }
        // Double transpose is the identity.
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn spmm_row_major_matches_spmm_bitwise() {
        let mut triplets = Vec::new();
        for r in 0..11u32 {
            for c in 0..6u32 {
                if (r * 13 + c * 7) % 3 == 0 {
                    triplets.push((r, c, (r as f32 * 0.61 - c as f32 * 0.87).cos()));
                }
            }
        }
        let a = Csr::from_triplets(11, 6, &triplets).unwrap();
        let d = 4usize;
        // x row-major (6, 4); the same data vector-major for spmm.
        let x: Vec<f32> = (0..6 * d).map(|i| ((i * 17 + 5) % 9) as f32 * 0.4 - 1.1).collect();
        let mut vs = vec![0.0f32; 6 * d];
        for k in 0..6 {
            for b in 0..d {
                vs[b * 6 + k] = x[k * d + b];
            }
        }
        let mut spmm_out = vec![0.0f32; 11 * d];
        a.spmm(&vs, d, &mut spmm_out);
        // Full range and a split range must both agree bit-for-bit.
        let mut rm = vec![0.0f32; 11 * d];
        a.spmm_row_major(0..11, &x, d, &mut rm);
        let mut rm_split = vec![0.0f32; 11 * d];
        a.spmm_row_major(0..5, &x, d, &mut rm_split[..5 * d]);
        a.spmm_row_major(5..11, &x, d, &mut rm_split[5 * d..]);
        for r in 0..11 {
            for b in 0..d {
                let expect = spmm_out[b * 11 + r];
                assert_eq!(rm[r * d + b].to_bits(), expect.to_bits(), "({r},{b})");
                assert_eq!(rm_split[r * d + b].to_bits(), expect.to_bits(), "split ({r},{b})");
            }
        }
    }

    #[test]
    fn spmm_row_major_tiled_matches_scalar_reference_at_all_tail_widths() {
        // d below, at, and straddling the SPMM_LANES=8 tile — the tiled
        // kernel must match the untiled ascending-nz walk bit for bit.
        let mut triplets = Vec::new();
        for r in 0..17u32 {
            for c in 0..9u32 {
                if (r * 19 + c * 5) % 4 != 0 {
                    triplets.push((r, c, (r as f32 * 0.53 - c as f32 * 1.13).sin()));
                }
            }
        }
        let a = Csr::from_triplets(17, 9, &triplets).unwrap();
        for d in [1usize, 5, 8, 11, 16, 19] {
            let x: Vec<f32> = (0..9 * d).map(|i| ((i * 23 + 1) % 13) as f32 * 0.3 - 1.7).collect();
            let mut want = vec![0.0f32; 17 * d];
            for r in 0..17 {
                let orow = &mut want[r * d..(r + 1) * d];
                for (k, &c) in a.row_indices(r).iter().enumerate() {
                    let av = a.row_values(r)[k];
                    for (o, &v) in orow.iter_mut().zip(&x[c as usize * d..(c as usize + 1) * d]) {
                        *o += av * v;
                    }
                }
            }
            let mut got = vec![0.0f32; 17 * d];
            a.spmm_row_major(0..17, &x, d, &mut got);
            assert!(
                got.iter().zip(&want).all(|(g, w)| g.to_bits() == w.to_bits()),
                "spmm_row_major tail mismatch at d={d}"
            );
        }
    }

    #[test]
    fn sparse_normalizations_match_dense() {
        let a = small().symmetrize().unwrap();
        assert_eq!(a.gcn_normalized().unwrap().to_dense(), a.gcn_normalized_dense().unwrap());
        assert_eq!(a.row_normalized().unwrap().to_dense(), a.row_normalized_dense().unwrap());
        // Row norm: every non-empty row sums to ~1.
        let rn = a.row_normalized().unwrap();
        for r in 0..3 {
            let s: f32 = rn.row_values(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // Independent reference for Â = D^{-1/2}(A+I)D^{-1/2}.
        let n = 3usize;
        let mut with_loops = a.to_dense();
        for i in 0..n {
            with_loops[i * n + i] += 1.0;
        }
        let deg: Vec<f32> =
            (0..n).map(|r| with_loops[r * n..(r + 1) * n].iter().sum()).collect();
        let gcn = a.gcn_normalized().unwrap().to_dense();
        for r in 0..n {
            for c in 0..n {
                let expect = with_loops[r * n + c] / (deg[r].sqrt() * deg[c].sqrt());
                assert!((gcn[r * n + c] - expect).abs() < 1e-6, "({r},{c})");
            }
        }
        // Dispatch helper.
        assert_eq!(a.normalized("raw").unwrap(), a);
        assert!(a.normalized("sym_norm").is_ok());
        assert!(a.normalized("row_norm").is_ok());
        assert!(a.normalized("bogus").is_err());
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::from_edges(4, &[(0, 1)]).unwrap();
        assert_eq!(a.degree(2), 0);
        assert_eq!(a.row_indices(2), &[] as &[u32]);
        let mut out = vec![0.0; 4];
        a.spmv(&[1.0; 4], &mut out);
        assert_eq!(out, vec![1.0, 0.0, 0.0, 0.0]);
    }
}
