//! JSON writer (pretty and compact, deterministic key order via BTreeMap).

use super::Json;

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out.push('\n');
    out
}

/// Serialize onto one line with no trailing newline — the framing the
/// NDJSON serving protocol requires (one JSON value per line; embedded
/// newlines in strings are escaped by the writer, so the output never
/// spans lines).
pub fn to_string_compact(v: &Json) -> String {
    let mut out = String::new();
    write_compact(v, &mut out);
    out
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(x, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_compact(x, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Json, indent: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            // Small all-scalar arrays inline (shape lists stay readable).
            let scalar = a.iter().all(|x| matches!(x, Json::Num(_) | Json::Bool(_) | Json::Null));
            if scalar && a.len() <= 16 {
                out.push('[');
                for (i, x) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    write_value(x, indent, out);
                }
                out.push(']');
                return;
            }
            out.push_str("[\n");
            for (i, x) in a.iter().enumerate() {
                pad(indent + 1, out);
                write_value(x, indent + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push(']');
        }
        Json::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, x)) in o.iter().enumerate() {
                pad(indent + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_value(x, indent + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ser::parse;

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(to_string_pretty(&Json::Num(5.0)).trim(), "5");
        assert_eq!(to_string_pretty(&Json::Num(0.5)).trim(), "0.5");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line1\nline2\t\"q\" \\ \u{0001}".into());
        let s = to_string_pretty(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let v = Json::obj(vec![
            ("op", Json::str("embed")),
            ("nodes", Json::arr_num([0.0, 1.0, 2.0])),
            ("note", Json::Str("line1\nline2".into())),
            ("nested", Json::obj(vec![("k", Json::Bool(true)), ("z", Json::Null)])),
        ]);
        let s = to_string_compact(&v);
        assert!(!s.contains('\n'), "compact output must be one line: {s:?}");
        assert_eq!(parse(&s).unwrap(), v);
        assert_eq!(to_string_compact(&Json::Arr(vec![])), "[]");
        assert_eq!(to_string_compact(&Json::obj(vec![])), "{}");
    }

    #[test]
    fn deterministic_output() {
        let v = Json::obj(vec![("b", Json::num(1.0)), ("a", Json::num(2.0))]);
        let s1 = to_string_pretty(&v);
        let s2 = to_string_pretty(&v);
        assert_eq!(s1, s2);
        // BTreeMap: keys sorted.
        assert!(s1.find("\"a\"").unwrap() < s1.find("\"b\"").unwrap());
    }
}
