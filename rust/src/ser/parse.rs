//! Recursive-descent JSON parser.

use std::collections::BTreeMap;

use super::Json;
use crate::{Error, Result};

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters after JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        // Report 1-based line/col for debuggability.
        let mut line = 1usize;
        let mut col = 1usize;
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line}, col {col}"))
    }

    #[inline]
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    #[inline]
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected '{lit}')")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated UTF-8 sequence"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        assert_eq!(parse(r#""a\nb\t\"c\"""#).unwrap(), Json::Str("a\nb\t\"c\"".into()));
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123 junk").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_reports_position() {
        let e = parse("{\n  \"a\": ?\n}").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": {"b": [1, [2, {"c": null}]]}}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap();
        assert_eq!(inner.as_arr().unwrap().len(), 2);
    }
}
