//! Minimal JSON substrate (the offline crate set has no `serde`).
//!
//! Provides a [`Json`] value model, a recursive-descent parser, and a
//! writer. Used for the AOT artifact manifests written by
//! `python/compile/aot.py`, experiment configs, and report output.
//!
//! Scope: full JSON except that numbers are parsed as `f64` (the manifests
//! only carry shapes, names and hyper-parameters — all exactly
//! representable).

#[cfg(all(feature = "mmap", unix))]
pub mod mmap;
mod parse;
pub mod section;
mod write;

pub use parse::parse;
pub use write::{to_string_compact, to_string_pretty};

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed JSON value. Objects use `BTreeMap` so output ordering is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn arr_num<I: IntoIterator<Item = f64>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    pub fn arr_usize<'a, I: IntoIterator<Item = &'a usize>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(|&u| Json::Num(u as f64)).collect())
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Json(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// `[1,2,3]` → `vec![1,2,3]` of usize (shape lists in manifests).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
}

/// FNV-1a 64-bit hash — the integrity checksum the binary artifact
/// headers carry (checkpoints, code files, serving bundles). Not
/// cryptographic; it guards against truncation and bit rot, not tampering.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Frame a binary artifact payload with the shared 24-byte envelope:
/// 8-byte ASCII magic, payload byte count (u64 LE), FNV-1a checksum of
/// the payload (u64 LE), then the payload. Checkpoints (`HGNP0002`),
/// code files (`HGNC0002`), serving bundles (`HGNB0001`) and shard
/// files (`HGNS0001`) all use this one framing, so truncation and bit
/// rot are caught the same way everywhere.
pub fn write_envelope(magic: &[u8; 8], payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(24 + payload.len());
    buf.extend_from_slice(magic);
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Validate a [`write_envelope`] frame and return `(matched magic index,
/// payload)`. `magics` lists every acceptable magic (bundle loading
/// accepts both the whole-bundle and the shard magic); `kind` names the
/// artifact in error messages ("checkpoint", "code file", ...). The
/// payload is checked against the header's byte count and checksum
/// before the caller decodes a single field.
pub fn read_envelope<'a>(
    buf: &'a [u8],
    magics: &[&[u8; 8]],
    kind: &str,
    path: &std::path::Path,
) -> Result<(usize, &'a [u8])> {
    let which = if buf.len() >= 24 {
        magics.iter().position(|m| buf[..8] == m[..])
    } else {
        None
    };
    let which = match which {
        Some(w) => w,
        None => {
            return Err(Error::Config(format!(
                "{}: not a {kind} (bad magic or shorter than the header)",
                path.display()
            )))
        }
    };
    let expect_len = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    let expect_sum = u64::from_le_bytes(buf[16..24].try_into().unwrap());
    let payload = &buf[24..];
    if payload.len() != expect_len {
        return Err(Error::Config(format!(
            "{}: {kind} payload is {} bytes, header says {expect_len} (truncated?)",
            path.display(),
            payload.len()
        )));
    }
    let got = fnv1a64(payload);
    if got != expect_sum {
        return Err(Error::Config(format!(
            "{}: {kind} checksum mismatch ({got:#018x} != {expect_sum:#018x}) — file is corrupt",
            path.display()
        )));
    }
    Ok((which, payload))
}

/// Read and parse a JSON file.
pub fn from_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

/// Pretty-print to a file.
pub fn to_file(path: &std::path::Path, v: &Json) -> Result<()> {
    std::fs::write(path, to_string_pretty(v))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::str("decoder")),
            ("shapes", Json::Arr(vec![Json::arr_num([2.0, 3.0]), Json::arr_num([4.0])])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("lr", Json::num(0.001)),
        ]);
        let s = to_string_pretty(&v);
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": [1, 2, 3], "b": "x", "c": 4.5, "d": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("c").unwrap().as_f64().unwrap(), 4.5);
        assert!(!v.get("d").unwrap().as_bool().unwrap());
        assert!(v.get("zzz").is_err());
        assert!(v.opt("zzz").is_none());
    }

    #[test]
    fn fnv1a64_known_vectors_and_sensitivity() {
        // Reference FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // Single-bit flips change the hash.
        assert_ne!(fnv1a64(b"hashgnn"), fnv1a64(b"iashgnn"));
    }

    #[test]
    fn envelope_roundtrips_and_rejects_damage() {
        let path = std::path::Path::new("mem.bin");
        let framed = write_envelope(b"HGNT0001", b"hello payload");
        assert_eq!(framed.len(), 24 + 13);
        let (which, payload) =
            read_envelope(&framed, &[b"HGNX0001", b"HGNT0001"], "test artifact", path).unwrap();
        assert_eq!(which, 1);
        assert_eq!(payload, b"hello payload");

        // Wrong magic / short buffer.
        let err = read_envelope(&framed, &[b"HGNX0001"], "test artifact", path).unwrap_err();
        assert!(format!("{err}").contains("not a test artifact"), "{err}");
        assert!(read_envelope(b"short", &[b"HGNT0001"], "t", path).is_err());

        // Truncated payload fails the byte count.
        let err = read_envelope(&framed[..framed.len() - 1], &[b"HGNT0001"], "t", path)
            .unwrap_err();
        assert!(format!("{err}").contains("header says"), "{err}");

        // Flipped payload byte fails the checksum.
        let mut bad = framed.clone();
        bad[30] ^= 0x40;
        let err = read_envelope(&bad, &[b"HGNT0001"], "t", path).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert!(Json::Num(1.5).as_usize().is_err());
        assert!(Json::Num(-2.0).as_usize().is_err());
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
    }
}
