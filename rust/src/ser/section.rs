//! Fixed-offset section tables — the zero-copy artifact framing behind
//! the `HGNB0002` / `HGNS0002` serving formats.
//!
//! The legacy [`super::write_envelope`] framing (`HGNB0001`, checkpoints,
//! code files) checksums one opaque payload, which forces loaders to walk
//! a sequential parse loop and heap-copy every field. A section file
//! instead publishes a **directory of typed, 64-byte-aligned sections**
//! up front, so a loader can (a) verify the directory *before* touching a
//! single payload byte — truncation is reported **by section name**, not
//! as a generic checksum failure after reading the whole file — and
//! (b) hand out **borrowed in-place views** (`&[u32]` / `&[u64]` /
//! `&[f32]`) straight into one backing buffer: no per-section `Vec`
//! copies, no parse loop, and an identical layout whether the backing is
//! a heap read or an `mmap` (the default-off `mmap` cargo feature,
//! [`super::mmap`]).
//!
//! # Layout (all little-endian)
//!
//! ```text
//! offset 0    8-byte ASCII magic (format version lives in the magic)
//! offset 8    u64 section count
//! offset 16   u64 total file length in bytes
//! offset 24   u64 FNV-1a of the directory bytes
//! offset 32   32 zero bytes (reserved)
//! offset 64   directory: count × 32-byte entries
//!               { u64 tag (8 ASCII bytes), u64 offset, u64 len,
//!                 u64 FNV-1a of the payload bytes }
//! ...         payloads, each starting at a 64-byte-aligned offset, in
//!             directory order, zero-padded between sections
//! ```
//!
//! The alignment rule is what makes in-place typed views sound: every
//! payload offset is a multiple of 64, the heap backing is allocated as
//! `u64` words (8-byte-aligned base) and an `mmap` base is page-aligned,
//! so a `&[f32]` / `&[u32]` / `&[u64]` view at any section offset is
//! always correctly aligned. Offsets are absolute file offsets; `len` is
//! the exact payload byte count (padding is excluded from the checksum).
//!
//! Open order is fail-fast: header bounds → magic → declared total
//! length vs. actual → directory checksum → per-section bounds (named
//! errors) → per-section checksums (named errors). Only then are views
//! handed out, so a corrupt file can never be partially served.

use std::path::Path;
use std::sync::Arc;

use crate::{Error, Result};

use super::fnv1a64;

// In-place views reinterpret little-endian file bytes as host integers /
// floats. Every rust_pallas deployment target is little-endian; a
// big-endian port would need decode-on-read accessors here instead.
#[cfg(target_endian = "big")]
compile_error!(
    "ser::section hands out in-place &[u32]/&[u64]/&[f32] views of little-endian \
     file bytes and therefore requires a little-endian target"
);

/// Section payload alignment (bytes). Also the header size.
pub const ALIGN: usize = 64;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 64;
/// Directory entry size in bytes.
pub const DIR_ENTRY_LEN: usize = 32;
/// Sanity cap on the declared section count (a corrupt header must not
/// drive a multi-GiB directory allocation).
pub const MAX_SECTIONS: usize = 4096;

/// An 8-byte ASCII section tag (zero-padded on the right).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Tag(pub [u8; 8]);

impl Tag {
    /// Human name for error messages: trailing zero bytes stripped.
    pub fn name(&self) -> String {
        let end = self.0.iter().position(|&b| b == 0).unwrap_or(8);
        String::from_utf8_lossy(&self.0[..end]).into_owned()
    }
}

impl std::fmt::Debug for Tag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tag({})", self.name())
    }
}

fn align_up(v: usize) -> usize {
    v.div_ceil(ALIGN) * ALIGN
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

/// Assemble a section file: append sections, then [`SectionWriter::finish`]
/// computes offsets, per-section checksums and the directory checksum.
#[derive(Default)]
pub struct SectionWriter {
    sections: Vec<(Tag, Vec<u8>)>,
}

impl SectionWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new section and return its payload buffer to fill.
    /// Sections are written in call order; duplicate tags are a logic
    /// error caught at `finish`.
    pub fn section(&mut self, tag: [u8; 8]) -> &mut Vec<u8> {
        self.sections.push((Tag(tag), Vec::new()));
        &mut self.sections.last_mut().expect("just pushed").1
    }

    /// Serialize: header + directory + aligned payloads, checksums filled.
    pub fn finish(self, magic: &[u8; 8]) -> Result<Vec<u8>> {
        let n = self.sections.len();
        if n > MAX_SECTIONS {
            return Err(Error::Config(format!(
                "section file would carry {n} sections (cap {MAX_SECTIONS})"
            )));
        }
        for (i, (tag, _)) in self.sections.iter().enumerate() {
            if self.sections[..i].iter().any(|(t, _)| t == tag) {
                return Err(Error::Config(format!(
                    "duplicate section tag '{}' in section file",
                    tag.name()
                )));
            }
        }
        let dir_end = HEADER_LEN + n * DIR_ENTRY_LEN;
        let mut offset = align_up(dir_end);
        let mut entries = Vec::with_capacity(n);
        for (tag, payload) in &self.sections {
            entries.push((*tag, offset, payload.len(), fnv1a64(payload)));
            offset = align_up(offset + payload.len());
        }
        // Total length: end of the last payload (unpadded) — or the padded
        // directory end when there are no sections.
        let total = entries
            .last()
            .map(|&(_, off, len, _)| off + len)
            .unwrap_or_else(|| align_up(dir_end));

        let mut dir = Vec::with_capacity(n * DIR_ENTRY_LEN);
        for &(tag, off, len, sum) in &entries {
            dir.extend_from_slice(&tag.0);
            dir.extend_from_slice(&(off as u64).to_le_bytes());
            dir.extend_from_slice(&(len as u64).to_le_bytes());
            dir.extend_from_slice(&sum.to_le_bytes());
        }

        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(magic);
        out.extend_from_slice(&(n as u64).to_le_bytes());
        out.extend_from_slice(&(total as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&dir).to_le_bytes());
        out.resize(HEADER_LEN, 0);
        out.extend_from_slice(&dir);
        for ((_, payload), &(_, off, _, _)) in self.sections.iter().zip(&entries) {
            out.resize(off, 0); // zero pad up to the aligned offset
            out.extend_from_slice(payload);
        }
        debug_assert_eq!(out.len(), total);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Backing buffer
// ---------------------------------------------------------------------------

/// The single backing buffer every borrowed view points into: a heap
/// read (allocated as `u64` words so the base is 8-byte-aligned) or a
/// read-only file mapping behind the `mmap` feature. Shared by
/// `Arc` — views each hold a clone, so a loaded bundle is freely
/// clonable and `Sync` without self-referential lifetimes.
pub struct SectionBuf {
    repr: Repr,
}

enum Repr {
    /// `words` holds `len.div_ceil(8)` u64s; the live bytes are the first
    /// `len` of its byte view.
    Heap { words: Vec<u64>, len: usize },
    #[cfg(all(feature = "mmap", unix))]
    Map(super::mmap::Map),
}

impl SectionBuf {
    /// Read a whole file into an 8-byte-aligned heap buffer (one read,
    /// the zero-dependency default path).
    pub fn read_heap(path: &Path) -> Result<Arc<Self>> {
        let mut f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        let mut words = vec![0u64; len.div_ceil(8)];
        {
            // &mut [u8] view of the word buffer: u64 → u8 loosens
            // alignment and both types have no padding, so this is sound.
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len)
            };
            std::io::Read::read_exact(&mut f, bytes)?;
        }
        Ok(Arc::new(Self { repr: Repr::Heap { words, len } }))
    }

    /// Map a file read-only (`mmap` feature): K worker processes serving
    /// the same bundle share the page cache instead of K heap copies.
    #[cfg(all(feature = "mmap", unix))]
    pub fn map(path: &Path) -> Result<Arc<Self>> {
        Ok(Arc::new(Self { repr: Repr::Map(super::mmap::Map::open(path)?) }))
    }

    /// Wrap an in-memory image (tests; the writer's output can be opened
    /// without a filesystem round-trip).
    pub fn from_bytes(bytes: &[u8]) -> Arc<Self> {
        let len = bytes.len();
        let mut words = vec![0u64; len.div_ceil(8)];
        let dst = unsafe {
            std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, len)
        };
        dst.copy_from_slice(bytes);
        Arc::new(Self { repr: Repr::Heap { words, len } })
    }

    /// The whole backing as bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.repr {
            Repr::Heap { words, len } => unsafe {
                std::slice::from_raw_parts(words.as_ptr() as *const u8, *len)
            },
            #[cfg(all(feature = "mmap", unix))]
            Repr::Map(m) => m.bytes(),
        }
    }

    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::Heap { len, .. } => *len,
            #[cfg(all(feature = "mmap", unix))]
            Repr::Map(m) => m.bytes().len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the backing is a file mapping rather than a heap read.
    pub fn is_mapped(&self) -> bool {
        match &self.repr {
            Repr::Heap { .. } => false,
            #[cfg(all(feature = "mmap", unix))]
            Repr::Map(_) => true,
        }
    }

    fn check_typed(&self, off: usize, byte_len: usize, align: usize, what: &str) -> Result<()> {
        if off % align != 0 {
            return Err(Error::Config(format!(
                "section view: {what} at offset {off} is not {align}-byte aligned"
            )));
        }
        if off + byte_len > self.len() {
            return Err(Error::Config(format!(
                "section view: {what} [{off}, {}) exceeds the {}-byte backing",
                off + byte_len,
                self.len()
            )));
        }
        Ok(())
    }
}

// A mapped buffer is read-only for its whole lifetime, so sharing
// references across the serving worker pool is safe. (The heap variant is
// Send + Sync automatically; the raw-pointer map needs the explicit vouch,
// which lives on `mmap::Map` itself.)

macro_rules! shared_view {
    ($name:ident, $ty:ty, $doc:literal) => {
        #[doc = $doc]
        #[derive(Clone)]
        pub struct $name {
            buf: Arc<SectionBuf>,
            off: usize,
            n: usize,
        }

        impl $name {
            /// Validated construction: `off` (bytes) must be aligned for
            /// the element type and `n` elements must fit the backing.
            pub fn new(buf: Arc<SectionBuf>, off: usize, n: usize) -> Result<Self> {
                let elem = std::mem::size_of::<$ty>();
                buf.check_typed(off, n * elem, std::mem::align_of::<$ty>(), stringify!($name))?;
                Ok(Self { buf, off, n })
            }

            #[inline]
            pub fn as_slice(&self) -> &[$ty] {
                // Alignment and bounds were validated at construction and
                // the backing is immutable and pinned by the Arc.
                unsafe {
                    std::slice::from_raw_parts(
                        self.buf.bytes().as_ptr().add(self.off) as *const $ty,
                        self.n,
                    )
                }
            }

            pub fn len(&self) -> usize {
                self.n
            }

            pub fn is_empty(&self) -> bool {
                self.n == 0
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}(off={}, n={})", stringify!($name), self.off, self.n)
            }
        }
    };
}

shared_view!(SharedU64s, u64, "Borrowed `&[u64]` view into a [`SectionBuf`].");
shared_view!(SharedU32s, u32, "Borrowed `&[u32]` view into a [`SectionBuf`].");
shared_view!(SharedF32s, f32, "Borrowed `&[f32]` view into a [`SectionBuf`].");
shared_view!(SharedBytes, u8, "Borrowed `&[u8]` view into a [`SectionBuf`].");

// ---------------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------------

/// One verified directory entry.
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    pub tag: Tag,
    pub offset: usize,
    pub len: usize,
}

/// A parsed, fully-verified section file: directory checked first, then
/// every section's bounds and checksum — all before any view is handed
/// out. Accessors return borrowed views; nothing is copied.
pub struct SectionFile {
    buf: Arc<SectionBuf>,
    entries: Vec<Entry>,
    magic_index: usize,
    kind: String,
    path: std::path::PathBuf,
}

impl SectionFile {
    /// Parse and verify an already-loaded backing. `magics` lists every
    /// acceptable magic; `kind` names the artifact in errors.
    pub fn parse(
        buf: Arc<SectionBuf>,
        magics: &[&[u8; 8]],
        kind: &str,
        path: &Path,
    ) -> Result<Self> {
        let bytes = buf.bytes();
        if bytes.len() < HEADER_LEN {
            return Err(Error::Config(format!(
                "{}: not a {kind} ({} bytes is shorter than the {HEADER_LEN}-byte header)",
                path.display(),
                bytes.len()
            )));
        }
        let magic_index = magics
            .iter()
            .position(|m| bytes[..8] == m[..])
            .ok_or_else(|| {
                Error::Config(format!("{}: not a {kind} (bad magic)", path.display()))
            })?;
        let u64_at = |off: usize| u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
        let count = u64_at(8) as usize;
        let total = u64_at(16) as usize;
        let dir_sum = u64_at(24);
        if count > MAX_SECTIONS {
            return Err(Error::Config(format!(
                "{}: {kind} declares {count} sections (cap {MAX_SECTIONS}) — corrupt header?",
                path.display()
            )));
        }
        if bytes.len() > total {
            return Err(Error::Config(format!(
                "{}: {kind} is {} bytes, header says {total} — trailing bytes?",
                path.display(),
                bytes.len()
            )));
        }
        // A short file (bytes.len() < total) is NOT rejected here: if the
        // directory survived, the per-entry bounds walk below names the
        // first section the cut landed in — far more actionable than a
        // generic length mismatch.
        let dir_end = HEADER_LEN + count * DIR_ENTRY_LEN;
        if dir_end > bytes.len() {
            return Err(Error::Config(format!(
                "{}: {kind} section directory ({count} entries) is truncated",
                path.display()
            )));
        }
        // Directory integrity FIRST — before a single payload byte is
        // trusted, so every later error can name its section.
        let dir = &bytes[HEADER_LEN..dir_end];
        if fnv1a64(dir) != dir_sum {
            return Err(Error::Config(format!(
                "{}: {kind} section directory checksum mismatch — refusing to decode",
                path.display()
            )));
        }
        let mut entries = Vec::with_capacity(count);
        let mut prev_end = dir_end;
        for i in 0..count {
            let e = HEADER_LEN + i * DIR_ENTRY_LEN;
            let mut tag = [0u8; 8];
            tag.copy_from_slice(&bytes[e..e + 8]);
            let tag = Tag(tag);
            let offset = u64_at(e + 8) as usize;
            let len = u64_at(e + 16) as usize;
            if offset % ALIGN != 0 {
                return Err(Error::Config(format!(
                    "{}: {kind} section '{}' offset {offset} is not {ALIGN}-byte aligned",
                    path.display(),
                    tag.name()
                )));
            }
            if offset < prev_end {
                return Err(Error::Config(format!(
                    "{}: {kind} section '{}' overlaps the previous section",
                    path.display(),
                    tag.name()
                )));
            }
            // Fail fast, by name: a truncated file is reported against the
            // first section whose payload falls outside the actual bytes.
            if offset.checked_add(len).map(|end| end > bytes.len()).unwrap_or(true) {
                return Err(Error::Config(format!(
                    "{}: {kind} section '{}' truncated — needs {len} bytes at offset \
                     {offset}, file has {}",
                    path.display(),
                    tag.name(),
                    bytes.len()
                )));
            }
            prev_end = offset + len;
            entries.push(Entry { tag, offset, len });
        }
        // Every section fit, so a remaining length mismatch means the
        // header itself lied about the total.
        if total != bytes.len() {
            return Err(Error::Config(format!(
                "{}: {kind} is {} bytes, header says {total} (truncated?)",
                path.display(),
                bytes.len()
            )));
        }
        // Payload integrity, still before any decoding — one sequential
        // hashing pass per section, zero copies.
        for (i, e) in entries.iter().enumerate() {
            let d = HEADER_LEN + i * DIR_ENTRY_LEN;
            let expect = u64_at(d + 24);
            let got = fnv1a64(&bytes[e.offset..e.offset + e.len]);
            if got != expect {
                return Err(Error::Config(format!(
                    "{}: {kind} section '{}' checksum mismatch \
                     (stored {expect:#018x}, computed {got:#018x})",
                    path.display(),
                    e.tag.name()
                )));
            }
        }
        Ok(Self {
            buf,
            entries,
            magic_index,
            kind: kind.to_string(),
            path: path.to_path_buf(),
        })
    }

    /// Read + verify from disk into the heap backing.
    pub fn open_heap(path: &Path, magics: &[&[u8; 8]], kind: &str) -> Result<Self> {
        Self::parse(SectionBuf::read_heap(path)?, magics, kind, path)
    }

    /// Map + verify (`mmap` feature): checksums stream through the
    /// mapping once; pages stay shared across processes.
    #[cfg(all(feature = "mmap", unix))]
    pub fn open_mmap(path: &Path, magics: &[&[u8; 8]], kind: &str) -> Result<Self> {
        Self::parse(SectionBuf::map(path)?, magics, kind, path)
    }

    /// Which of the accepted magics matched.
    pub fn magic_index(&self) -> usize {
        self.magic_index
    }

    pub fn backing(&self) -> &Arc<SectionBuf> {
        &self.buf
    }

    pub fn entries(&self) -> &[Entry] {
        &self.entries
    }

    pub fn find(&self, tag: [u8; 8]) -> Option<Entry> {
        self.entries.iter().find(|e| e.tag == Tag(tag)).copied()
    }

    pub fn has(&self, tag: [u8; 8]) -> bool {
        self.find(tag).is_some()
    }

    fn require(&self, tag: [u8; 8]) -> Result<Entry> {
        self.find(tag).ok_or_else(|| {
            Error::Config(format!(
                "{}: {} has no '{}' section",
                self.path.display(),
                self.kind,
                Tag(tag).name()
            ))
        })
    }

    fn elems(&self, tag: [u8; 8], elem: usize) -> Result<(Entry, usize)> {
        let e = self.require(tag)?;
        if e.len % elem != 0 {
            return Err(Error::Config(format!(
                "{}: {} section '{}' holds {} bytes, not a multiple of {elem}",
                self.path.display(),
                self.kind,
                e.tag.name(),
                e.len
            )));
        }
        Ok((e, e.len / elem))
    }

    /// Borrowed raw bytes of a section.
    pub fn bytes(&self, tag: [u8; 8]) -> Result<SharedBytes> {
        let e = self.require(tag)?;
        SharedBytes::new(self.buf.clone(), e.offset, e.len)
    }

    /// Borrowed `&[u64]` view of a section.
    pub fn u64s(&self, tag: [u8; 8]) -> Result<SharedU64s> {
        let (e, n) = self.elems(tag, 8)?;
        SharedU64s::new(self.buf.clone(), e.offset, n)
    }

    /// Borrowed `&[u32]` view of a section.
    pub fn u32s(&self, tag: [u8; 8]) -> Result<SharedU32s> {
        let (e, n) = self.elems(tag, 4)?;
        SharedU32s::new(self.buf.clone(), e.offset, n)
    }

    /// Borrowed `&[f32]` view of a section.
    pub fn f32s(&self, tag: [u8; 8]) -> Result<SharedF32s> {
        let (e, n) = self.elems(tag, 4)?;
        SharedF32s::new(self.buf.clone(), e.offset, n)
    }

    /// UTF-8 text of a section (manifest JSON).
    pub fn text(&self, tag: [u8; 8]) -> Result<&str> {
        let e = self.require(tag)?;
        std::str::from_utf8(&self.buf.bytes()[e.offset..e.offset + e.len]).map_err(|_| {
            Error::Config(format!(
                "{}: {} section '{}' is not UTF-8",
                self.path.display(),
                self.kind,
                e.tag.name()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    const MAGIC: &[u8; 8] = b"HGNT0002";

    fn build(sections: &[([u8; 8], Vec<u8>)]) -> Vec<u8> {
        let mut w = SectionWriter::new();
        for (tag, data) in sections {
            w.section(*tag).extend_from_slice(data);
        }
        w.finish(MAGIC).unwrap()
    }

    fn parse(bytes: &[u8]) -> Result<SectionFile> {
        SectionFile::parse(
            SectionBuf::from_bytes(bytes),
            &[MAGIC],
            "test artifact",
            Path::new("mem"),
        )
    }

    #[test]
    fn roundtrip_views_are_exact_and_aligned() {
        let a: Vec<u8> = (0..13).collect();
        let b: Vec<u8> = 100u64.to_le_bytes().into_iter().chain(7u64.to_le_bytes()).collect();
        let img = build(&[(*b"AAAAAAAA", a.clone()), (*b"BBBB\0\0\0\0", b)]);
        let f = parse(&img).unwrap();
        assert_eq!(f.entries().len(), 2);
        for e in f.entries() {
            assert_eq!(e.offset % ALIGN, 0, "section '{}' misaligned", e.tag.name());
        }
        assert_eq!(f.bytes(*b"AAAAAAAA").unwrap().as_slice(), &a[..]);
        assert_eq!(f.u64s(*b"BBBB\0\0\0\0").unwrap().as_slice(), &[100, 7]);
        assert!(f.find(*b"CCCCCCCC").is_none());
        assert!(f.u64s(*b"CCCCCCCC").is_err());
        // Odd-length section can't be viewed as u64s.
        assert!(f.u64s(*b"AAAAAAAA").is_err());
    }

    #[test]
    fn alignment_and_padding_roundtrip_property() {
        // Random section-size vectors: every offset must be 64-aligned,
        // every payload must come back byte-exact, and the declared total
        // must equal the file length.
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        for trial in 0..50u64 {
            let n_sections = 1 + rng.index(6);
            let sections: Vec<([u8; 8], Vec<u8>)> = (0..n_sections)
                .map(|i| {
                    let mut tag = *b"S\0\0\0\0\0\0\0";
                    tag[1] = b'0' + i as u8;
                    let len = rng.index(300); // includes 0 and non-multiples of 64
                    let data: Vec<u8> = (0..len).map(|_| rng.index(256) as u8).collect();
                    (tag, data)
                })
                .collect();
            let img = build(&sections);
            let f = parse(&img).unwrap();
            assert_eq!(f.entries().len(), n_sections, "trial {trial}");
            let mut prev_end = HEADER_LEN + n_sections * DIR_ENTRY_LEN;
            for (e, (tag, data)) in f.entries().iter().zip(&sections) {
                assert_eq!(e.tag, Tag(*tag));
                assert_eq!(e.offset % ALIGN, 0, "trial {trial}: offset {}", e.offset);
                assert!(e.offset >= prev_end, "trial {trial}: overlap");
                // Inter-section padding is zero bytes.
                assert!(
                    img[prev_end..e.offset].iter().all(|&b| b == 0),
                    "trial {trial}: nonzero padding"
                );
                assert_eq!(f.bytes(*tag).unwrap().as_slice(), &data[..], "trial {trial}");
                prev_end = e.offset + e.len;
            }
            assert_eq!(img.len(), prev_end, "trial {trial}: total length");
        }
    }

    #[test]
    fn truncation_fails_fast_with_the_section_name() {
        let img = build(&[
            (*b"MANIFEST", vec![1; 40]),
            (*b"EDGES\0\0\0", vec![2; 200]),
        ]);
        // Cut inside the second payload: the error must name EDGES and
        // fire from the directory check, not a whole-file checksum.
        let cut = &img[..img.len() - 50];
        let err = parse(cut).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("EDGES"), "{msg}");
        assert!(msg.contains("truncated") || msg.contains("header says"), "{msg}");
        // Cut inside the directory itself.
        let cut = &img[..HEADER_LEN + DIR_ENTRY_LEN / 2];
        assert!(parse(cut).is_err());
        // Shorter than the header.
        assert!(parse(&img[..10]).is_err());
    }

    #[test]
    fn corrupt_directory_and_payload_are_distinguished() {
        let img = build(&[(*b"PARAMF32", vec![9; 64]), (*b"CODEWORD", vec![7; 16])]);
        // Flip a directory byte → directory checksum error.
        let mut bad = img.clone();
        bad[HEADER_LEN + 9] ^= 0x40;
        // Keep the total-length field honest so we reach the dir check.
        let err = parse(&bad).unwrap_err();
        assert!(format!("{err}").contains("directory checksum"), "{err}");
        // Flip a payload byte → error names the section.
        let f = parse(&img).unwrap();
        let e = f.find(*b"CODEWORD").unwrap();
        let mut bad = img.clone();
        bad[e.offset + 3] ^= 0x01;
        let err = parse(&bad).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("CODEWORD") && msg.contains("checksum"), "{msg}");
    }

    #[test]
    fn wrong_magic_and_bad_counts_rejected() {
        let img = build(&[(*b"AAAAAAAA", vec![1, 2, 3])]);
        let err = SectionFile::parse(
            SectionBuf::from_bytes(&img),
            &[b"XXXX0002"],
            "test artifact",
            Path::new("mem"),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("bad magic"), "{err}");
        // Absurd section count.
        let mut bad = img.clone();
        bad[8..16].copy_from_slice(&(u64::MAX).to_le_bytes());
        assert!(parse(&bad).is_err());
    }

    #[test]
    fn duplicate_tags_rejected_at_write() {
        let mut w = SectionWriter::new();
        w.section(*b"AAAAAAAA").push(1);
        w.section(*b"AAAAAAAA").push(2);
        assert!(w.finish(MAGIC).is_err());
    }

    #[test]
    fn empty_sections_and_empty_files_roundtrip() {
        let img = build(&[(*b"EMPTY\0\0\0", vec![]), (*b"DATA\0\0\0\0", vec![5])]);
        let f = parse(&img).unwrap();
        assert!(f.bytes(*b"EMPTY\0\0\0").unwrap().is_empty());
        assert_eq!(f.bytes(*b"DATA\0\0\0\0").unwrap().as_slice(), &[5]);
        let img = build(&[]);
        let f = parse(&img).unwrap();
        assert!(f.entries().is_empty());
    }

    #[test]
    fn typed_views_reject_misalignment_out_of_band() {
        // Direct SharedU64s construction with a bad offset must fail even
        // though SectionFile never produces one.
        let buf = SectionBuf::from_bytes(&[0u8; 64]);
        assert!(SharedU64s::new(buf.clone(), 4, 2).is_err());
        assert!(SharedU64s::new(buf.clone(), 0, 9).is_err(), "out of bounds");
        assert_eq!(SharedU64s::new(buf, 0, 8).unwrap().as_slice(), &[0u64; 8]);
    }
}
