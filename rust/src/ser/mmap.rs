//! Read-only file mapping behind the default-off `mmap` cargo feature.
//!
//! The crate is zero-dependency, so instead of pulling in `libc` this
//! module declares the two syscall wrappers it needs directly (they are
//! part of every Unix libc ABI the crate targets). The feature mirrors
//! the `xla` pattern: default-off, the heap read in
//! [`super::section::SectionBuf::read_heap`] stays the portable default,
//! and nothing outside `ser/` touches a raw pointer.
//!
//! Why map at all: K shard-worker processes serving the same bundle file
//! share its page-cache pages instead of making K heap copies, and a
//! multi-GB bundle starts serving after reading only the header +
//! directory + one checksum pass (the kernel pages payloads in on
//! demand).

use std::os::unix::io::AsRawFd;
use std::path::Path;

use crate::{Error, Result};

// Stable POSIX constants (identical on linux and macOS for these flags).
const PROT_READ: i32 = 1;
const MAP_SHARED: i32 = 1;

extern "C" {
    fn mmap(
        addr: *mut core::ffi::c_void,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut core::ffi::c_void;
    fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
}

/// A read-only, shared, whole-file mapping. Unmapped on drop.
pub struct Map {
    ptr: *const u8,
    len: usize,
}

// The mapping is PROT_READ for its whole lifetime and never remapped, so
// concurrent reads from any thread are safe.
unsafe impl Send for Map {}
unsafe impl Sync for Map {}

impl Map {
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            // mmap of length 0 is EINVAL; an empty artifact can't be a
            // section file anyway (no header), so surface that directly.
            return Err(Error::Config(format!(
                "{}: cannot map an empty file",
                path.display()
            )));
        }
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_SHARED,
                f.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1.
        if ptr as isize == -1 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        // `f` closes on return; the mapping keeps the pages alive.
        Ok(Self { ptr: ptr as *const u8, len })
    }

    #[inline]
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Map {
    fn drop(&mut self) {
        unsafe {
            munmap(self.ptr as *mut core::ffi::c_void, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_a_file_and_reads_it_back() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hashgnn_mmap_test_{}.bin", std::process::id()));
        let data: Vec<u8> = (0..=255).cycle().take(1000).collect();
        std::fs::write(&path, &data).unwrap();
        let m = Map::open(&path).unwrap();
        assert_eq!(m.bytes(), &data[..]);
        drop(m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hashgnn_mmap_empty_{}.bin", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        assert!(Map::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
