//! Host-side tensors and the [`xla::Literal`] bridge.

#[cfg(not(feature = "xla"))]
use crate::xla;
use crate::{Error, Result};

/// A host tensor: shape + data. Only the two dtypes the artifacts use.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "f32 tensor: shape {shape:?} needs {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "i32 tensor: shape {shape:?} needs {expect} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor::I32 { shape, data })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => Err(Error::Shape("expected f32 tensor, got i32".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => Err(Error::Shape("expected i32 tensor, got f32".into())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => Err(Error::Shape("expected f32 tensor, got i32".into())),
        }
    }

    /// Scalar extraction (loss values).
    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(Error::Shape(format!("expected scalar, got {} elements", d.len())));
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (host→device copy happens at execute).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Tensor::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    /// Convert back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => Err(Error::Runtime(format!("unsupported literal type {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![3], vec![7, -1, 0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_f32(2.5);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.scalar().unwrap(), 2.5);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::scalar_f32(1.0);
        assert!(t.as_i32().is_err());
        let t = Tensor::i32(vec![1], vec![1]).unwrap();
        assert!(t.as_f32().is_err());
        assert!(t.scalar().is_err());
    }
}
