//! Execution runtime — the backend seam.
//!
//! [`Engine::load`] resolves an artifact name to a [`Model`] whose train
//! and pred [`Executable`]s run on one of two backends:
//!
//! - **Native** ([`native`]): the pure-Rust forward/backward/AdamW engine,
//!   built directly from the [`Manifest`]/[`ParamSpec`] contract. Needs no
//!   artifacts at all — names the Python exporter knows are synthesized by
//!   [`native::spec::builtin`] at the same scales (including the full
//!   Table-1 grid `node_fb_*` / `link_fb_*`, whose adjacency is a sparse
//!   CSR bound via [`Model::bind_adjacency`], never a dense `n×n`
//!   tensor). This is the default whenever HLO artifacts are absent, and
//!   the only path that works in the offline build.
//! - **Hlo**: AOT-compiled HLO text executed on the CPU PJRT client. The
//!   only code that touches the `xla` crate; without the default-off `xla`
//!   feature, `xla` here is the in-crate stub ([`crate::xla`]) and
//!   compilation returns a clean error.
//!
//! Which backend wins is governed by [`BackendKind`]
//! (`hashgnn train --backend {auto,native,xla}`): `Auto` prefers HLO when
//! the `xla` feature is compiled in *and* the artifact files exist,
//! otherwise native. Everything above this module works with [`Tensor`]s
//! and artifact names and never sees the difference — the train step is
//! the same `(params…, m…, v…, step, batch…) → (params'…, m'…, v'…, loss)`
//! tuple on both paths. Future backends (GPU, sharded, remote serving)
//! plug into the same dispatch.
//!
//! Lifecycle: [`Engine::cpu`] (or [`Engine::with_backend`]) once per
//! process → [`Engine::load`] per artifact → [`Executable::run`] per step.

mod manifest;
pub mod native;
mod tensor;

pub use manifest::{InitKind, Manifest, ParamSpec, TensorSpec};
pub use tensor::Tensor;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::cfg::BackendKind;
use crate::sparse::Csr;
#[cfg(not(feature = "xla"))]
use crate::xla;
use crate::{Error, Result};

/// Runtime entry point: a (possibly unused) PJRT client, an artifacts
/// directory and the backend policy. One per process.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    backend: BackendKind,
    /// Native-backend compute threads (`0` = all cores). Never changes
    /// results — the native kernels are bit-deterministic across counts.
    native_threads: usize,
}

impl Engine {
    /// CPU engine rooted at an artifacts directory, `Auto` backend.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Self::with_backend(artifacts_dir, BackendKind::Auto, 0)
    }

    /// CPU engine with an explicit backend policy and native thread budget.
    pub fn with_backend(
        artifacts_dir: impl Into<PathBuf>,
        backend: BackendKind,
        native_threads: usize,
    ) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts_dir: artifacts_dir.into(), backend, native_threads })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Resolve the backend for one artifact name under the engine policy.
    fn resolve(&self, name: &str) -> BackendKind {
        match self.backend {
            BackendKind::Xla => BackendKind::Xla,
            BackendKind::Native => BackendKind::Native,
            BackendKind::Auto => {
                let have_files = self.artifacts_dir.join(format!("{name}.json")).exists()
                    && self.artifacts_dir.join(format!("{name}_train.hlo.txt")).exists();
                if cfg!(feature = "xla") && have_files {
                    BackendKind::Xla
                } else {
                    BackendKind::Native
                }
            }
        }
    }

    /// Load `name` on the resolved backend. HLO: parse `<name>.json` and
    /// compile the `_train`/`_pred` HLO text. Native: load the manifest
    /// from disk when present, else synthesize it from the built-in
    /// registry — no files required.
    pub fn load(&self, name: &str) -> Result<Model> {
        match self.resolve(name) {
            BackendKind::Native => self.load_native(name),
            _ => self.load_hlo(name),
        }
    }

    fn load_hlo(&self, name: &str) -> Result<Model> {
        let manifest = Manifest::load(&self.artifacts_dir.join(format!("{name}.json")))?;
        let train = self.compile_file(&self.artifacts_dir.join(format!("{name}_train.hlo.txt")))?;
        let pred = self.compile_file(&self.artifacts_dir.join(format!("{name}_pred.hlo.txt")))?;
        Ok(Model { manifest, train, pred })
    }

    fn load_native(&self, name: &str) -> Result<Model> {
        let path = self.artifacts_dir.join(format!("{name}.json"));
        let manifest = if path.exists() {
            Manifest::load(&path)?
        } else {
            native::spec::builtin(name).ok_or_else(|| {
                Error::Runtime(format!(
                    "no artifact manifest at {} and '{name}' is not a built-in native model \
                     (native registry: {}) — run `make artifacts` for exported variants",
                    path.display(),
                    native::spec::builtin_names().join(", ")
                ))
            })?
        };
        Model::native(manifest, self.native_threads)
    }

    /// Compile a single HLO text file into an executable (HLO path only).
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable::Hlo(HloExecutable { exe }))
    }
}

/// A compiled HLO computation. The exported HLO always returns a tuple
/// (`return_tuple=True` at lowering), so `run` flattens it back into
/// tensors.
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl HloExecutable {
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// One executable computation — the backend dispatch point. Both variants
/// are pure functions of their inputs; all state lives in
/// [`crate::params::ParamStore`].
pub enum Executable {
    /// PJRT-compiled HLO artifact.
    Hlo(HloExecutable),
    /// Pure-Rust native engine.
    Native(native::NativeExec),
}

impl Executable {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self {
            Executable::Hlo(e) => e.run(inputs),
            Executable::Native(e) => e.run(inputs),
        }
    }

    /// Which backend this executable runs on (`"hlo"` / `"native"`).
    pub fn backend_name(&self) -> &'static str {
        match self {
            Executable::Hlo(_) => "hlo",
            Executable::Native(_) => "native",
        }
    }
}

/// A train/pred executable pair plus its manifest.
pub struct Model {
    pub manifest: Manifest,
    pub train: Executable,
    pub pred: Executable,
}

impl Model {
    /// Build a native-backend model directly from a manifest (no engine,
    /// no files) — the constructor tests and custom scales use. The stored
    /// manifest is the native model's normalized copy (for full-batch
    /// tasks, any dense `adj` input spec is stripped — the adjacency is
    /// bound as a CSR via [`Model::bind_adjacency`] instead).
    pub fn native(manifest: Manifest, threads: usize) -> Result<Model> {
        let nm = Arc::new(native::NativeModel::from_manifest(&manifest)?);
        let manifest = nm.manifest().clone();
        Ok(Model {
            train: Executable::Native(native::NativeExec::new(
                nm.clone(),
                native::Mode::Train,
                threads,
            )),
            pred: Executable::Native(native::NativeExec::new(nm, native::Mode::Pred, threads)),
            manifest,
        })
    }

    /// Bind the (normalized) sparse adjacency for a native full-batch GNN
    /// model; train and pred share the binding. Errors on the HLO backend,
    /// whose executables take the adjacency as a dense input tensor.
    pub fn bind_adjacency(&self, adj: Arc<Csr>) -> Result<()> {
        match &self.train {
            Executable::Native(e) => e.model().bind_adjacency(adj),
            Executable::Hlo(_) => Err(Error::Runtime(
                "the HLO backend takes a dense adj input tensor, not a CSR binding — \
                 build the batch with tasks::nodeclf::adj_input"
                    .into(),
            )),
        }
    }

    /// Bind the poshash front-end's degree-rank bucket map for a native
    /// model (see `tasks::nodeclf::pos_map_for`); train and pred share the
    /// binding. Errors on the HLO backend, which has no hash front-ends.
    pub fn bind_pos_map(&self, map: Arc<Vec<u32>>) -> Result<()> {
        match &self.train {
            Executable::Native(e) => e.model().bind_pos_map(map),
            Executable::Hlo(_) => Err(Error::Runtime(
                "hash-embedding front-ends are native-backend models — the HLO backend \
                 takes no position map"
                    .into(),
            )),
        }
    }

    /// Does this model's front-end need [`Model::bind_pos_map`] before it
    /// can run? (Only the native poshash front-end does.)
    pub fn needs_pos_map(&self) -> bool {
        match &self.train {
            Executable::Native(e) => e.model().needs_pos_map(),
            Executable::Hlo(_) => false,
        }
    }

    /// Backend of the train executable (`"hlo"` / `"native"`).
    pub fn backend_name(&self) -> &'static str {
        self.train.backend_name()
    }

    /// Toggle the native backend's step-scratch buffer reuse (on by
    /// default). Reuse is structurally bit-identical to fresh allocation;
    /// turning it off exists for the train-step bench and parity tests.
    /// Errors on the HLO backend, which manages its own buffers.
    pub fn set_scratch_reuse(&self, on: bool) -> Result<()> {
        match &self.train {
            Executable::Native(e) => {
                e.model().set_scratch_reuse(on);
                Ok(())
            }
            Executable::Hlo(_) => Err(Error::Runtime(
                "scratch reuse is a native-backend knob — the HLO backend manages its own \
                 buffers"
                    .into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run). Unit tests here cover backend
    // resolution and the error paths.
    use super::*;

    #[test]
    fn unknown_name_without_artifacts_is_a_clean_error() {
        let engine = Engine::cpu("/nonexistent-artifacts-dir").unwrap();
        let err = match engine.load("nope") {
            Err(e) => e,
            Ok(_) => panic!("loading an unknown model must fail"),
        };
        let msg = format!("{err}");
        assert!(msg.contains("nope") && msg.contains("native registry"), "{msg}");
    }

    #[test]
    fn auto_backend_synthesizes_builtin_models_offline() {
        let engine = Engine::cpu("/nonexistent-artifacts-dir").unwrap();
        assert_eq!(engine.backend(), crate::cfg::BackendKind::Auto);
        let model = engine.load("sage_mb_coded").unwrap();
        assert_eq!(model.backend_name(), "native");
        assert_eq!(model.manifest.name, "sage_mb_coded");
        assert_eq!(model.manifest.hyper_usize("n").unwrap(), 10_000);
    }

    #[test]
    fn xla_backend_still_reports_missing_artifacts() {
        let engine =
            Engine::with_backend("/nonexistent-artifacts-dir", BackendKind::Xla, 0).unwrap();
        let err = engine.load("sage_mb_coded").map(|_| ()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("artifacts") || msg.contains(".json"), "{msg}");
    }

    #[test]
    fn native_backend_synthesizes_every_registry_name() {
        let engine = Engine::with_backend("/nowhere", BackendKind::Native, 2).unwrap();
        // The full-batch Table-1 grid is part of the registry since PR 3.
        let fb = engine.load("node_fb_gcn_coded").unwrap();
        assert_eq!(fb.backend_name(), "native");
        // Native full-batch manifests carry no dense adj input.
        assert!(fb.manifest.train_inputs.iter().all(|t| t.name != "adj"));
        // Every registry name loads.
        for name in native::spec::builtin_names() {
            let model = engine.load(name).unwrap();
            assert_eq!(model.backend_name(), "native", "{name}");
        }
        // Unknown names still fail cleanly.
        assert!(engine.load("node_fb_gat_coded").is_err());
    }
}
