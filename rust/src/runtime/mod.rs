//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them
//! on the CPU PJRT client. This is the only module that touches the `xla`
//! crate; everything above it works with [`Tensor`]s and artifact names.
//!
//! Without the default-off `xla` feature, `xla` here is the in-crate stub
//! ([`crate::xla`]): clients and host literals work, while HLO compilation
//! and execution return clean [`Error::Runtime`]-shaped errors.
//!
//! Lifecycle: [`Engine::cpu`] once per process → [`Engine::load`] per
//! artifact (compiles HLO → executable) → [`Executable::run`] per step.

mod manifest;
mod tensor;

pub use manifest::{InitKind, Manifest, ParamSpec, TensorSpec};
pub use tensor::Tensor;

use std::path::{Path, PathBuf};

#[cfg(not(feature = "xla"))]
use crate::xla;
use crate::{Error, Result};

/// PJRT client wrapper. One per process.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

impl Engine {
    /// CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts_dir: artifacts_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load `<name>.json` (manifest) and compile `<name>_train.hlo.txt` /
    /// `<name>_pred.hlo.txt` into executables.
    pub fn load(&self, name: &str) -> Result<Model> {
        let manifest = Manifest::load(&self.artifacts_dir.join(format!("{name}.json")))?;
        let train = self.compile_file(&self.artifacts_dir.join(format!("{name}_train.hlo.txt")))?;
        let pred = self.compile_file(&self.artifacts_dir.join(format!("{name}_pred.hlo.txt")))?;
        Ok(Model { manifest, train, pred })
    }

    /// Compile a single HLO text file into an executable.
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { exe })
    }
}

/// A compiled computation. The exported HLO always returns a tuple
/// (`return_tuple=True` at lowering), so `run` flattens it back into
/// tensors.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// A train/pred executable pair plus its manifest.
pub struct Model {
    pub manifest: Manifest,
    pub train: Executable,
    pub pred: Executable,
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/ (they
    // require `make artifacts` to have run). Unit tests here cover the
    // error path only.
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let engine = Engine::cpu("/nonexistent-artifacts-dir").unwrap();
        let err = match engine.load("nope") {
            Err(e) => e,
            Ok(_) => panic!("loading a missing artifact must fail"),
        };
        let msg = format!("{err}");
        assert!(msg.contains("nope") || msg.contains("artifacts"), "{msg}");
    }
}
