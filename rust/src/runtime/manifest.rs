//! Artifact manifest model — the JSON contract written by
//! `python/compile/aot.py`. Parameter order in the manifest *is* the
//! executable's argument order; `rust/src/params` initializes buffers from
//! these specs with the same rules the Python side documents.

use std::path::Path;

use crate::ser::Json;
use crate::{Error, Result};

/// Parameter initialization rule (mirrors `python/compile/specs.py`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitKind {
    XavierUniform,
    Normal { std: f32 },
    Zeros,
    Ones,
}

/// One parameter tensor spec.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    pub trainable: bool,
}

impl ParamSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One input/output tensor spec.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `<name>.json` manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub params: Vec<ParamSpec>,
    pub train_inputs: Vec<TensorSpec>,
    pub pred_inputs: Vec<TensorSpec>,
    pub pred_output: TensorSpec,
    /// Raw hyper-parameter object (task-specific fields).
    pub hyper: Json,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let v = crate::ser::from_file(path)
            .map_err(|e| Error::Json(format!("{}: {e}", path.display())))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let params = v
            .get("params")?
            .as_arr()?
            .iter()
            .map(parse_param)
            .collect::<Result<Vec<_>>>()?;
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)?.as_arr()?.iter().map(parse_tensor).collect()
        };
        Ok(Manifest {
            name: v.get("name")?.as_str()?.to_string(),
            params,
            train_inputs: tensors("train_inputs")?,
            pred_inputs: tensors("pred_inputs")?,
            pred_output: parse_tensor(v.get("pred_output")?)?,
            hyper: v.get("hyper")?.clone(),
        })
    }

    /// Total parameter element count.
    pub fn n_param_elements(&self) -> usize {
        self.params.iter().map(ParamSpec::n_elements).sum()
    }

    /// Count of trainable parameter elements.
    pub fn n_trainable_elements(&self) -> usize {
        self.params.iter().filter(|p| p.trainable).map(ParamSpec::n_elements).sum()
    }

    /// Serialize back to the exact JSON contract [`Self::from_json`]
    /// parses — used by the serving bundle, which freezes the manifest
    /// alongside the trained parameters.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("params", Json::Arr(self.params.iter().map(param_to_json).collect())),
            (
                "train_inputs",
                Json::Arr(self.train_inputs.iter().map(tensor_to_json).collect()),
            ),
            (
                "pred_inputs",
                Json::Arr(self.pred_inputs.iter().map(tensor_to_json).collect()),
            ),
            ("pred_output", tensor_to_json(&self.pred_output)),
            ("hyper", self.hyper.clone()),
        ])
    }

    /// Hyper field helpers.
    pub fn hyper_usize(&self, key: &str) -> Result<usize> {
        self.hyper.get(key)?.as_usize()
    }

    pub fn hyper_str(&self, key: &str) -> Result<&str> {
        self.hyper.get(key)?.as_str()
    }

    pub fn hyper_bool(&self, key: &str) -> Result<bool> {
        self.hyper.get(key)?.as_bool()
    }
}

fn parse_param(v: &Json) -> Result<ParamSpec> {
    let init = match v.get("init")?.as_str()? {
        "xavier_uniform" => InitKind::XavierUniform,
        "normal" => InitKind::Normal { std: v.get("std")?.as_f64()? as f32 },
        "zeros" => InitKind::Zeros,
        "ones" => InitKind::Ones,
        other => return Err(Error::Json(format!("unknown init kind '{other}'"))),
    };
    Ok(ParamSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v.get("shape")?.as_usize_vec()?,
        init,
        trainable: v.get("trainable")?.as_bool()?,
    })
}

fn param_to_json(p: &ParamSpec) -> Json {
    let (init, std) = match p.init {
        InitKind::XavierUniform => ("xavier_uniform", 0.0f32),
        InitKind::Normal { std } => ("normal", std),
        InitKind::Zeros => ("zeros", 0.0),
        InitKind::Ones => ("ones", 0.0),
    };
    Json::obj(vec![
        ("name", Json::str(p.name.clone())),
        ("shape", Json::arr_usize(&p.shape)),
        ("init", Json::str(init)),
        ("std", Json::num(std as f64)),
        ("trainable", Json::Bool(p.trainable)),
    ])
}

fn tensor_to_json(t: &TensorSpec) -> Json {
    Json::obj(vec![
        ("name", Json::str(t.name.clone())),
        ("shape", Json::arr_usize(&t.shape)),
        ("dtype", Json::str(t.dtype.clone())),
    ])
}

fn parse_tensor(v: &Json) -> Result<TensorSpec> {
    let dtype = v.get("dtype")?.as_str()?.to_string();
    if dtype != "f32" && dtype != "i32" {
        return Err(Error::Json(format!("unsupported dtype '{dtype}'")));
    }
    Ok(TensorSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v.get("shape")?.as_usize_vec()?,
        dtype,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ser::parse;

    fn sample() -> Json {
        parse(
            r#"{
          "name": "t",
          "params": [
            {"name": "dec.books", "shape": [4, 16, 8], "init": "normal", "std": 0.5, "trainable": false},
            {"name": "dec.mlp0.w", "shape": [8, 8], "init": "xavier_uniform", "std": 0.0, "trainable": true},
            {"name": "dec.mlp0.b", "shape": [8], "init": "zeros", "std": 0.0, "trainable": true}
          ],
          "train_inputs": [{"name": "codes", "shape": [32, 4], "dtype": "i32"}],
          "pred_inputs": [{"name": "codes", "shape": [32, 4], "dtype": "i32"}],
          "pred_output": {"name": "emb", "shape": [32, 8], "dtype": "f32"},
          "hyper": {"task": "recon", "c": 16, "m": 4}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.params[0].init, InitKind::Normal { std: 0.5 });
        assert!(!m.params[0].trainable);
        assert_eq!(m.params[1].init, InitKind::XavierUniform);
        assert_eq!(m.train_inputs[0].dtype, "i32");
        assert_eq!(m.pred_output.shape, vec![32, 8]);
        assert_eq!(m.hyper_usize("c").unwrap(), 16);
        assert_eq!(m.hyper_str("task").unwrap(), "recon");
    }

    #[test]
    fn element_counts() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.n_param_elements(), 4 * 16 * 8 + 64 + 8);
        assert_eq!(m.n_trainable_elements(), 64 + 8);
    }

    #[test]
    fn to_json_round_trips() {
        let m = Manifest::from_json(&sample()).unwrap();
        let j1 = m.to_json();
        let back = Manifest::from_json(&j1).unwrap();
        assert_eq!(j1, back.to_json());
        assert_eq!(back.params[0].init, InitKind::Normal { std: 0.5 });
        assert_eq!(back.pred_output.shape, vec![32, 8]);
        assert_eq!(back.hyper_usize("c").unwrap(), 16);
    }

    #[test]
    fn rejects_bad_dtype() {
        let mut j = sample();
        if let Json::Obj(o) = &mut j {
            o.insert(
                "pred_output".into(),
                parse(r#"{"name": "x", "shape": [1], "dtype": "f64"}"#).unwrap(),
            );
        }
        assert!(Manifest::from_json(&j).is_err());
    }
}
