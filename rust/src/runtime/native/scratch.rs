//! Step-scratch arena: the training analog of serving's `SessionScratch`.
//!
//! A train step allocates dozens of activation / gradient / gather
//! buffers (`vec![0.0f32; …]` per layer per step) whose shapes are
//! identical every step. [`StepScratch`] is a small free-list of `Vec<f32>`
//! buffers owned by `NativeModel`: the forward/backward passes [`take`]
//! buffers from it and [`give`] them back when a temporary dies or a
//! step's caches are retired, so steady-state training performs no
//! per-step heap allocation on those paths.
//!
//! **Bit parity is structural, not asserted-away:** [`take`] returns a
//! buffer that is `clear()`ed and `resize(len, 0.0)`ed — element-for-
//! element identical to a fresh `vec![0.0f32; len]` — so reuse cannot
//! change a single trained bit. The test suite still asserts reuse-on ==
//! reuse-off loss curves end-to-end (`tests/train_pipeline.rs`).
//!
//! [`take`]: StepScratch::take
//! [`give`]: StepScratch::give

/// Upper bound on pooled buffers: enough for every live temporary of the
/// deepest backward pass (full-batch GIN), small enough that an
/// anomalous step cannot pin unbounded memory.
const MAX_POOLED: usize = 64;

/// Free-list of reusable `f32` buffers (see module docs).
pub struct StepScratch {
    reuse: bool,
    pool: Vec<Vec<f32>>,
}

impl Default for StepScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl StepScratch {
    pub fn new() -> Self {
        Self { reuse: true, pool: Vec::new() }
    }

    /// A scratch that never pools — every [`Self::take`] is a fresh
    /// allocation (the before-side of the bench comparison).
    pub fn disabled() -> Self {
        Self { reuse: false, pool: Vec::new() }
    }

    /// Turn pooling on/off. Turning it off drops all pooled buffers.
    pub fn set_reuse(&mut self, on: bool) {
        self.reuse = on;
        if !on {
            self.pool.clear();
        }
    }

    pub fn reuse(&self) -> bool {
        self.reuse
    }

    /// A zeroed buffer of `len` elements — bit-identical to
    /// `vec![0.0f32; len]`, pooled when reuse is on.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if self.reuse {
            if let Some(mut v) = self.pool.pop() {
                v.clear();
                v.resize(len, 0.0);
                return v;
            }
        }
        vec![0.0f32; len]
    }

    /// A buffer holding a copy of `src` — the pooled replacement for
    /// `src.to_vec()` / `src.clone()`.
    pub fn take_copy(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.take(src.len());
        v.copy_from_slice(src);
        v
    }

    /// Return a dead buffer to the pool (dropped when reuse is off or the
    /// pool is full).
    pub fn give(&mut self, v: Vec<f32>) {
        if self.reuse && v.capacity() > 0 && self.pool.len() < MAX_POOLED {
            self.pool.push(v);
        }
    }

    /// [`Self::give`] a whole batch of buffers (retiring a step's caches).
    pub fn give_all<I: IntoIterator<Item = Vec<f32>>>(&mut self, vs: I) {
        for v in vs {
            self.give(v);
        }
    }

    /// Buffers currently pooled (observability / tests).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_bitwise_a_fresh_zero_vec() {
        let mut s = StepScratch::new();
        let mut v = s.take(4);
        v.copy_from_slice(&[1.0, -2.0, 3.0, f32::NAN]);
        s.give(v);
        let v2 = s.take(6); // longer than the recycled buffer
        assert_eq!(v2, vec![0.0f32; 6]);
        s.give(v2);
        let v3 = s.take(2); // shorter
        assert_eq!(v3, vec![0.0f32; 2]);
    }

    #[test]
    fn disabled_scratch_never_pools() {
        let mut s = StepScratch::disabled();
        let v = s.take(8);
        s.give(v);
        assert_eq!(s.pooled(), 0);
        let mut on = StepScratch::new();
        on.give(vec![0.0; 8]);
        assert_eq!(on.pooled(), 1);
        on.set_reuse(false);
        assert_eq!(on.pooled(), 0);
    }

    #[test]
    fn pool_is_bounded() {
        let mut s = StepScratch::new();
        for _ in 0..(MAX_POOLED + 10) {
            s.give(vec![0.0; 4]);
        }
        assert_eq!(s.pooled(), MAX_POOLED);
    }

    #[test]
    fn take_copy_matches_to_vec() {
        let mut s = StepScratch::new();
        s.give(vec![9.0; 16]);
        let src = [1.0f32, 2.0, 3.0];
        assert_eq!(s.take_copy(&src), src.to_vec());
    }
}
