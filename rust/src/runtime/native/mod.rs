//! Pure-Rust training backend: forward, hand-derived reverse-mode
//! backward, and fused AdamW for the paper's models, implemented directly
//! from the [`Manifest`]/[`crate::runtime::ParamSpec`] contract — no HLO
//! artifacts, no PJRT, no Python anywhere.
//!
//! Supported tasks (`hyper.task`):
//!
//! | task | model | adjacency |
//! |---|---|---|
//! | `recon` | §3.2 decoder, MSE vs pre-trained embeddings | — |
//! | `sage_minibatch` | decoder/NC-table → 2-layer mean-agg GraphSAGE → softmax-CE head (§4) | fan-out tensors |
//! | `sage_minibatch_link` | same encoder → dot-product/BPR link head | fan-out tensors |
//! | `nodeclf_fullbatch` | GCN / SGC / GIN / full-batch SAGE → masked-CE head (Table 1) | bound sparse CSR |
//! | `linkpred_fullbatch` | same encoders → dot-product/BCE edge scorer | bound sparse CSR |
//!
//! The full-batch tasks ([`gnn`]) never see a dense `n×n` adjacency: the
//! driver normalizes the graph once and hands the CSR to
//! [`NativeModel::bind_adjacency`] (via
//! [`crate::runtime::Model::bind_adjacency`]); any `adj` tensor spec an
//! exported HLO manifest declares is stripped at load, so the same
//! manifest runs on either backend.
//!
//! The train step consumes and produces exactly the tuple
//! [`crate::params::ParamStore`] threads through every call —
//! `(params…, m…, v…, step, batch…) → (params'…, m'…, v'…, loss)` — so
//! [`crate::train`] and every task driver run unchanged on either backend.
//!
//! **Determinism:** every kernel partitions only output elements across
//! threads and keeps each reduction a fixed-order sequential sum (see
//! [`ops`]); gradient contributions to shared parameters accumulate in
//! fixed program order. Training is therefore bit-identical for every
//! thread count, which the test suite asserts. Kernels dispatch to one
//! process-wide worker pool (the private `par` module) instead of
//! spawning OS threads per call; the pool never changes the output
//! partition, so pool size and scheduling cannot change results either.
//!
//! **Forward/backward split:** forward-only execution lives in the
//! inference-only paths (`decoder::forward_infer`, `sage::encode_infer`,
//! `gnn::encode_infer`) — no activation stashing, no grad buffers — and
//! is surfaced as [`infer::InferModel`], the model the serving subsystem
//! ([`crate::serve`]) loads from a frozen bundle. The train-fused paths
//! keep their caches; both run the same kernels in the same order, so
//! inference output is bit-identical to the training forward.

pub mod adam;
pub mod decoder;
pub mod gnn;
pub mod hashemb;
pub mod infer;
pub mod layers;
pub mod ops;
pub(crate) mod par;
pub mod sage;
pub mod scratch;
pub mod spec;

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use crate::runtime::{Manifest, Tensor, TensorSpec};
use crate::sparse::Csr;
use crate::{Error, Result};

pub use adam::AdamHyper;
use gnn::{FbAdj, FbDims, FbGnn};
use layers::{FeatSource, LinearIdx};
use par::resolve_threads;
use sage::{SageDims, SageIdx};
use scratch::StepScratch;

/// Which model family a manifest describes.
enum Task {
    /// §5.1 reconstruction decoder: `feat` must be the decoder.
    Recon { batch: usize, d_e: usize },
    /// §4 minibatch GraphSAGE + softmax-CE node head.
    SageClf { sage: SageIdx, head: LinearIdx, n_classes: usize, dims: SageDims },
    /// §4 minibatch GraphSAGE + dot-product/BPR link head.
    SageLink { sage: SageIdx, dims: SageDims },
    /// §5.2 full-batch GNN + masked-CE node head (Table 1 node rows).
    FbClf { gnn: FbGnn, head: LinearIdx, n_classes: usize, dims: FbDims, coded: bool },
    /// §5.2 full-batch GNN + dot-product/BCE link head (Table 1 link rows).
    FbLink { gnn: FbGnn, dims: FbDims, coded: bool },
}

impl Task {
    fn is_fullbatch(&self) -> bool {
        matches!(self, Task::FbClf { .. } | Task::FbLink { .. })
    }
}

/// The manifest's feature front-end name: the explicit `front_end` hyper
/// key when present (`coded` / `nc` / `multihash` / `bloom` / `poshash`),
/// else derived from the legacy `coded` bool — so pre-existing manifests
/// keep resolving unchanged.
pub fn front_end_name(manifest: &Manifest) -> Result<&str> {
    let coded = manifest.hyper_bool("coded")?;
    let Ok(name) = manifest.hyper_str("front_end") else {
        return Ok(if coded { "coded" } else { "nc" });
    };
    if coded != (name == "coded") {
        return Err(Error::Config(format!(
            "manifest '{}' declares front_end '{name}' but coded = {coded}",
            manifest.name
        )));
    }
    Ok(name)
}

/// Resolve the feature front-end named by [`front_end_name`].
fn resolve_front_end(manifest: &Manifest) -> Result<FeatSource> {
    match front_end_name(manifest)? {
        "coded" => FeatSource::resolve_decoder(manifest),
        "nc" => FeatSource::resolve_table(manifest),
        kind @ ("multihash" | "bloom" | "poshash") => {
            FeatSource::resolve_hashemb(manifest, kind)
        }
        other => Err(Error::Config(format!(
            "unknown front_end '{other}' (expected coded / nc / multihash / bloom / \
             poshash)"
        ))),
    }
}

/// Resolve a manifest's task string into typed parameter indices + dims —
/// the shared front half of both the train/bwd model ([`NativeModel`])
/// and the inference-only model ([`infer::InferModel`]).
fn resolve_task(manifest: &Manifest) -> Result<(Task, FeatSource)> {
    let task_str = manifest.hyper_str("task")?;
    match task_str {
        "recon" => {
            let feat = FeatSource::resolve_decoder(manifest)?;
            let batch = manifest.hyper_usize("batch")?;
            let d_e = feat.d_out();
            Ok((Task::Recon { batch, d_e }, feat))
        }
        "sage_minibatch" | "sage_minibatch_link" => {
            let feat = resolve_front_end(manifest)?;
            let dims = SageDims {
                batch: manifest.hyper_usize("batch")?,
                k1: manifest.hyper_usize("k1")?,
                k2: manifest.hyper_usize("k2")?,
                d_e: manifest.hyper_usize("d_e")?,
                hidden: manifest.hyper_usize("hidden")?,
            };
            dims.validate()?;
            let sage = SageIdx::resolve(manifest, dims.d_e, dims.hidden)?;
            let task = if task_str == "sage_minibatch" {
                let n_classes = manifest.hyper_usize("n_classes")?;
                let head =
                    LinearIdx::resolve(manifest, "head.w", "head.b", dims.hidden, n_classes)?;
                Task::SageClf { sage, head, n_classes, dims }
            } else {
                Task::SageLink { sage, dims }
            };
            Ok((task, feat))
        }
        "nodeclf_fullbatch" | "linkpred_fullbatch" => {
            let coded = manifest.hyper_bool("coded")?;
            let feat = resolve_front_end(manifest)?;
            let dims = FbDims {
                n: manifest.hyper_usize("n")?,
                d_e: manifest.hyper_usize("d_e")?,
                hidden: manifest.hyper_usize("hidden")?,
            };
            let gnn = FbGnn::resolve(manifest, manifest.hyper_str("gnn")?, dims.d_e, dims.hidden)?;
            let task = if task_str == "nodeclf_fullbatch" {
                let n_classes = manifest.hyper_usize("n_classes")?;
                let head =
                    LinearIdx::resolve(manifest, "head.w", "head.b", dims.hidden, n_classes)?;
                Task::FbClf { gnn, head, n_classes, dims, coded }
            } else {
                Task::FbLink { gnn, dims, coded }
            };
            Ok((task, feat))
        }
        other => Err(Error::Runtime(format!(
            "native backend does not implement task '{other}'"
        ))),
    }
}

/// Normalized manifest copy for native execution: exported HLO manifests
/// declare a dense `(n, n)` adj input for the full-batch tasks; the
/// native paths bind a CSR instead and must never allocate `n²`.
fn normalize_manifest(manifest: &Manifest, task: &Task) -> Manifest {
    let mut manifest = manifest.clone();
    if task.is_fullbatch() {
        manifest.train_inputs.retain(|t| t.name != "adj");
        manifest.pred_inputs.retain(|t| t.name != "adj");
    }
    manifest
}

/// Borrow every parameter tensor as a checked `&[f32]` slice in manifest
/// order (shared by the train and inference models).
/// Validate already-sliced parameter data against the manifest — the
/// entry check for the `*_with` inference paths, where a serving bundle
/// hands out borrowed `&[f32]` views of its file image instead of owned
/// [`Tensor`]s. Mirrors [`param_slices`] exactly (count, then per-param
/// element count), so passing `param_slices(...)?` output always
/// succeeds.
pub fn check_param_slices(manifest: &Manifest, slices: &[&[f32]]) -> Result<()> {
    if slices.len() < manifest.params.len() {
        return Err(Error::Shape(format!(
            "got {} param slices, manifest has {}",
            slices.len(),
            manifest.params.len()
        )));
    }
    for (spec, data) in manifest.params.iter().zip(slices) {
        if data.len() != spec.n_elements() {
            return Err(Error::Shape(format!(
                "param '{}' has {} elements, spec wants {}",
                spec.name,
                data.len(),
                spec.n_elements()
            )));
        }
    }
    Ok(())
}

fn param_slices<'a>(manifest: &Manifest, params: &'a [Tensor]) -> Result<Vec<&'a [f32]>> {
    if params.len() < manifest.params.len() {
        return Err(Error::Shape(format!(
            "got {} param tensors, manifest has {}",
            params.len(),
            manifest.params.len()
        )));
    }
    manifest
        .params
        .iter()
        .zip(params)
        .map(|(spec, t)| {
            let data = t.as_f32()?;
            if data.len() != spec.n_elements() {
                return Err(Error::Shape(format!(
                    "param '{}' has {} elements, spec wants {}",
                    spec.name,
                    data.len(),
                    spec.n_elements()
                )));
            }
            Ok(data)
        })
        .collect()
}

/// A manifest compiled for the native backend: resolved parameter
/// indices, dims and optimizer settings.
pub struct NativeModel {
    manifest: Manifest,
    task: Task,
    feat: FeatSource,
    optim: AdamHyper,
    trainable: Vec<bool>,
    /// Sparse adjacency for the full-batch tasks, bound once per model.
    adj: OnceLock<FbAdj>,
    /// Step-scratch arena: activation/gradient/gather buffers recycled
    /// across train steps (see [`scratch`]). Buffer reuse is structurally
    /// bit-identical to fresh allocation, so it cannot change results.
    scratch: Mutex<StepScratch>,
}

impl NativeModel {
    /// Build from a manifest (exported by `python/compile/aot.py` or
    /// synthesized by [`spec`]). Validates every referenced parameter's
    /// name and shape against the contract. For the full-batch tasks any
    /// dense `adj` input spec is stripped (the native path takes the
    /// adjacency as a bound CSR instead).
    pub fn from_manifest(manifest: &Manifest) -> Result<Self> {
        let (task, feat) = resolve_task(manifest)?;
        let optim = AdamHyper::from_json(manifest.hyper.get("optim")?)?;
        let trainable = manifest.params.iter().map(|p| p.trainable).collect();
        let manifest = normalize_manifest(manifest, &task);
        Ok(Self {
            manifest,
            task,
            feat,
            optim,
            trainable,
            adj: OnceLock::new(),
            scratch: Mutex::new(StepScratch::new()),
        })
    }

    /// Lock the step-scratch arena. A poisoned lock is recovered — the
    /// pool only ever holds dead zero-fill buffers, so a panicking step
    /// cannot leave it in a state that affects later steps.
    fn scratch(&self) -> MutexGuard<'_, StepScratch> {
        self.scratch.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Toggle step-scratch buffer reuse (on by default). With reuse off
    /// every temporary is a fresh allocation — the before-side of the
    /// train-step bench and the parity tests.
    pub fn set_scratch_reuse(&self, on: bool) {
        self.scratch().set_reuse(on);
    }

    pub fn n_params(&self) -> usize {
        self.manifest.params.len()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Bind the (already normalized) sparse adjacency for a full-batch
    /// model. Must be called exactly once before train/predict; the
    /// structural transpose for the backward pass is precomputed here.
    pub fn bind_adjacency(&self, adj: Arc<Csr>) -> Result<()> {
        let n = match &self.task {
            Task::FbClf { dims, .. } | Task::FbLink { dims, .. } => dims.n,
            _ => {
                return Err(Error::Runtime(format!(
                    "model '{}' is not a full-batch task — only nodeclf_fullbatch / \
                     linkpred_fullbatch take a CSR adjacency",
                    self.manifest.name
                )))
            }
        };
        if adj.n_rows() != n || adj.n_cols() != n {
            return Err(Error::Shape(format!(
                "adjacency is {}×{}, model '{}' wants {n}×{n}",
                adj.n_rows(),
                adj.n_cols(),
                self.manifest.name
            )));
        }
        // Rebinding the *same* matrix is a no-op, so drivers like
        // `run_fullbatch_model` can reuse one loaded model across runs on
        // one graph; a different matrix is rejected (load a fresh model).
        if let Some(existing) = self.adj.get() {
            if Arc::ptr_eq(&existing.a, &adj) || *existing.a == *adj {
                return Ok(());
            }
            return Err(Error::Runtime(format!(
                "model '{}' already has a different bound adjacency",
                self.manifest.name
            )));
        }
        self.adj.set(FbAdj::new(adj)).map_err(|_| {
            Error::Runtime(format!(
                "model '{}': concurrent adjacency binds raced — bind once before training",
                self.manifest.name
            ))
        })
    }

    /// Bind the poshash front-end's degree-rank bucket map. Same contract
    /// as [`Self::bind_adjacency`]: bind once before train/predict,
    /// rebinding an equal map is a no-op, any other front-end refuses.
    pub fn bind_pos_map(&self, map: Arc<Vec<u32>>) -> Result<()> {
        self.feat.bind_pos_map(map)
    }

    /// Does this model's front-end need [`Self::bind_pos_map`] before it
    /// can run?
    pub fn needs_pos_map(&self) -> bool {
        self.feat.needs_pos_map()
    }

    fn fb_adj(&self) -> Result<&FbAdj> {
        self.adj.get().ok_or_else(|| {
            Error::Runtime(format!(
                "full-batch model '{}' has no adjacency bound — call \
                 Model::bind_adjacency with the normalized graph CSR before train/predict \
                 (the native path never materializes a dense n×n adjacency)",
                self.manifest.name
            ))
        })
    }

    /// Loss and per-parameter gradients at `params` for one batch — the
    /// differentiation core, exposed for finite-difference verification.
    /// Gradients of non-trainable parameters are zero.
    pub fn loss_and_grads(
        &self,
        params: &[Tensor],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        validate_specs(batch, &self.manifest.train_inputs)?;
        let slices = self.param_slices(params)?;
        self.grads_inner(&slices, batch, resolve_threads(threads))
    }

    /// Forward-only prediction over one batch (already validated against
    /// `pred_inputs`).
    pub fn predict(&self, params: &[Tensor], batch: &[Tensor], threads: usize) -> Result<Tensor> {
        validate_specs(batch, &self.manifest.pred_inputs)?;
        let slices = self.param_slices(params)?;
        let threads = resolve_threads(threads);
        let out = &self.manifest.pred_output;
        let data = match &self.task {
            Task::Recon { .. } => {
                let mut guard = self.scratch();
                let scratch = &mut *guard;
                let cache = self.feat.fwd(&slices, &batch[0], threads, scratch)?;
                let data = self.feat.output(&cache).to_vec();
                cache.recycle(scratch);
                data
            }
            Task::SageClf { sage, head, n_classes, dims } => {
                sage::clf_pred(&self.feat, sage, head, *n_classes, dims, &slices, batch, threads)?
            }
            Task::SageLink { sage, dims } => {
                sage::link_pred(&self.feat, sage, dims, &slices, batch, threads)?
            }
            Task::FbClf { gnn, head, n_classes, dims, coded } => gnn::clf_pred(
                &self.feat,
                gnn,
                head,
                *n_classes,
                dims,
                *coded,
                &slices,
                &self.fb_adj()?.a,
                batch,
                threads,
            )?,
            Task::FbLink { gnn, dims, coded } => gnn::link_pred(
                &self.feat,
                gnn,
                dims,
                *coded,
                &slices,
                &self.fb_adj()?.a,
                batch,
                threads,
            )?,
        };
        Tensor::f32(out.shape.clone(), data)
    }

    /// One fused train step: gradients then masked AdamW. Consumes the
    /// `(params…, m…, v…, step, batch…)` input vector and returns
    /// `(params'…, m'…, v'…, loss)`.
    pub fn train_step(&self, inputs: &[Tensor], threads: usize) -> Result<Vec<Tensor>> {
        let p = self.n_params();
        let n_batch = self.manifest.train_inputs.len();
        if inputs.len() != 3 * p + 1 + n_batch {
            return Err(Error::Shape(format!(
                "native train step got {} inputs, expected {} (3·{p} params + step + {n_batch} batch)",
                inputs.len(),
                3 * p + 1 + n_batch
            )));
        }
        let step = inputs[3 * p].scalar()?;
        let batch = &inputs[3 * p + 1..];
        validate_specs(batch, &self.manifest.train_inputs)?;
        let params = &inputs[..p];
        let slices = self.param_slices(params)?;
        let threads = resolve_threads(threads);
        let (loss, grads) = self.grads_inner(&slices, batch, threads)?;

        let t = step + 1.0;
        let mut out_p = Vec::with_capacity(p);
        let mut out_m = Vec::with_capacity(p);
        let mut out_v = Vec::with_capacity(p);
        for i in 0..p {
            if self.trainable[i] {
                let shape = self.manifest.params[i].shape.clone();
                let mut pn = inputs[i].as_f32()?.to_vec();
                let mut mn = inputs[p + i].as_f32()?.to_vec();
                let mut vn = inputs[2 * p + i].as_f32()?.to_vec();
                adam::adamw_update(&mut pn, &grads[i], &mut mn, &mut vn, t, self.optim, threads);
                out_p.push(Tensor::F32 { shape: shape.clone(), data: pn });
                out_m.push(Tensor::F32 { shape: shape.clone(), data: mn });
                out_v.push(Tensor::F32 { shape, data: vn });
            } else {
                out_p.push(inputs[i].clone());
                out_m.push(inputs[p + i].clone());
                out_v.push(inputs[2 * p + i].clone());
            }
        }
        // Gradient buffers came from the scratch arena (`grads_inner`);
        // retire them now that the update has consumed them.
        self.scratch().give_all(grads);
        let mut out = out_p;
        out.append(&mut out_m);
        out.append(&mut out_v);
        out.push(Tensor::scalar_f32(loss));
        Ok(out)
    }

    fn param_slices<'a>(&self, params: &'a [Tensor]) -> Result<Vec<&'a [f32]>> {
        param_slices(&self.manifest, params)
    }

    fn grads_inner(
        &self,
        params: &[&[f32]],
        batch: &[Tensor],
        threads: usize,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let mut guard = self.scratch();
        let scratch = &mut *guard;
        let mut grads: Vec<Vec<f32>> =
            self.manifest.params.iter().map(|s| scratch.take(s.n_elements())).collect();
        let loss = match &self.task {
            Task::Recon { batch: b, d_e } => {
                let cache = self.feat.fwd(params, &batch[0], threads, scratch)?;
                let out = self.feat.output(&cache);
                let target = batch[1].as_f32()?;
                let mut dout = scratch.take(b * d_e);
                let loss = ops::mse(out, target, &mut dout, threads);
                self.feat.bwd(
                    params,
                    &batch[0],
                    &cache,
                    &dout,
                    &self.trainable,
                    &mut grads,
                    threads,
                    scratch,
                )?;
                scratch.give(dout);
                cache.recycle(scratch);
                loss
            }
            Task::SageClf { sage, head, n_classes, dims } => sage::clf_grads(
                &self.feat,
                sage,
                head,
                *n_classes,
                dims,
                params,
                batch,
                &self.trainable,
                &mut grads,
                threads,
                scratch,
            )?,
            Task::SageLink { sage, dims } => sage::link_grads(
                &self.feat,
                sage,
                dims,
                params,
                batch,
                &self.trainable,
                &mut grads,
                threads,
                scratch,
            )?,
            Task::FbClf { gnn, head, n_classes, dims, coded } => gnn::clf_grads(
                &self.feat,
                gnn,
                head,
                *n_classes,
                dims,
                *coded,
                params,
                self.fb_adj()?,
                batch,
                &self.trainable,
                &mut grads,
                threads,
                scratch,
            )?,
            Task::FbLink { gnn, dims, coded } => gnn::link_grads(
                &self.feat,
                gnn,
                dims,
                *coded,
                params,
                self.fb_adj()?,
                batch,
                &self.trainable,
                &mut grads,
                threads,
                scratch,
            )?,
        };
        if !loss.is_finite() {
            return Err(Error::Runtime(format!("native train step produced loss {loss}")));
        }
        Ok((loss, grads))
    }
}

/// Shape/dtype validation of a batch against manifest tensor specs.
fn validate_specs(batch: &[Tensor], specs: &[TensorSpec]) -> Result<()> {
    if batch.len() != specs.len() {
        return Err(Error::Shape(format!(
            "batch has {} tensors, manifest expects {}",
            batch.len(),
            specs.len()
        )));
    }
    for (t, s) in batch.iter().zip(specs) {
        if t.shape() != s.shape.as_slice() {
            return Err(Error::Shape(format!(
                "input '{}': got shape {:?}, manifest says {:?}",
                s.name,
                t.shape(),
                s.shape
            )));
        }
        let dtype_ok = match t {
            Tensor::F32 { .. } => s.dtype == "f32",
            Tensor::I32 { .. } => s.dtype == "i32",
        };
        if !dtype_ok {
            return Err(Error::Shape(format!("input '{}': dtype must be {}", s.name, s.dtype)));
        }
    }
    Ok(())
}

/// Execution mode of one [`NativeExec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Train,
    Pred,
}

/// A native "executable": the [`NativeModel`] plus a mode and thread
/// budget, presenting the same `run(&[Tensor]) → Vec<Tensor>` surface as
/// a compiled HLO executable.
pub struct NativeExec {
    model: Arc<NativeModel>,
    mode: Mode,
    threads: usize,
}

impl NativeExec {
    pub fn new(model: Arc<NativeModel>, mode: Mode, threads: usize) -> Self {
        Self { model, mode, threads }
    }

    /// The shared model (train and pred executables hold the same one, so
    /// binding an adjacency through either is visible to both).
    pub fn model(&self) -> &Arc<NativeModel> {
        &self.model
    }

    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self.mode {
            Mode::Train => self.model.train_step(inputs, self.threads),
            Mode::Pred => {
                let p = self.model.n_params();
                if inputs.len() < p {
                    return Err(Error::Shape(format!(
                        "native pred got {} inputs, needs at least {p} params",
                        inputs.len()
                    )));
                }
                let out = self.model.predict(&inputs[..p], &inputs[p..], self.threads)?;
                Ok(vec![out])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::ParamStore;

    fn tiny_clf_manifest() -> Manifest {
        spec::SageMbBuild {
            name: "tiny".into(),
            coded: true,
            link: false,
            n: 50,
            n_classes: 3,
            d_e: 4,
            hidden: 5,
            batch: 2,
            k1: 2,
            k2: 2,
            c: 4,
            m: 3,
            d_c: 4,
            d_m: 6,
            l: 2,
            light: false,
            optim: crate::cfg::OptimCfg::adamw_gnn(),
        }
        .manifest()
    }

    fn codes_tensor(rows: usize, m: usize, seed: i32) -> Tensor {
        let data: Vec<i32> = (0..rows * m).map(|i| ((i as i32 * 7 + seed) % 4).abs()).collect();
        Tensor::i32(vec![rows, m], data).unwrap()
    }

    fn clf_batch() -> Vec<Tensor> {
        vec![
            codes_tensor(2, 3, 0),
            codes_tensor(4, 3, 1),
            codes_tensor(8, 3, 2),
            Tensor::i32(vec![2], vec![0, 2]).unwrap(),
        ]
    }

    #[test]
    fn rejects_unknown_tasks_with_clear_error() {
        let mut m = tiny_clf_manifest();
        if let crate::ser::Json::Obj(o) = &mut m.hyper {
            o.insert("task".into(), crate::ser::Json::str("transformer"));
        }
        let err = NativeModel::from_manifest(&m).unwrap_err();
        assert!(format!("{err}").contains("transformer"), "{err}");
    }

    #[test]
    fn fullbatch_without_bound_adjacency_is_a_clear_error() {
        let m = spec::FullBatchBuild {
            name: "t_fb".into(),
            gnn: crate::cfg::GnnKind::Sgc,
            coded: false,
            link: false,
            n: 8,
            n_classes: 2,
            d_e: 3,
            hidden: 4,
            c: 4,
            m: 2,
            d_c: 3,
            d_m: 3,
            l: 2,
            light: false,
            e_train: 4,
            e_pred: 4,
            optim: crate::cfg::OptimCfg::adamw_gnn(),
        }
        .manifest();
        let model = NativeModel::from_manifest(&m).unwrap();
        let store = ParamStore::init(&m, 3);
        // NC full-batch pred takes no batch tensors at all.
        let err = model.predict(&store.params, &[], 1).unwrap_err();
        assert!(format!("{err}").contains("bind_adjacency"), "{err}");
        // Binding a wrong-sized CSR is rejected; a right-sized one works.
        let small = Arc::new(crate::sparse::Csr::from_edges(3, &[(0, 1)]).unwrap());
        assert!(model.bind_adjacency(small).is_err());
        let adj = Arc::new(crate::sparse::Csr::from_edges(8, &[(0, 1), (1, 2)]).unwrap());
        model.bind_adjacency(adj.clone()).unwrap();
        assert!(model.predict(&store.params, &[], 1).is_ok());
        // Rebinding the same matrix is a no-op; a different one is rejected.
        assert!(model.bind_adjacency(adj).is_ok());
        let other = Arc::new(crate::sparse::Csr::from_edges(8, &[(3, 4)]).unwrap());
        assert!(model.bind_adjacency(other).is_err());
        // Non-fullbatch models reject binding outright.
        let mb = NativeModel::from_manifest(&tiny_clf_manifest()).unwrap();
        let any = Arc::new(crate::sparse::Csr::from_edges(50, &[(0, 1)]).unwrap());
        assert!(mb.bind_adjacency(any).is_err());
    }

    #[test]
    fn fullbatch_transpose_is_computed_once_and_shared_across_steps() {
        let m = spec::FullBatchBuild {
            name: "t_fb_at".into(),
            gnn: crate::cfg::GnnKind::Sgc,
            coded: false,
            link: false,
            n: 8,
            n_classes: 2,
            d_e: 3,
            hidden: 4,
            c: 4,
            m: 2,
            d_c: 3,
            d_m: 3,
            l: 2,
            light: false,
            e_train: 4,
            e_pred: 4,
            optim: crate::cfg::OptimCfg::adamw_gnn(),
        }
        .manifest();
        let model = NativeModel::from_manifest(&m).unwrap();
        let adj = Arc::new(crate::sparse::Csr::from_edges(8, &[(0, 1), (1, 2), (2, 3)]).unwrap());
        model.bind_adjacency(adj.clone()).unwrap();
        // The structural transpose is precomputed at bind time and must
        // be REUSED by every subsequent step — recomputing it per epoch
        // would redo O(nnz) work on the full-batch hot path. Pointer
        // identity (not equality) pins that down.
        let bound = model.adj.get().expect("bound above");
        assert!(Arc::ptr_eq(&bound.a, &adj), "bound matrix is the caller's Arc, not a copy");
        let (a0, at0) = (Arc::as_ptr(&bound.a), Arc::as_ptr(&bound.at));
        let mut store = ParamStore::init(&m, 9);
        let labels = Tensor::i32(vec![8], vec![0, 1, 0, 1, 0, 1, 0, 1]).unwrap();
        let mask = Tensor::f32(vec![8], vec![1.0; 8]).unwrap();
        for _ in 0..3 {
            let inputs = store.train_inputs(&[labels.clone(), mask.clone()]);
            let outputs = model.train_step(&inputs, 1).unwrap();
            store.absorb(outputs).unwrap();
            let again = model.adj.get().expect("still bound");
            assert_eq!(Arc::as_ptr(&again.a), a0, "adjacency must not be recomputed");
            assert_eq!(Arc::as_ptr(&again.at), at0, "transpose must not be recomputed");
        }
    }

    #[test]
    fn train_step_round_trips_through_param_store() {
        let m = tiny_clf_manifest();
        let model = NativeModel::from_manifest(&m).unwrap();
        let mut store = ParamStore::init(&m, 5);
        let inputs = store.train_inputs(&clf_batch());
        let outputs = model.train_step(&inputs, 1).unwrap();
        assert_eq!(outputs.len(), 3 * model.n_params() + 1);
        let loss = store.absorb(outputs).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(store.step, 1);
    }

    #[test]
    fn train_step_rejects_malformed_batches() {
        let m = tiny_clf_manifest();
        let model = NativeModel::from_manifest(&m).unwrap();
        let store = ParamStore::init(&m, 5);
        // Wrong label arity.
        let mut bad = clf_batch();
        bad[3] = Tensor::i32(vec![3], vec![0, 1, 2]).unwrap();
        assert!(model.train_step(&store.train_inputs(&bad), 1).is_err());
        // Out-of-range label.
        let mut bad = clf_batch();
        bad[3] = Tensor::i32(vec![2], vec![0, 3]).unwrap();
        assert!(model.train_step(&store.train_inputs(&bad), 1).is_err());
        // Out-of-range code.
        let mut bad = clf_batch();
        bad[0] = Tensor::i32(vec![2, 3], vec![0, 1, 2, 3, 4, 0]).unwrap();
        assert!(model.train_step(&store.train_inputs(&bad), 1).is_err());
    }

    #[test]
    fn pred_shape_matches_manifest() {
        let m = tiny_clf_manifest();
        let model = NativeModel::from_manifest(&m).unwrap();
        let store = ParamStore::init(&m, 5);
        let batch = clf_batch();
        let out = model.predict(&store.params, &batch[..3], 2).unwrap();
        assert_eq!(out.shape(), &[2, 3]);
    }

    #[test]
    fn frozen_params_pass_through_unchanged() {
        let mut m = tiny_clf_manifest();
        // Freeze the codebooks by hand (light-style masking).
        m.params[0].trainable = false;
        let model = NativeModel::from_manifest(&m).unwrap();
        let mut store = ParamStore::init(&m, 5);
        let before = store.params[0].clone();
        let outputs = model.train_step(&store.train_inputs(&clf_batch()), 1).unwrap();
        store.absorb(outputs).unwrap();
        assert_eq!(store.params[0], before, "frozen param must not move");
        let fresh = ParamStore::init(&m, 5);
        assert_ne!(store.params[1], fresh.params[1], "trainable params must move");
    }
}
