//! Native minibatch GraphSAGE (paper §4 / Figure 4): two mean-aggregation
//! layers over the fan-out tensors [`crate::tasks::sage::SageBatcher`]
//! produces, fed by either the code-dependent decoder (compressed path)
//! or an explicit embedding table (NC baseline), with a softmax-CE node
//! head and a dot-product/BPR link head. Mirrors
//! `python/compile/gnn.py::sage_mb_apply` layer for layer, composed from
//! the shared [`super::layers`] blocks ([`FeatSource`] front-end,
//! [`LinearIdx`] layers) that the full-batch grid ([`super::gnn`]) also
//! uses.
//!
//! The backward pass is hand-derived and follows the determinism rule of
//! [`super::ops`]; gradient accumulation into shared parameters (`gnn.w1`
//! is applied twice, the feature front-end three times) happens in a fixed
//! program order, so loss curves are bit-identical across thread counts.
#![allow(clippy::too_many_arguments)]

use crate::runtime::{Manifest, Tensor};
use crate::{Error, Result};

use super::layers::{FeatCache, FeatSource, LinearIdx};
use super::ops;
use super::par::par_rows;
use super::scratch::StepScratch;

/// GraphSAGE encoder dims (one minibatch).
#[derive(Clone, Copy, Debug)]
pub struct SageDims {
    pub batch: usize,
    pub k1: usize,
    pub k2: usize,
    pub d_e: usize,
    pub hidden: usize,
}

impl SageDims {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("batch", self.batch),
            ("k1", self.k1),
            ("k2", self.k2),
            ("d_e", self.d_e),
            ("hidden", self.hidden),
        ] {
            if v == 0 {
                return Err(Error::Config(format!("sage {name} must be positive")));
            }
        }
        Ok(())
    }
}

/// The two SAGE layers (`gnn.w1/b1`, `gnn.w2/b2`) as linear blocks.
#[derive(Clone, Copy, Debug)]
pub struct SageIdx {
    pub l1: LinearIdx,
    pub l2: LinearIdx,
}

impl SageIdx {
    pub fn resolve(manifest: &Manifest, d_e: usize, hidden: usize) -> Result<Self> {
        Ok(Self {
            l1: LinearIdx::resolve(manifest, "gnn.w1", "gnn.b1", 2 * d_e, hidden)?,
            l2: LinearIdx::resolve(manifest, "gnn.w2", "gnn.b2", 2 * hidden, hidden)?,
        })
    }
}

/// Encoder forward cache (everything the reverse pass replays).
pub struct EncCache {
    fc_b: FeatCache,
    fc_h1: FeatCache,
    fc_h2: FeatCache,
    cat_h1: Vec<f32>,
    l1_h1: Vec<f32>,
    cat_b: Vec<f32>,
    l1_b: Vec<f32>,
    cat2: Vec<f32>,
    /// Final node representations `(batch, hidden)`.
    pub hfin: Vec<f32>,
}

impl EncCache {
    /// Retire the cache, returning every buffer to the step arena.
    pub fn recycle(self, scratch: &mut StepScratch) {
        self.fc_b.recycle(scratch);
        self.fc_h1.recycle(scratch);
        self.fc_h2.recycle(scratch);
        scratch.give_all([self.cat_h1, self.l1_h1, self.cat_b, self.l1_b, self.cat2, self.hfin]);
    }
}

/// Encode one node set (targets + two fan-out hops) to `(batch, hidden)`.
/// Buffers come from `scratch` (bit-identical to fresh allocation).
pub fn encode_fwd(
    feat: &FeatSource,
    sage: &SageIdx,
    dims: &SageDims,
    params: &[&[f32]],
    t_b: &Tensor,
    t_h1: &Tensor,
    t_h2: &Tensor,
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<EncCache> {
    let (b, k1, k2, d, h) = (dims.batch, dims.k1, dims.k2, dims.d_e, dims.hidden);
    let fc_b = feat.fwd(params, t_b, threads, scratch)?;
    let fc_h1 = feat.fwd(params, t_h1, threads, scratch)?;
    let fc_h2 = feat.fwd(params, t_h2, threads, scratch)?;
    let xb = feat.output(&fc_b);
    let xh1 = feat.output(&fc_h1);
    let xh2 = feat.output(&fc_h2);
    if xb.len() != b * d || xh1.len() != b * k1 * d || xh2.len() != b * k1 * k2 * d {
        return Err(Error::Shape(format!(
            "sage encode: feature rows {}/{}/{} do not match (B, B·k1, B·k1·k2) = ({b}, {}, {})",
            xb.len() / d,
            xh1.len() / d,
            xh2.len() / d,
            b * k1,
            b * k1 * k2
        )));
    }

    // Layer 1 on the hop-1 nodes (their neighbors are the hop-2 nodes).
    let mut agg_h2 = scratch.take(b * k1 * d);
    ops::mean_rows_fwd(xh2, b * k1, k2, d, &mut agg_h2, threads);
    let mut cat_h1 = scratch.take(b * k1 * 2 * d);
    ops::scatter_cols(xh1, b * k1, 2 * d, 0, d, &mut cat_h1, threads);
    ops::scatter_cols(&agg_h2, b * k1, 2 * d, d, d, &mut cat_h1, threads);
    scratch.give(agg_h2);
    let mut l1_h1 = scratch.take(b * k1 * h);
    sage.l1.fwd(params, &cat_h1, b * k1, true, &mut l1_h1, threads);

    // Layer 1 on the targets (their neighbors are the hop-1 nodes).
    let mut agg_h1 = scratch.take(b * d);
    ops::mean_rows_fwd(xh1, b, k1, d, &mut agg_h1, threads);
    let mut cat_b = scratch.take(b * 2 * d);
    ops::scatter_cols(xb, b, 2 * d, 0, d, &mut cat_b, threads);
    ops::scatter_cols(&agg_h1, b, 2 * d, d, d, &mut cat_b, threads);
    scratch.give(agg_h1);
    let mut l1_b = scratch.take(b * h);
    sage.l1.fwd(params, &cat_b, b, true, &mut l1_b, threads);

    // Layer 2: aggregate the layer-1 neighbor representations.
    let mut agg2 = scratch.take(b * h);
    ops::mean_rows_fwd(&l1_h1, b, k1, h, &mut agg2, threads);
    let mut cat2 = scratch.take(b * 2 * h);
    ops::scatter_cols(&l1_b, b, 2 * h, 0, h, &mut cat2, threads);
    ops::scatter_cols(&agg2, b, 2 * h, h, h, &mut cat2, threads);
    scratch.give(agg2);
    let mut hfin = scratch.take(b * h);
    sage.l2.fwd(params, &cat2, b, true, &mut hfin, threads);

    Ok(EncCache { fc_b, fc_h1, fc_h2, cat_h1, l1_h1, cat_b, l1_b, cat2, hfin })
}

/// Inference-only encoder: the final `(batch, hidden)` representations
/// with **no cache** — every intermediate buffer is dropped the moment
/// the next layer has consumed it, and nothing the reverse pass would
/// need survives the call. Runs the exact kernel sequence of
/// [`encode_fwd`] (each kernel is deterministic in its inputs), so the
/// output is bit-identical to the training forward's `hfin` at every
/// thread count — asserted by `tests/infer_parity.rs`.
pub fn encode_infer(
    feat: &FeatSource,
    sage: &SageIdx,
    dims: &SageDims,
    params: &[&[f32]],
    t_b: &Tensor,
    t_h1: &Tensor,
    t_h2: &Tensor,
    threads: usize,
) -> Result<Vec<f32>> {
    let (b, k1, k2, d, h) = (dims.batch, dims.k1, dims.k2, dims.d_e, dims.hidden);
    let xb = feat.infer(params, t_b, threads)?;
    let xh1 = feat.infer(params, t_h1, threads)?;
    let xh2 = feat.infer(params, t_h2, threads)?;
    if xb.len() != b * d || xh1.len() != b * k1 * d || xh2.len() != b * k1 * k2 * d {
        return Err(Error::Shape(format!(
            "sage encode: feature rows {}/{}/{} do not match (B, B·k1, B·k1·k2) = ({b}, {}, {})",
            xb.len() / d,
            xh1.len() / d,
            xh2.len() / d,
            b * k1,
            b * k1 * k2
        )));
    }

    // Layer 1 on the hop-1 nodes.
    let l1_h1 = {
        let mut agg_h2 = vec![0.0f32; b * k1 * d];
        ops::mean_rows_fwd(&xh2, b * k1, k2, d, &mut agg_h2, threads);
        drop(xh2);
        let mut cat_h1 = vec![0.0f32; b * k1 * 2 * d];
        ops::scatter_cols(&xh1, b * k1, 2 * d, 0, d, &mut cat_h1, threads);
        ops::scatter_cols(&agg_h2, b * k1, 2 * d, d, d, &mut cat_h1, threads);
        drop(agg_h2);
        let mut out = vec![0.0f32; b * k1 * h];
        sage.l1.fwd(params, &cat_h1, b * k1, true, &mut out, threads);
        out
    };

    // Layer 1 on the targets.
    let l1_b = {
        let mut agg_h1 = vec![0.0f32; b * d];
        ops::mean_rows_fwd(&xh1, b, k1, d, &mut agg_h1, threads);
        drop(xh1);
        let mut cat_b = vec![0.0f32; b * 2 * d];
        ops::scatter_cols(&xb, b, 2 * d, 0, d, &mut cat_b, threads);
        ops::scatter_cols(&agg_h1, b, 2 * d, d, d, &mut cat_b, threads);
        drop(xb);
        let mut out = vec![0.0f32; b * h];
        sage.l1.fwd(params, &cat_b, b, true, &mut out, threads);
        out
    };

    // Layer 2.
    let mut agg2 = vec![0.0f32; b * h];
    ops::mean_rows_fwd(&l1_h1, b, k1, h, &mut agg2, threads);
    drop(l1_h1);
    let mut cat2 = vec![0.0f32; b * 2 * h];
    ops::scatter_cols(&l1_b, b, 2 * h, 0, h, &mut cat2, threads);
    ops::scatter_cols(&agg2, b, 2 * h, h, h, &mut cat2, threads);
    let mut hfin = vec![0.0f32; b * h];
    sage.l2.fwd(params, &cat2, b, true, &mut hfin, threads);
    Ok(hfin)
}

/// Reverse pass of [`encode_fwd`] for `dh (batch, hidden)` — the gradient
/// w.r.t. the (post-ReLU) final representations. Accumulates into `grads`.
pub fn encode_bwd(
    feat: &FeatSource,
    sage: &SageIdx,
    dims: &SageDims,
    params: &[&[f32]],
    t_b: &Tensor,
    t_h1: &Tensor,
    t_h2: &Tensor,
    cache: &EncCache,
    dh: &[f32],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let (b, k1, k2, d, h) = (dims.batch, dims.k1, dims.k2, dims.d_e, dims.hidden);
    debug_assert_eq!(dh.len(), b * h);

    // Layer 2.
    let mut dz2 = scratch.take_copy(dh);
    ops::relu_bwd_mask(&mut dz2, &cache.hfin, threads);
    let mut dcat2 = scratch.take(b * 2 * h);
    sage.l2.bwd(params, &cache.cat2, &dz2, b, trainable, grads, Some(&mut dcat2), false, threads);
    scratch.give(dz2);
    let mut dl1_b = scratch.take(b * h);
    ops::gather_cols(&dcat2, b, 2 * h, 0, h, false, &mut dl1_b, threads);
    let mut dagg2 = scratch.take(b * h);
    ops::gather_cols(&dcat2, b, 2 * h, h, h, false, &mut dagg2, threads);
    scratch.give(dcat2);
    let mut dl1_h1 = scratch.take(b * k1 * h);
    ops::mean_rows_bwd(&dagg2, b, k1, h, false, &mut dl1_h1, threads);
    scratch.give(dagg2);

    // Layer 1, target application.
    ops::relu_bwd_mask(&mut dl1_b, &cache.l1_b, threads);
    let mut dcat_b = scratch.take(b * 2 * d);
    sage.l1.bwd(params, &cache.cat_b, &dl1_b, b, trainable, grads, Some(&mut dcat_b), false, threads);
    scratch.give(dl1_b);
    let mut dxb = scratch.take(b * d);
    ops::gather_cols(&dcat_b, b, 2 * d, 0, d, false, &mut dxb, threads);
    let mut dagg_h1 = scratch.take(b * d);
    ops::gather_cols(&dcat_b, b, 2 * d, d, d, false, &mut dagg_h1, threads);
    scratch.give(dcat_b);
    let mut dxh1 = scratch.take(b * k1 * d);
    ops::mean_rows_bwd(&dagg_h1, b, k1, d, false, &mut dxh1, threads);
    scratch.give(dagg_h1);

    // Layer 1, hop-1 application (second contribution to w1/b1 and xh1).
    ops::relu_bwd_mask(&mut dl1_h1, &cache.l1_h1, threads);
    let mut dcat_h1 = scratch.take(b * k1 * 2 * d);
    sage.l1.bwd(
        params,
        &cache.cat_h1,
        &dl1_h1,
        b * k1,
        trainable,
        grads,
        Some(&mut dcat_h1),
        false,
        threads,
    );
    scratch.give(dl1_h1);
    ops::gather_cols(&dcat_h1, b * k1, 2 * d, 0, d, true, &mut dxh1, threads);
    let mut dagg_h2 = scratch.take(b * k1 * d);
    ops::gather_cols(&dcat_h1, b * k1, 2 * d, d, d, false, &mut dagg_h2, threads);
    scratch.give(dcat_h1);
    let mut dxh2 = scratch.take(b * k1 * k2 * d);
    ops::mean_rows_bwd(&dagg_h2, b * k1, k2, d, false, &mut dxh2, threads);
    scratch.give(dagg_h2);

    // Feature front-end, fixed order: targets, hop 1, hop 2.
    feat.bwd(params, t_b, &cache.fc_b, &dxb, trainable, grads, threads, scratch)?;
    feat.bwd(params, t_h1, &cache.fc_h1, &dxh1, trainable, grads, threads, scratch)?;
    feat.bwd(params, t_h2, &cache.fc_h2, &dxh2, trainable, grads, threads, scratch)?;
    scratch.give_all([dxb, dxh1, dxh2]);
    Ok(())
}

/// Full train-step gradients for the classification head (softmax CE over
/// `n_classes`). Returns the loss.
pub fn clf_grads(
    feat: &FeatSource,
    sage: &SageIdx,
    head: &LinearIdx,
    n_classes: usize,
    dims: &SageDims,
    params: &[&[f32]],
    batch: &[Tensor],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<f32> {
    let (b, h) = (dims.batch, dims.hidden);
    let cache =
        encode_fwd(feat, sage, dims, params, &batch[0], &batch[1], &batch[2], threads, scratch)?;
    let labels = batch[3].as_i32()?;
    let mut logits = scratch.take(b * n_classes);
    head.fwd(params, &cache.hfin, b, false, &mut logits, threads);
    let mut dlogits = scratch.take(b * n_classes);
    let loss = ops::softmax_ce(&logits, labels, b, n_classes, &mut dlogits, threads)?;
    scratch.give(logits);
    let mut dh = scratch.take(b * h);
    head.bwd(params, &cache.hfin, &dlogits, b, trainable, grads, Some(&mut dh), false, threads);
    scratch.give(dlogits);
    encode_bwd(
        feat, sage, dims, params, &batch[0], &batch[1], &batch[2], &cache, &dh, trainable, grads,
        threads, scratch,
    )?;
    cache.recycle(scratch);
    scratch.give(dh);
    Ok(loss)
}

/// Prediction for the classification head: logits `(batch, n_classes)`.
/// Runs the inference-only encoder — no activation cache is built.
pub fn clf_pred(
    feat: &FeatSource,
    sage: &SageIdx,
    head: &LinearIdx,
    n_classes: usize,
    dims: &SageDims,
    params: &[&[f32]],
    batch: &[Tensor],
    threads: usize,
) -> Result<Vec<f32>> {
    let b = dims.batch;
    let hfin = encode_infer(feat, sage, dims, params, &batch[0], &batch[1], &batch[2], threads)?;
    let mut logits = vec![0.0f32; b * n_classes];
    head.fwd(params, &hfin, b, false, &mut logits, threads);
    Ok(logits)
}

/// Train-step gradients for the dot-product/BPR link head: three node
/// sets (source `u`, positive `v`, negative `w`), loss
/// `mean softplus(−(⟨hu, hv⟩ − ⟨hu, hw⟩))`.
pub fn link_grads(
    feat: &FeatSource,
    sage: &SageIdx,
    dims: &SageDims,
    params: &[&[f32]],
    batch: &[Tensor],
    trainable: &[bool],
    grads: &mut [Vec<f32>],
    threads: usize,
    scratch: &mut StepScratch,
) -> Result<f32> {
    let (b, h) = (dims.batch, dims.hidden);
    let cu =
        encode_fwd(feat, sage, dims, params, &batch[0], &batch[1], &batch[2], threads, scratch)?;
    let cv =
        encode_fwd(feat, sage, dims, params, &batch[3], &batch[4], &batch[5], threads, scratch)?;
    let cw =
        encode_fwd(feat, sage, dims, params, &batch[6], &batch[7], &batch[8], threads, scratch)?;
    let mut pos = scratch.take(b);
    let mut neg = scratch.take(b);
    ops::dot_rows(&cu.hfin, &cv.hfin, b, h, &mut pos, threads);
    ops::dot_rows(&cu.hfin, &cw.hfin, b, h, &mut neg, threads);
    let mut dpos = scratch.take(b);
    let mut dneg = scratch.take(b);
    let loss = ops::bpr_loss(&pos, &neg, &mut dpos, &mut dneg);
    scratch.give(pos);
    scratch.give(neg);
    // Score gradients back to the three representation sets.
    let mut dhu = scratch.take(b * h);
    let mut dhv = scratch.take(b * h);
    let mut dhw = scratch.take(b * h);
    {
        let (hu, hv, hw) = (&cu.hfin, &cv.hfin, &cw.hfin);
        par_rows(&mut dhu, h, threads, |row0, rows| {
            for (i, row) in rows.chunks_mut(h).enumerate() {
                let r = row0 + i;
                for (j, o) in row.iter_mut().enumerate() {
                    *o = dpos[r] * hv[r * h + j] + dneg[r] * hw[r * h + j];
                }
            }
        });
        par_rows(&mut dhv, h, threads, |row0, rows| {
            for (i, row) in rows.chunks_mut(h).enumerate() {
                let r = row0 + i;
                for (j, o) in row.iter_mut().enumerate() {
                    *o = dpos[r] * hu[r * h + j];
                }
            }
        });
        par_rows(&mut dhw, h, threads, |row0, rows| {
            for (i, row) in rows.chunks_mut(h).enumerate() {
                let r = row0 + i;
                for (j, o) in row.iter_mut().enumerate() {
                    *o = dneg[r] * hu[r * h + j];
                }
            }
        });
    }
    scratch.give(dpos);
    scratch.give(dneg);
    // Fixed order: u, v, w.
    encode_bwd(
        feat,
        sage,
        dims,
        params,
        &batch[0],
        &batch[1],
        &batch[2],
        &cu,
        &dhu,
        trainable,
        grads,
        threads,
        scratch,
    )?;
    encode_bwd(
        feat,
        sage,
        dims,
        params,
        &batch[3],
        &batch[4],
        &batch[5],
        &cv,
        &dhv,
        trainable,
        grads,
        threads,
        scratch,
    )?;
    encode_bwd(
        feat,
        sage,
        dims,
        params,
        &batch[6],
        &batch[7],
        &batch[8],
        &cw,
        &dhw,
        trainable,
        grads,
        threads,
        scratch,
    )?;
    cu.recycle(scratch);
    cv.recycle(scratch);
    cw.recycle(scratch);
    scratch.give_all([dhu, dhv, dhw]);
    Ok(loss)
}

/// Prediction for the link head: scores `(batch,)` for (u, v) pairs.
/// Runs the inference-only encoder — no activation cache is built.
pub fn link_pred(
    feat: &FeatSource,
    sage: &SageIdx,
    dims: &SageDims,
    params: &[&[f32]],
    batch: &[Tensor],
    threads: usize,
) -> Result<Vec<f32>> {
    let (b, h) = (dims.batch, dims.hidden);
    let hu = encode_infer(feat, sage, dims, params, &batch[0], &batch[1], &batch[2], threads)?;
    let hv = encode_infer(feat, sage, dims, params, &batch[3], &batch[4], &batch[5], threads)?;
    let mut scores = vec![0.0f32; b];
    ops::dot_rows(&hu, &hv, b, h, &mut scores, threads);
    Ok(scores)
}
